"""Attention-free mixers: RWKV6 (Finch) and RG-LRU (Griffin/RecurrentGemma).

Both are linear-recurrence token mixers with O(1) decode state — they are
what makes the ``long_500k`` cell feasible.  Train/prefill run the
recurrence with ``lax.scan`` over time (chunk-parallel forms are a §Perf
extension); decode is a single recurrence step on carried state.

RWKV6 (arXiv:2404.05892), simplified faithfully:
  per head h, state S_t in R^{dk x dv}:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^t v_t)        (u: bonus for current)
  with data-dependent decay w_t = exp(-exp(w0 + tanh(x_t A) B)) and
  token-shift interpolation x'_t = lerp(x_t, x_{t-1}, mu_*).

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(x_t W_r);  i_t = sigmoid(x_t W_i)
    a_t = a^(c * r_t)  (a = sigmoid(Lambda), c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
  preceded by a short depthwise conv1d (Griffin recurrent block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["rwkv6_mix", "rwkv6_channelmix", "rglru_block"]


# --------------------------------------------------------------------- RWKV6
def _rwkv6_chunked(r, k, v, w, u, state0, chunk: int = 16):
    """Chunk-parallel (GLA-form) RWKV6 recurrence — the §Perf variant.

    Equivalent to the per-token scan but processes C tokens per step;
    state I/O drops from per-token to per-chunk (C x fewer HBM bytes for
    the (B, H, Dk, Dv) state and, crucially, for the backward pass's
    scan-saved copies).  Derivation: with per-channel decay w_t and
    b_i = sum_{j<=i} log w_j (monotone non-increasing within a chunk),

      intra:  o_i += sum_{j<i} (r_i * e^{b_{i-1}-b_j}) . k_j  v_j
              + (r_i . u k_i) v_i                  (diagonal bonus)
      cross:  o_i += (r_i * e^{b_{i-1}}) S_in
      state:  S_out = diag(e^{b_last}) S_in + sum_j (k_j e^{b_last-b_j})^T v_j

    All exponents are <= 0: cross/state by monotonicity, and the intra
    pair term is computed *exactly* per (i, j, d) inside one fused
    broadcast-multiply-reduce (the (B, C, C, H, D) intermediate never
    reaches HBM), clamped at 0 only for the masked j >= i half.  This
    avoids the overflow-prone e^{-b_j} factoring of matmul-form GLA; the
    anchored sub-chunk factoring (FLA) is the follow-up if MXU utilization
    of the intra term ever matters — at C = 16 the intra work is ~C/S of
    the recurrent FLOPs and stays off the roofline.

    The chunk step is jax.checkpoint'ed: backward saves one state per
    chunk, not per token.
    """
    B, S, H, D = r.shape
    if S % chunk:
        pad = chunk - S % chunk
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded tokens: k=v=r=0 (no output/state contribution), w=1
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        S_pad = S + pad
    else:
        S_pad = S
    C = chunk
    N = S_pad // C

    def seg(t):  # (B, S, H, D) -> (N, B, C, H, D)
        return jnp.moveaxis(t.reshape(B, N, C, H, D), 1, 0)

    rs, ks, vs, ws = seg(r), seg(k), seg(v), seg(w)
    causal = jnp.tril(jnp.ones((C, C), jnp.float32), -1)  # strict lower

    def chunk_step(state, inp):
        r_c, k_c, v_c, w_c = inp  # (B, C, H, D)
        # r/k/v may arrive in bf16 (their producing matmuls are bf16, so
        # this is their native precision); all recurrence math is f32.
        r_c, k_c, v_c = (t.astype(jnp.float32) for t in (r_c, k_c, v_c))
        logw = jnp.log(jnp.maximum(w_c, 1e-38))
        b = jnp.cumsum(logw, axis=1)             # (B, C, H, D), <= 0
        b_last = b[:, -1:, :, :]
        b_prev = b - logw                        # b_{i-1}
        # intra-chunk, exact pairwise decay: exponent b_{i-1} - b_j <= 0
        # for the causal (j < i) half; clamp the masked half to 0 so the
        # exp never overflows.  One fused elementwise+reduce on TPU.
        expo = jnp.minimum(
            b_prev[:, :, None, :, :] - b[:, None, :, :, :], 0.0
        )  # (B, C, C, H, D)
        att = jnp.sum(
            r_c[:, :, None, :, :] * k_c[:, None, :, :, :] * jnp.exp(expo),
            axis=-1,
        )  # (B, C, C, H)
        att = att * causal[None, :, :, None]
        o = jnp.einsum("bijh,bjhd->bihd", att, v_c)
        diag = jnp.einsum("bihd,bihd->bih", r_c * u[None, None], k_c)
        o = o + diag[..., None] * v_c
        # cross-chunk from carried state (exponent <= 0)
        q_in = r_c * jnp.exp(b_prev)
        o = o + jnp.einsum("bihk,bhkv->bihv", q_in, state)
        # state update (exponents <= 0)
        k_out = k_c * jnp.exp(b_last - b)
        state = jnp.exp(b_last)[:, 0, :, :, None] * state + jnp.einsum(
            "bjhk,bjhv->bhkv", k_out, v_c
        )
        return state, o

    state, outs = jax.lax.scan(
        jax.checkpoint(chunk_step), state0, (rs, ks, vs, ws)
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S_pad, H, D)
    return out[:, :S], state


def _rwkv6_recurrence(r, k, v, w, u, state0):
    """r,k,v: (B, S, H, D); w: (B, S, H, D) decay in (0,1); u: (H, D).

    state: (B, H, D, D) mapping k-dim -> v-dim.  Returns (out, state_final).
    """
    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, D)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, Dk, Dv)
        out = jnp.einsum(
            "bhk,bhkv->bhv", r_t, state + u[None, :, :, None] * kv
        )
        state = w_t[..., :, None] * state + kv
        return state, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1), state  # (B, S, H, Dv)


def rwkv6_mix(p, x, cfg, state=None, prev_x=None):
    """RWKV6 time-mix.  x: (B, S, d).  Returns (y, (state, last_x))."""
    B, S, d = x.shape
    H, D = cfg.num_heads, cfg.rwkv_head_dim
    dt = x.dtype
    if prev_x is None:
        prev_x = jnp.zeros((B, d), dt)
    x_shift = jnp.concatenate([prev_x[:, None], x[:, :-1]], axis=1)

    def lerp(mu):
        return x + (x_shift - x) * mu

    def heads(t):
        return t.reshape(B, S, H, D)

    r = heads(lerp(p["rwkv_mu_r"]) @ p["rwkv_w_r"])
    k = heads(lerp(p["rwkv_mu_k"]) @ p["rwkv_w_k"])
    v = heads(lerp(p["rwkv_mu_v"]) @ p["rwkv_w_v"])
    g = jax.nn.silu(lerp(p["rwkv_mu_g"]) @ p["rwkv_w_g"])
    # data-dependent decay (low-rank): w = exp(-exp(w0 + tanh(x A) B))
    dd = jnp.tanh(lerp(p["rwkv_mu_w"]) @ p["rwkv_w_decay_a"])
    logit = p["rwkv_w0"] + dd @ p["rwkv_w_decay_b"]
    w = heads(jnp.exp(-jnp.exp(logit.astype(jnp.float32)))).astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)
    chunk = getattr(cfg, "rwkv_chunk", 0)
    if chunk and S > 1:
        # chunked path: r/k/v streams stay bf16 until inside the chunk
        # step (halves the scan-saved stream bytes); decay stays f32.
        out, state = _rwkv6_chunked(
            r, k, v, w, p["rwkv_u"].astype(jnp.float32), state,
            chunk=chunk,
        )
    else:
        out, state = _rwkv6_recurrence(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, p["rwkv_u"].astype(jnp.float32),
            state,
        )
    out = out.reshape(B, S, H * D).astype(dt)
    y = (out * g) @ p["rwkv_w_o"]
    return y, (state, x[:, -1])


def rwkv6_channelmix(p, x, prev_x=None):
    """RWKV channel-mix FFN (relu^2), with token shift."""
    B, S, d = x.shape
    if prev_x is None:
        prev_x = jnp.zeros((B, d), x.dtype)
    x_shift = jnp.concatenate([prev_x[:, None], x[:, :-1]], axis=1)
    xk = x + (x_shift - x) * p["rwkv_mu_ck"]
    xr = x + (x_shift - x) * p["rwkv_mu_cr"]
    h = jnp.square(jax.nn.relu(xk @ p["rwkv_w_ck"]))
    gate = jax.nn.sigmoid(xr @ p["rwkv_w_cr"])
    return gate * (h @ p["rwkv_w_cv"]), x[:, -1]


# -------------------------------------------------------------------- RG-LRU
LRU_C = 8.0


def _rglru_recurrence(a, gated_x, h0, out_dtype=jnp.float32):
    """a: (B, S, W) f32 (decay precision near 1 matters); gated_x may be
    bf16 (its producing matmul/gates are bf16 — §Perf stream-dtype cut);
    h0: (B, W) f32 carry.  Emits hs in ``out_dtype``."""
    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + jnp.sqrt(
            jnp.maximum(1.0 - a_t * a_t, 0.0)
        ) * gx_t.astype(jnp.float32)
        return h, h.astype(out_dtype)

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated_x, 1, 0))
    h, outs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(outs, 0, 1), h


def rglru_block(p, x, cfg, state=None):
    """Griffin recurrent block: in-proj + conv1d + RG-LRU + gated out-proj.

    x: (B, S, d).  state = (h (B,W) f32, conv tail (B, cw-1, W)).
    Returns (y, state).
    """
    B, S, d = x.shape
    W = cfg.lru_width
    cw = cfg.conv_width
    dt = x.dtype
    u = x @ p["lru_in"]  # (B, S, W)
    gate_branch = jax.nn.gelu(x @ p["lru_gate"])

    if state is None:
        h0 = jnp.zeros((B, W), jnp.float32)
        conv_tail = jnp.zeros((B, cw - 1, W), dt)
    else:
        h0, conv_tail = state
    # depthwise causal conv1d over time, width cw
    u_pad = jnp.concatenate([conv_tail, u], axis=1)  # (B, S+cw-1, W)
    conv = sum(
        u_pad[:, i : i + S] * p["lru_conv"][i][None, None, :]
        for i in range(cw)
    ) + p["lru_conv_bias"][None, None, :]
    new_tail = u_pad[:, S:, :]

    # per-channel gates (Griffin uses block-diagonal W_a/W_x; the diagonal
    # form keeps the recurrence TP-shardable with zero replicated weight)
    r = jax.nn.sigmoid(conv * p["lru_wr"][None, None, :] + p["lru_br"])
    i_g = jax.nn.sigmoid(conv * p["lru_wi"][None, None, :] + p["lru_bi"])
    log_a = -LRU_C * r * jax.nn.softplus(p["lru_lambda"])[None, None, :]
    a = jnp.exp(log_a.astype(jnp.float32))
    gx = i_g * conv  # stays in activation dtype; f32 inside the step
    hs, h_last = _rglru_recurrence(a, gx, h0, out_dtype=dt)
    y = (hs * gate_branch) @ p["lru_out"]
    return y, (h_last, new_tail)
