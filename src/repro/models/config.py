"""Model/shape configuration dataclasses for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "block_kinds"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    attention: str = "full"  # full | swa | local | mla | none
    window: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mlp_type: str = "swiglu"  # swiglu | gelu
    # layer pattern: fraction of layers that are attention for hybrids;
    # explicit kinds are derived in block_kinds()
    mixer: str = "attn"  # attn | rwkv6 | rglru_hybrid
    attn_every: int = 0  # for rglru_hybrid: attention layer every N layers
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_first_dense: int = 0  # leading dense-FFN layers
    moe_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    # MLA
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0
    mla_nope_dim: int = 0
    mla_v_dim: int = 0
    # RWKV / RG-LRU
    rwkv_head_dim: int = 64
    # chunk length for the chunk-parallel RWKV6 recurrence (0 = per-token
    # scan, the paper-faithful-style baseline; 16 = GLA-form §Perf variant)
    rwkv_chunk: int = 0
    # shard rwkv blocks batch-parallel over (data x model) with FSDP
    # weights instead of row-parallel TP (kills the per-projection psums
    # that dominate the collective term; see EXPERIMENTS.md §Perf)
    rwkv_batch_parallel: bool = False
    # flash-style custom-VJP attention backward (recompute block scores
    # instead of autodiff saving them; §Perf)
    flash_vjp: bool = False
    # FSDP-only parallelism (ZeRO-3 style): batch sharded over the FULL
    # mesh, weights row-sharded over the full mesh and gathered per layer,
    # NO tensor-parallel activation psums.  The right regime whenever the
    # per-layer weight all-gather is cheaper than 2 activation all-reduces
    # per layer — true for every dense train_4k cell on the 16x16 mesh
    # (see EXPERIMENTS.md §Perf).  Dense/GQA archs only (MoE uses EP).
    fsdp_only: bool = False
    # sequence-parallel (context-parallel) prefill for windowed-attention
    # archs: activations S-sharded over the model axis, weights FSDP —
    # SWA attention only needs a window-sized KV halo from the neighbor
    # shard (XLA lowers it to collective-permute), killing the per-layer
    # Megatron activation all-reduces that dominate prefill collectives.
    seq_parallel_prefill: bool = False
    # gradient-accumulation microbatches for train_step (activation
    # memory scales with global_batch / train_microbatch)
    train_microbatch: int = 1
    # MLA absorbed decode: attention in the compressed-KV space (no per-
    # step cache decompression); beyond-paper §Perf variant
    mla_absorb: bool = False
    lru_width: int = 0
    conv_width: int = 4
    # modality
    frontend: str = "tokens"  # tokens | embeddings (audio/vlm stub)
    dtype_str: str = "bfloat16"
    remat: bool = True
    paper_ref: str = ""

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_str)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with O(1)/O(window) state?"""
        return self.mixer != "attn" or self.attention in ("swa", "local")

    def num_params(self) -> int:
        """Total parameter count (exact, from the layer definitions)."""
        from .transformer import count_params  # lazy to avoid cycle

        return count_params(self)

    def active_params(self) -> int:
        from .transformer import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def block_kinds(cfg: ModelConfig) -> Tuple[Tuple[str, str], ...]:
    """Per-layer (mixer_kind, ffn_kind) tuples.

    mixer_kind in {attn, swa, local, mla, rwkv6, rglru};
    ffn_kind in {dense, dense_big, moe, channelmix}.
    """
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.mixer == "rwkv6":
            mixer = "rwkv6"
        elif cfg.mixer == "rglru_hybrid":
            mixer = ("local" if cfg.attn_every and (i % cfg.attn_every
                     == cfg.attn_every - 1) else "rglru")
        else:
            mixer = cfg.attention
        if cfg.moe_num_experts and i >= cfg.moe_first_dense:
            ffn = "moe"
        elif cfg.moe_num_experts:
            ffn = "dense_big"
        elif cfg.mixer == "rwkv6":
            ffn = "channelmix"
        else:
            ffn = "dense"
        kinds.append((mixer, ffn))
    return tuple(kinds)


def segments(cfg: ModelConfig):
    """Group consecutive identical block kinds for lax.scan stacking."""
    out = []
    for kind in block_kinds(cfg):
        if out and out[-1][0] == kind:
            out[-1][1] += 1
        else:
            out.append([kind, 1])
    return [(tuple(k), n) for k, n in out]
