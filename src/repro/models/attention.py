"""Attention: blocked online-softmax (full/windowed), GQA/MQA/MLA, decode.

Never materializes S x S scores: training/prefill attention scans KV blocks
with a running (max, denom, acc) — the flash-attention recurrence in pure
JAX, which is what makes prefill_32k compile inside HBM.  Windowed variants
(SWA / Griffin local) use a *banded* q-block scan whose KV span is constant
(window + one q block), so compiled FLOPs scale with S*window, not S^2.

Full-causal attention pays ~2x ideal FLOPs (masked upper triangle is still
computed) — a known artifact of dense-blocked causal attention; see
EXPERIMENTS.md §Roofline for the accounting and §Perf for the staircase
packing that removes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..distributed.compat import shard_map

__all__ = ["attend", "decode_attend", "swa_attend_cp"]

NEG_INF = -1e30


def _pick_block(T: int) -> int:
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if T % cand == 0:
            return min(T, cand)
    return T


def _online_block_scan(q, k_span, v_span, q_pos, kv_pos, window, scale,
                       with_stats: bool = False):
    """Online-softmax over KV blocks of a span.

    q: (B, Q, KVH, G, Dk); k_span: (B, T, KVH, Dk); v_span: (B, T, KVH, Dv);
    q_pos: (Q,) absolute positions; kv_pos: (T,) absolute positions
    (entries < 0 are padding and always masked).  Causal + window mask.
    Returns (B, Q, KVH, G, Dv) f32 (unnormalized-then-normalized); with
    ``with_stats`` also the running (m, l) softmax statistics for the
    flash backward.
    """
    B, Q, KVH, G, Dk = q.shape
    T = k_span.shape[1]
    Dv = v_span.shape[-1]
    bk = _pick_block(T)
    nkb = T // bk

    qf = q.astype(jnp.float32) * scale

    def step(carry, j):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k_span, j * bk, bk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v_span, j * bk, bk, axis=1)
        ps = jax.lax.dynamic_slice_in_dim(kv_pos, j * bk, bk, axis=0)
        s = jnp.einsum(
            "bqkgd,btkd->bkgqt", qf, ks.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # (B, KVH, G, Q, bk)
        allow = (ps[None, :] <= q_pos[:, None]) & (ps[None, :] >= 0)
        if window:
            allow &= ps[None, :] > (q_pos[:, None] - window)
        s = jnp.where(allow[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bkgqt,btkd->bkgqd", p, vs.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, Q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Q), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Q, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), jnp.arange(nkb), length=nkb
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, Q, KVH, G, Dv)
    if with_stats:
        return out, m, l
    return out


# ------------------------------------------------------- flash custom VJP
def _mask(ps, q_pos, window):
    allow = (ps[None, :] <= q_pos[:, None]) & (ps[None, :] >= 0)
    if window:
        allow &= ps[None, :] > (q_pos[:, None] - window)
    return allow


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _flash(q, k_span, v_span, q_pos, kv_pos, window, scale):
    """Online-softmax attention with a flash-style backward.

    Identical forward to _online_block_scan; the custom VJP recomputes
    block scores in the backward instead of letting scan save p / (m, l,
    acc) per KV block — without this, autodiff materializes the full
    S x S score matrix per layer (the dominant §Perf memory bucket for
    dense-train cells).  Residuals: (q, k, v, out, m, l) — O(S·d), not
    O(S^2).
    """
    out, _, _ = _flash_fwd_impl(q, k_span, v_span, q_pos, kv_pos, window,
                                scale)
    return out


def _flash_fwd_impl(q, k_span, v_span, q_pos, kv_pos, window, scale):
    out = _online_block_scan(q, k_span, v_span, q_pos, kv_pos, window,
                             scale, with_stats=True)
    return out


def _flash_fwd(q, k_span, v_span, q_pos, kv_pos, window, scale):
    out, m, l = _flash_fwd_impl(q, k_span, v_span, q_pos, kv_pos, window,
                                scale)
    return out, (q, k_span, v_span, q_pos, kv_pos, out, m, l)


def _flash_bwd(window, scale, res, g):
    q, k_span, v_span, q_pos, kv_pos, out, m, l = res
    B, Q, KVH, G, Dk = q.shape
    T = k_span.shape[1]
    Dv = v_span.shape[-1]
    bk = _pick_block(T)
    nkb = T // bk
    qf = q.astype(jnp.float32) * scale
    g32 = g.astype(jnp.float32)
    # delta_i = sum_d dO_i O_i  (B, KVH, G, Q)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", g32, out)
    linv = 1.0 / jnp.maximum(l, 1e-30)

    def step(dq_acc, j):
        ks = jax.lax.dynamic_slice_in_dim(k_span, j * bk, bk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v_span, j * bk, bk, axis=1)
        ps = jax.lax.dynamic_slice_in_dim(kv_pos, j * bk, bk, axis=0)
        s = jnp.einsum(
            "bqkgd,btkd->bkgqt", qf, ks.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        allow = _mask(ps, q_pos, window)
        s = jnp.where(allow[None, None, None], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) * linv[..., None]  # true probs
        dv_j = jnp.einsum(
            "bkgqt,bqkgd->btkd", p, g32,
            preferred_element_type=jnp.float32,
        )
        dp = jnp.einsum(
            "bqkgd,btkd->bkgqt", g32, vs.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - delta[..., None])  # (B, KVH, G, Q, bk)
        dq_j = jnp.einsum(
            "bkgqt,btkd->bqkgd", ds, ks.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        dk_j = jnp.einsum(
            "bkgqt,bqkgd->btkd", ds, qf,
            preferred_element_type=jnp.float32,
        )
        return dq_acc + dq_j, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Q, KVH, G, Dk), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, jnp.arange(nkb), length=nkb)
    dq = dq * scale
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, T, KVH, Dk)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, T, KVH, Dv)
    return (dq.astype(q.dtype), dk.astype(k_span.dtype),
            dv.astype(v_span.dtype), None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend(q, k, v, *, window: int = 0, q_block: int = 1024,
           q_offset: int = 0, flash_vjp: bool = False):
    """Causal (optionally windowed) attention for train/prefill.

    q: (B, Sq, H, Dk); k: (B, Skv, KVH, Dk); v: (B, Skv, KVH, Dv).
    H % KVH == 0 (GQA); Dv may differ from Dk (MLA).  ``q_offset`` is the
    absolute position of q[0] (0 for train; cache length for chunked
    prefill).  ``flash_vjp`` switches the backward to the flash-style
    recompute (identical forward; see _flash).  Returns (B, Sq, H, Dv) in
    q.dtype.
    """
    B, Sq, H, Dk = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = Dk**-0.5
    qr = q.reshape(B, Sq, KVH, G, Dk)
    kv_pos = jnp.arange(Skv, dtype=jnp.int32)
    inner = (
        functools.partial(_flash, window=0)
        if flash_vjp else
        functools.partial(_online_block_scan, window=0)
    )

    if not window or window >= Skv:
        # full causal: single q span over all KV blocks
        q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
        out = inner(qr, k, v, q_pos, kv_pos, scale=scale)
        return out.reshape(B, Sq, H, -1).astype(q.dtype)

    # banded: constant KV span per q block = window rounded up + one block
    bq = min(q_block, Sq)
    nqb = Sq // bq
    assert Sq % bq == 0, "pad Sq to q_block"
    span = min(Skv, ((window + bq + bq - 1) // bq) * bq)

    def qstep(_, i):
        q_i = jax.lax.dynamic_slice_in_dim(qr, i * bq, bq, axis=1)
        q_pos = q_offset + i * bq + jnp.arange(bq, dtype=jnp.int32)
        start = jnp.clip(q_offset + (i + 1) * bq - span, 0, Skv - span)
        k_s = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        v_s = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        p_s = jax.lax.dynamic_slice_in_dim(kv_pos, start, span, axis=0)
        out_i = _online_block_scan(q_i, k_s, v_s, q_pos, p_s, window, scale)
        return None, out_i

    _, outs = jax.lax.scan(qstep, None, jnp.arange(nqb), length=nqb)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KVH, G, -1)
    return out.reshape(B, Sq, H, -1).astype(q.dtype)


def swa_attend_cp(q, k, v, *, window: int, rules, q_block: int = 1024,
                  flash_vjp: bool = False):
    """Context-parallel sliding-window attention (explicit halo exchange).

    S is sharded over the tp axis; each device holds an S/ntp chunk and
    needs only ceil(window / S_local) left-neighbor chunks of K/V — moved
    with ppermute inside shard_map, so the collective cost is the halo
    (window-sized), not per-layer activation all-reduces, and no
    computation is replicated (XLA's auto-partitioner replicates the
    banded q-block scan when left to its own devices — measured 4x flops;
    see EXPERIMENTS.md §Perf h2o prefill iterations).

    Semantics identical to attend(window=...) for S % ntp == 0.
    """
    mesh, tp = rules.mesh, rules.tp_axis
    ntp = rules.tp_size
    B, S, H, Dk = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = Dk**-0.5
    S_local = S // ntp
    n_halo = -(-window // S_local)  # ceil: neighbor chunks covering window
    perm = [(i, (i + 1) % ntp) for i in range(ntp)]
    dp = rules.dp_axes
    from jax.sharding import PartitionSpec as P  # local import, tidy deps

    spec = P(dp, tp, None, None)

    def local_fn(q_l, k_l, v_l):
        idx = jax.lax.axis_index(tp)
        halos_k, halos_v = [], []
        kk, vv = k_l, v_l
        for _ in range(n_halo):
            kk = jax.lax.ppermute(kk, tp, perm)
            vv = jax.lax.ppermute(vv, tp, perm)
            halos_k.insert(0, kk)
            halos_v.insert(0, vv)
        k_span = jnp.concatenate(halos_k + [k_l], axis=1)
        v_span = jnp.concatenate(halos_v + [v_l], axis=1)
        start = (idx - n_halo) * S_local
        kv_pos = start + jnp.arange((n_halo + 1) * S_local,
                                    dtype=jnp.int32)
        q_pos = idx * S_local + jnp.arange(S_local, dtype=jnp.int32)
        qr = q_l.reshape(q_l.shape[0], S_local, KVH, G, Dk)
        fn = _flash if flash_vjp else _online_block_scan
        out = fn(qr, k_span, v_span, q_pos, kv_pos, window, scale)
        return out.reshape(q_l.shape[0], S_local, H, -1).astype(q_l.dtype)

    return shard_map(
        local_fn, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False,
    )(q, k, v)


def decode_attend(q, k_cache, v_cache, cache_pos, pos, *, window: int = 0):
    """Single-token decode attention over a (possibly ring) KV cache.

    q: (B, 1, H, Dk); k_cache: (B, T, KVH, Dk); v_cache: (B, T, KVH, Dv);
    cache_pos: (T,) absolute position held in each cache slot (-1 = empty);
    pos: () current absolute position.  Window semantics match attend().
    """
    B, _, H, Dk = q.shape
    T, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = Dk**-0.5
    qf = q.reshape(B, KVH, G, Dk).astype(jnp.float32) * scale
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qf, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    allow = (cache_pos <= pos) & (cache_pos >= 0)
    if window:
        allow &= cache_pos > (pos - window)
    s = jnp.where(allow[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, -1).astype(q.dtype)
