"""Shared primitive layers: RMSNorm, rotary embedding, MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rotary", "apply_rope", "swiglu", "gelu_mlp"]


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def rotary(positions, dim: int, theta: float, dtype=jnp.float32):
    """(..., P) int positions -> cos/sin tables (..., P, dim//2)."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """LLaMA-style gated MLP: (x@w1 * silu(x@w3)) @ w2."""
    h = jnp.einsum("...d,dh->...h", x, w1)
    g = jax.nn.silu(jnp.einsum("...d,dh->...h", x, w3))
    return jnp.einsum("...h,hd->...d", h * g, w2)


def gelu_mlp(x, w1, w2):
    h = jax.nn.gelu(jnp.einsum("...d,dh->...h", x, w1))
    return jnp.einsum("...h,hd->...d", h, w2)
