from .config import ModelConfig, ShapeConfig, SHAPES, block_kinds, segments
from . import attention, kvcache, layers, moe, ssm, transformer

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "block_kinds", "segments",
           "attention", "kvcache", "layers", "moe", "ssm", "transformer"]
