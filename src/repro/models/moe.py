"""Token-choice top-k MoE with expert parallelism over the 'model' axis.

Scheme: *replicated dispatch* EP.  Activations are data-sharded and
model-replicated under our pjit layout, so every model shard already holds
all tokens of its data shard.  Each shard therefore:

  1. routes its local tokens (router is replicated),
  2. builds a capacity-bounded (E_local, C, d) dispatch buffer for the
     experts *it owns* only (scatter with drop),
  3. runs its expert FFNs,
  4. scatters results back to token order weighted by router gates,
  5. psum over the 'model' axis merges the k expert contributions that live
     on different shards (this all-reduce is the only EP collective, the
     same cost as a Megatron TP all-reduce).

Steps 2-5 run inside shard_map (manual collectives); everything composes
with the auto-sharded pjit program around it.  Tokens beyond capacity
C = ceil(T k cf / E) are dropped (standard Switch-style; drop counts are
returned for monitoring).  Shared experts (DeepSeek-style) are computed
TP-style inside the same shard_map and merged into the same psum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from ..distributed.compat import shard_map

__all__ = ["moe_ffn", "router_aux_loss"]


def _route(x, router_w, top_k):
    """x: (T, d) -> (gates (T,k) f32, experts (T,k) i32, probs (T,E) f32)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


def router_aux_loss(probs, experts, num_experts):
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[
        experts.reshape(-1)
    ].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p_mean)


def _local_expert_pass(x, gates, experts, w1, w3, w2, capacity, e_offset,
                       num_experts):
    """Dispatch local tokens to locally-owned experts, compute, combine.

    x: (T, d); gates/experts: (T, k); w*: (E_loc, ...); returns (T, d)
    partial output (zero rows for tokens whose experts live elsewhere) and
    the number of dropped assignments.
    """
    T, d = x.shape
    k = experts.shape[1]
    E_loc = w1.shape[0]
    fe = experts.reshape(-1)  # (T*k,) global expert ids
    gate_flat = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    # position of each assignment within its expert queue (over ALL experts
    # so ordering is shard-invariant), via sort-based ranking
    order = jnp.argsort(fe, stable=True)
    fe_sorted = fe[order]
    # start offset of each expert's run
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(fe_sorted, length=num_experts), axis=0)[
             :-1
         ].astype(jnp.int32)]
    )
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - seg_start[fe_sorted]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)

    local = (fe >= e_offset) & (fe < e_offset + E_loc)
    kept = local & (pos < capacity)
    dropped = jnp.sum(local & (pos >= capacity))
    slot = jnp.where(kept, (fe - e_offset) * capacity + pos, E_loc * capacity)
    # scatter token *ids* (int32), then gather x straight into the
    # capacity buffer — scattering x[tok] directly would materialize a
    # (T*k, d) copy of the activations (k x the activation bytes; the
    # dominant §Perf memory bucket for the MoE train cells).
    tok_buf = jnp.full((E_loc * capacity + 1,), T, jnp.int32)
    tok_buf = tok_buf.at[slot].set(tok, mode="drop")[:-1]
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = x_pad[tok_buf].reshape(E_loc, capacity, d)

    h = jnp.einsum("ecd,edh->ech", buf, w1)
    g = jax.nn.silu(jnp.einsum("ecd,edh->ech", buf, w3))
    out_buf = jnp.einsum("ech,ehd->ecd", h * g, w2)  # (E_loc, C, d)

    # combine: per-token gather of its k expert rows, weighted reduce over
    # k in one fusion (the gather is a fusable producer — no (T*k, d)
    # intermediate in HBM).  Dropped/remote assignments point at a zero row.
    flat_out = jnp.concatenate(
        [out_buf.reshape(E_loc * capacity, d),
         jnp.zeros((1, d), out_buf.dtype)], axis=0,
    )
    slot_2d = jnp.where(kept, slot, E_loc * capacity).reshape(T, k)
    w_2d = jnp.where(kept, gate_flat, 0.0).reshape(T, k).astype(x.dtype)
    y = jnp.einsum("tkd,tk->td", flat_out[slot_2d], w_2d)
    return y, dropped


def moe_ffn(x, params, cfg, rules):
    """MoE FFN.  x: (B, S, d) global (pjit-sharded).  Returns (y, aux).

    params: router (d, E); experts_w1/w3 (E, d, h); experts_w2 (E, h, d);
    optional shared_w1/w3 (d, hs), shared_w2 (hs, d).
    """
    B, S, d = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    mesh = rules.mesh
    tp = rules.tp_axis
    dp = rules.dp_axes

    def inner(x_loc, router_w, w1, w3, w2, *shared):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, d)
        gates, experts, probs = _route(xt, router_w, k)
        aux = router_aux_loss(probs, experts, E)
        capacity = max(1, int(T * k * cfg.capacity_factor / E))
        E_loc = w1.shape[0]
        tp_index = jax.lax.axis_index(tp)
        e_offset = (tp_index * E_loc).astype(jnp.int32)
        y, dropped = _local_expert_pass(
            xt, gates, experts, w1, w3, w2, capacity, e_offset, E
        )
        if shared:
            sw1, sw3, sw2 = shared
            h = jnp.einsum("td,dh->th", xt, sw1)
            g = jax.nn.silu(jnp.einsum("td,dh->th", xt, sw3))
            y = y + jnp.einsum("th,hd->td", h * g, sw2)
        # one all-reduce merges expert contributions + shared TP partials
        y = jax.lax.psum(y, tp)
        aux = jax.lax.pmean(aux, tp)
        drop_frac = dropped.astype(jnp.float32) / (T * k)
        return (y.reshape(Bl, Sl, d), aux,
                jax.lax.pmax(drop_frac, tp))

    if mesh is None:
        # single-host fallback: one shard holding all experts
        def inner_local(x_loc, router_w, w1, w3, w2, *shared):
            Bl, Sl, _ = x_loc.shape
            T = Bl * Sl
            xt = x_loc.reshape(T, d)
            gates, experts, probs = _route(xt, router_w, k)
            aux = router_aux_loss(probs, experts, E)
            capacity = max(1, int(T * k * cfg.capacity_factor / E))
            y, dropped = _local_expert_pass(
                xt, gates, experts, w1, w3, w2, capacity, jnp.int32(0), E
            )
            if shared:
                sw1, sw3, sw2 = shared
                h = jnp.einsum("td,dh->th", xt, sw1)
                g = jax.nn.silu(jnp.einsum("td,dh->th", xt, sw3))
                y = y + jnp.einsum("th,hd->td", h * g, sw2)
            return (y.reshape(Bl, Sl, d), aux,
                    dropped.astype(jnp.float32) / (T * k))

        args = [x, params["router"], params["experts_w1"],
                params["experts_w3"], params["experts_w2"]]
        if "shared_w1" in params:
            args += [params["shared_w1"], params["shared_w3"],
                     params["shared_w2"]]
        return inner_local(*args)

    assert E % rules.tp_size == 0, "expert count must divide the model axis"
    e_spec = P(tp, None, None)
    in_specs = [P(dp, None, None), P(None, None), e_spec, e_spec, e_spec]
    args = [x, params["router"], params["experts_w1"], params["experts_w3"],
            params["experts_w2"]]
    if "shared_w1" in params:
        hs_ok = params["shared_w1"].shape[1] % rules.tp_size == 0
        s_col = P(None, tp) if hs_ok else P(None, None)
        s_row = P(tp, None) if hs_ok else P(None, None)
        in_specs += [s_col, s_col, s_row]
        args += [params["shared_w1"], params["shared_w3"],
                 params["shared_w2"]]
    out_specs = (P(dp, None, None), P(), P())
    fn = shard_map(
        inner, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
        check_vma=False,
    )
    return fn(*args)
