"""Decode caches: full KV, ring (windowed) KV, MLA compressed, SSM states.

Cache layout is per-*segment* (see config.segments): every leaf carries a
leading ``L_seg`` axis so lax.scan over a segment's layers maps over the
cache in lockstep.  A single scalar ``length`` (tokens written so far) is
carried globally — slot occupancy and absolute positions are derived from
it, which keeps ring-buffer bookkeeping out of the cache pytree.

Ring semantics (windowed attention): slot s of a T-slot cache holds the
most recent position p < length with p % T == s.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_segment_cache", "ring_positions", "write_token"]


def ring_positions(length, num_slots: int):
    """Absolute position per cache slot (-1 if never written).

    length: () int32 tokens written so far.  Works for full caches too
    (where length <= num_slots always and slot s holds position s).
    """
    s = jnp.arange(num_slots, dtype=jnp.int32)
    last = length - 1 - ((length - 1 - s) % num_slots)
    return jnp.where(s < jnp.minimum(length, num_slots),
                     jnp.where(length <= num_slots, s, last), -1)


def init_segment_cache(kind, n_layers: int, batch: int, cache_len: int,
                       cfg, dtype):
    """Zero cache for one segment.  kind = (mixer_kind, ffn_kind)."""
    mixer = kind[0]
    L, B = n_layers, batch
    Dh = cfg.resolved_head_dim
    if mixer in ("full", "swa", "local"):
        T = cache_len if mixer == "full" else min(cfg.window, cache_len)
        return {
            "k": jnp.zeros((L, B, T, cfg.num_kv_heads, Dh), dtype),
            "v": jnp.zeros((L, B, T, cfg.num_kv_heads, Dh), dtype),
        }
    if mixer == "mla":
        return {
            "ckv": jnp.zeros((L, B, cache_len, cfg.mla_kv_lora), dtype),
            "krope": jnp.zeros((L, B, cache_len, cfg.mla_rope_dim), dtype),
        }
    if mixer == "rwkv6":
        H, D = cfg.num_heads, cfg.rwkv_head_dim
        return {
            "state": jnp.zeros((L, B, H, D, D), jnp.float32),
            "prev_mix": jnp.zeros((L, B, cfg.d_model), dtype),
            "prev_cm": jnp.zeros((L, B, cfg.d_model), dtype),
        }
    if mixer == "rglru":
        return {
            "h": jnp.zeros((L, B, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros(
                (L, B, cfg.conv_width - 1, cfg.lru_width), dtype
            ),
        }
    raise ValueError(f"unknown mixer kind {mixer!r}")


def write_token(cache_kv, new_kv, length):
    """Write one token's (B, 1, ...) entry at ring slot length % T."""
    T = cache_kv.shape[1]
    slot = (length % T).astype(jnp.int32)
    return jax.lax.dynamic_update_slice_in_dim(cache_kv, new_kv, slot, axis=1)
