"""Unified decoder-only model covering all assigned architecture families.

One parameterized stack: GQA/MQA attention (full/SWA/local), MLA, RWKV6 and
RG-LRU mixers, dense/SwiGLU/GELU/channel-mix/MoE FFNs, token or
stub-embedding frontends.  Layers are grouped into homogeneous *segments*
(config.segments) and each segment runs under ``lax.scan`` over stacked
parameters — HLO size is O(#segments), not O(depth), so an 80-layer model
lowers as fast as a 2-layer one.  ``cfg.remat`` wraps each block in
jax.checkpoint for training.

Three entry points (what launch/dryrun lowers):
  * ``loss_fn``      — training objective (next-token CE + MoE aux)
  * ``prefill``      — full-sequence pass building a decode cache
  * ``decode_step``  — one token against the cache (serve_step)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import MeshRules
from .attention import attend, decode_attend
from .config import ModelConfig, block_kinds, segments
from .kvcache import init_segment_cache, ring_positions, write_token
from .layers import apply_rope, gelu_mlp, rms_norm, rotary, swiglu
from .moe import moe_ffn
from .ssm import rglru_block, rwkv6_channelmix, rwkv6_mix

__all__ = [
    "init_params", "abstract_params", "count_params", "forward", "loss_fn",
    "prefill", "decode_step", "init_cache",
]


# ============================================================ initialization
def _dense_ffn_shapes(cfg: ModelConfig, ffn_kind: str):
    d = cfg.d_model
    if ffn_kind == "dense_big":
        ff = cfg.moe_dense_d_ff or cfg.d_ff
    else:
        ff = cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {"w1": (d, ff), "w3": (d, ff), "w2": (ff, d)}
    return {"w1": (d, ff), "w2": (ff, d)}


def _block_param_shapes(cfg: ModelConfig, kind) -> dict:
    mixer, ffn = kind
    d = cfg.d_model
    Dh = cfg.resolved_head_dim
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    shapes: dict[str, tuple] = {"ln1": (d,), "ln2": (d,)}
    if mixer in ("full", "swa", "local"):
        shapes.update(
            wq=(d, H * Dh), wk=(d, KVH * Dh), wv=(d, KVH * Dh),
            wo=(H * Dh, d),
        )
        if cfg.qkv_bias:
            shapes.update(bq=(H * Dh,), bk=(KVH * Dh,), bv=(KVH * Dh,))
    elif mixer == "mla":
        qk = cfg.mla_nope_dim + cfg.mla_rope_dim
        shapes.update(
            wq_mla=(d, H * qk),
            wkv_a=(d, cfg.mla_kv_lora + cfg.mla_rope_dim),
            ln_kv=(cfg.mla_kv_lora,),
            wk_up=(cfg.mla_kv_lora, H * cfg.mla_nope_dim),
            wv_up=(cfg.mla_kv_lora, H * cfg.mla_v_dim),
            wo=(H * cfg.mla_v_dim, d),
        )
    elif mixer == "rwkv6":
        HD = H * cfg.rwkv_head_dim
        lora = 64
        shapes.update(
            rwkv_mu_r=(d,), rwkv_mu_k=(d,), rwkv_mu_v=(d,), rwkv_mu_g=(d,),
            rwkv_mu_w=(d,),
            rwkv_w_r=(d, HD), rwkv_w_k=(d, HD), rwkv_w_v=(d, HD),
            rwkv_w_g=(d, HD), rwkv_w_o=(HD, d),
            rwkv_w_decay_a=(d, lora), rwkv_w_decay_b=(lora, HD),
            rwkv_w0=(HD,), rwkv_u=(H, cfg.rwkv_head_dim),
        )
    elif mixer == "rglru":
        W = cfg.lru_width
        shapes.update(
            lru_in=(d, W), lru_gate=(d, W),
            lru_conv=(cfg.conv_width, W), lru_conv_bias=(W,),
            lru_wr=(W,), lru_wi=(W,), lru_br=(W,), lru_bi=(W,),
            lru_lambda=(W,), lru_out=(W, d),
        )
    else:
        raise ValueError(mixer)

    if ffn in ("dense", "dense_big"):
        shapes.update(_dense_ffn_shapes(cfg, ffn))
    elif ffn == "moe":
        E, h = cfg.moe_num_experts, cfg.moe_d_ff
        shapes.update(
            router=(cfg.d_model, E),
            experts_w1=(E, d, h), experts_w3=(E, d, h),
            experts_w2=(E, h, d),
        )
        if cfg.moe_num_shared:
            hs = cfg.moe_num_shared * h
            shapes.update(shared_w1=(d, hs), shared_w3=(d, hs),
                          shared_w2=(hs, d))
    elif ffn == "channelmix":
        ff = cfg.d_ff
        shapes.update(
            rwkv_mu_ck=(d,), rwkv_mu_cr=(d,),
            rwkv_w_ck=(d, ff), rwkv_w_cr=(d, d), rwkv_w_cv=(ff, d),
        )
    else:
        raise ValueError(ffn)
    return shapes


def _init_leaf(key, name, shape, cfg):
    dt = cfg.dtype
    if len(shape) <= 1 or name.startswith(("ln", "rwkv_mu", "lru_w",
                                           "lru_b", "lru_lambda")):
        if name == "lru_lambda":
            return jnp.linspace(1.0, 4.0, shape[0], dtype=dt)
        return jnp.zeros(shape, dt)
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = 0.02 if fan_in <= 0 else min(0.02, fan_in**-0.5)
    return (std * jax.random.truncated_normal(
        key, -3, 3, shape, jnp.float32)).astype(dt)


def init_params(key, cfg: ModelConfig):
    """Real initialization (smoke tests / examples).  Dry-run uses
    abstract_params (no allocation)."""
    segs = segments(cfg)
    params: dict[str, Any] = {}
    k_embed, k_head, key = jax.random.split(key, 3)
    params["embed"] = (
        0.02 * jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                 jnp.float32)
    ).astype(cfg.dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    params["lm_head"] = (
        0.02 * jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size),
                                 jnp.float32)
    ).astype(cfg.dtype)
    seg_params = []
    for kind, n in segs:
        shapes = _block_param_shapes(cfg, kind)
        layer = {}
        for name, shape in sorted(shapes.items()):
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            layer[name] = jnp.stack(
                [_init_leaf(keys[i], name, shape, cfg) for i in range(n)]
            )
        seg_params.append(layer)
    params["segments"] = seg_params
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model * 2 + cfg.d_model
    for kind, n in segments(cfg):
        shapes = _block_param_shapes(cfg, kind)
        for name, shape in shapes.items():
            size = 1
            for s in shape:
                size *= s
            if active_only and name.startswith("experts_"):
                size = size * cfg.moe_top_k // cfg.moe_num_experts
            total += n * size
    return total


# ================================================================== blocks
def _gqa_mixer(p, h, cfg, rules, window, mode, cache, length):
    B, S, d = h.shape
    Dh = cfg.resolved_head_dim
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    q = h @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = h @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = h @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KVH, Dh)
    v = v.reshape(B, S, KVH, Dh)
    offset = 0 if mode != "decode" else length
    pos = offset + jnp.arange(S, dtype=jnp.int32)
    cos, sin = rotary(pos, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if mode == "decode":
        kc = write_token(cache["k"], k, length)
        vc = write_token(cache["v"], v, length)
        kc = rules.constrain(kc, rules.batch_spec(), rules.tp_axis, None,
                             None)
        vc = rules.constrain(vc, rules.batch_spec(), rules.tp_axis, None,
                             None)
        cpos = ring_positions(length + 1, kc.shape[1])
        out = decode_attend(q, kc, vc, cpos, length, window=window)
        new_cache = {"k": kc, "v": vc}
    else:
        flash = getattr(cfg, "flash_vjp", False)
        ntp = rules.tp_size
        use_cp = (
            getattr(cfg, "seq_parallel_prefill", False)
            and window and rules.mesh is not None
            and S % ntp == 0 and S >= 2 * ntp
            and B % rules.dp_size == 0
        )
        if use_cp:
            from .attention import swa_attend_cp

            out = swa_attend_cp(q, k, v, window=window, rules=rules,
                                flash_vjp=flash)
        else:
            out = attend(q, k, v, window=window, flash_vjp=flash)
        if mode == "prefill":
            T = cache_len = cache["k"].shape[1]
            if window and S >= T:
                kc = jnp.roll(k[:, S - T:], S % T, axis=1)
                vc = jnp.roll(v[:, S - T:], S % T, axis=1)
            else:
                pad = T - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = {"k": kc.astype(cfg.dtype),
                         "v": vc.astype(cfg.dtype)}
    y = out.reshape(B, S, H * Dh) @ p["wo"]
    return y, new_cache


def _mla_mixer(p, h, cfg, rules, mode, cache, length):
    """Multi-head latent attention (DeepSeek-V2).  Baseline decode expands
    the compressed cache per step (absorbed variant: see §Perf)."""
    B, S, d = h.shape
    H = cfg.num_heads
    nope, rope_d = cfg.mla_nope_dim, cfg.mla_rope_dim
    vdim, lora = cfg.mla_v_dim, cfg.mla_kv_lora
    q = (h @ p["wq_mla"]).reshape(B, S, H, nope + rope_d)
    offset = 0 if mode != "decode" else length
    pos = offset + jnp.arange(S, dtype=jnp.int32)
    cos, sin = rotary(pos, rope_d, cfg.rope_theta)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = h @ p["wkv_a"]  # (B, S, lora + rope_d)
    c, k_rope = ckv[..., :lora], ckv[..., lora:]
    c = rms_norm(c, p["ln_kv"])
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rope)

    def expand(c_all, kr_all):
        k_nope = jnp.einsum("btl,lhn->bthn", c_all,
                            p["wk_up"].reshape(lora, H, nope))
        v = jnp.einsum("btl,lhn->bthn", c_all,
                       p["wv_up"].reshape(lora, H, vdim))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all,
                                      k_nope.shape[:3] + (rope_d,))],
            axis=-1,
        )
        return k, v

    new_cache = None
    if mode == "decode":
        cc = write_token(cache["ckv"], c, length)
        krc = write_token(cache["krope"], k_rope[:, :, 0, :], length)
        cc = rules.constrain(cc, rules.batch_spec(), rules.tp_axis, None)
        krc = rules.constrain(krc, rules.batch_spec(), rules.tp_axis, None)
        cpos = ring_positions(length + 1, cc.shape[1])
        if getattr(cfg, "mla_absorb", False):
            # absorbed decode (beyond-paper perf variant): fold W_UK into
            # q and W_UV into the output so attention runs in the
            # compressed c-space — per step O(T*(lora+rope)) instead of
            # O(T*H*(nope+vdim)) cache decompression.
            scale = (nope + rope_d) ** -0.5
            q_c = jnp.einsum(
                "bshn,lhn->bshl", q_nope.astype(jnp.float32),
                p["wk_up"].reshape(lora, H, nope).astype(jnp.float32),
            )  # (B, 1, H, lora)
            s = jnp.einsum("bshl,btl->bhst", q_c,
                           cc.astype(jnp.float32))[:, :, 0]
            s = s + jnp.einsum(
                "bshr,btr->bhst", q_rope.astype(jnp.float32),
                krc.astype(jnp.float32))[:, :, 0]
            s = s * scale  # (B, H, T)
            allow = (cpos <= length) & (cpos >= 0)
            s = jnp.where(allow[None, None], s, -1e30)
            pr = jax.nn.softmax(s, axis=-1)
            o_c = jnp.einsum("bht,btl->bhl", pr, cc.astype(jnp.float32))
            out = jnp.einsum(
                "bhl,lhn->bhn", o_c,
                p["wv_up"].reshape(lora, H, vdim).astype(jnp.float32),
            ).astype(h.dtype)[:, None]  # (B, 1, H, vdim)
        else:
            k_all, v_all = expand(cc, krc[:, :, None, :])
            out = decode_attend(q, k_all, v_all, cpos, length)
        new_cache = {"ckv": cc, "krope": krc}
    else:
        k_all, v_all = expand(c, k_rope)
        out = attend(q, k_all, v_all)
        if mode == "prefill":
            T = cache["ckv"].shape[1]
            pad = T - S
            new_cache = {
                "ckv": jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(
                    cfg.dtype),
                "krope": jnp.pad(
                    k_rope[:, :, 0, :], ((0, 0), (0, pad), (0, 0))
                ).astype(cfg.dtype),
            }
    y = out.reshape(B, S, H * vdim) @ p["wo"]
    return y, new_cache


def _block_batch_spec(cfg, rules, x, mixer):
    """Activation batch sharding for this block.

    rwkv6 blocks in batch-parallel mode — and every block in fsdp_only
    mode — spread the batch over EVERY mesh axis (full batch sharding
    needs no TP activation psums at all; rwkv additionally because its
    head count rarely divides tp) — when the batch divides the full
    mesh.  Everything else: batch over dp axes.
    """
    if (
        (getattr(cfg, "fsdp_only", False)
         or (mixer == "rwkv6"
             and getattr(cfg, "rwkv_batch_parallel", False)))
        and rules.mesh is not None
    ):
        total = rules.dp_size * rules.tp_size
        if x.shape[0] % total == 0 and x.shape[0] >= total:
            return rules.dp_axes + (rules.tp_axis,)
    return rules.batch_spec()


def _seq_spec(cfg, rules, x, mixer, mode):
    """Sequence (context-parallel) sharding for windowed-attention prefill:
    S over the model axis; attention only needs a window-sized KV halo
    (XLA lowers the banded slices to collective-permute)."""
    if (
        getattr(cfg, "seq_parallel_prefill", False)
        and mode in ("train", "prefill")
        and mixer in ("swa", "local")
        and rules.mesh is not None
        and x.shape[1] % rules.tp_size == 0
        and x.shape[1] >= 2 * rules.tp_size
    ):
        return rules.tp_axis
    return None


def _apply_block(kind, p, x, cfg, rules, mode, cache, length):
    """One residual block.  Returns (x, new_cache, aux)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    bspec = _block_batch_spec(cfg, rules, x, mixer)
    sspec = _seq_spec(cfg, rules, x, mixer, mode)
    x = rules.constrain(x, bspec, sspec, None)
    h = rms_norm(x, p["ln1"])
    window = cfg.window if mixer in ("swa", "local") else 0
    if mixer in ("full", "swa", "local"):
        y, new_cache = _gqa_mixer(p, h, cfg, rules, window, mode, cache,
                                  length)
    elif mixer == "mla":
        y, new_cache = _mla_mixer(p, h, cfg, rules, mode, cache, length)
    elif mixer == "rwkv6":
        state = (cache["state"], cache["prev_mix"]) if mode == "decode" \
            else (None, None)
        y, (st, prev) = rwkv6_mix(p, h, cfg, state=state[0],
                                  prev_x=state[1])
        new_cache = {"state": st, "prev_mix": h[:, -1]}
    elif mixer == "rglru":
        state = (cache["h"], cache["conv"]) if mode == "decode" else None
        y, (hs, conv) = rglru_block(p, h, cfg, state=state)
        new_cache = {"h": hs, "conv": conv}
    else:
        raise ValueError(mixer)
    x = x + y
    x = rules.constrain(x, bspec, sspec, None)

    h2 = rms_norm(x, p["ln2"])
    if ffn in ("dense", "dense_big"):
        if cfg.mlp_type == "swiglu":
            f = swiglu(h2, p["w1"], p["w3"], p["w2"])
        else:
            f = gelu_mlp(h2, p["w1"], p["w2"])
    elif ffn == "moe":
        f, aux_moe, _drop = moe_ffn(h2, p, cfg, rules)
        aux = aux + aux_moe
    elif ffn == "channelmix":
        prev = cache["prev_cm"] if mode == "decode" else None
        f, prev_cm = rwkv6_channelmix(p, h2, prev_x=prev)
        if new_cache is not None or mode in ("decode", "prefill"):
            new_cache = dict(new_cache or {})
            new_cache["prev_cm"] = prev_cm
    else:
        raise ValueError(ffn)
    x = x + f
    # keep bspec/sspec at block exit: consecutive same-kind blocks then
    # never reshard (rwkv segments stay batch-parallel end-to-end; the lm
    # head reshards once after the final block)
    x = rules.constrain(x, bspec, sspec, None)
    # rwkv prefill also needs channelmix prev state captured above
    return x, new_cache, aux


def _run_segments(params, x, cfg, rules, mode, caches, length):
    """Scan each homogeneous segment; returns (x, new_caches, aux_total)."""
    segs = segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, ((kind, n), p_seg) in enumerate(zip(segs, params["segments"])):
        cache_seg = caches[si] if caches is not None else None

        def body(carry, xs, _kind=kind):
            xc, aux = carry
            p_l = xs[0]
            c_l = xs[1] if len(xs) > 1 else None
            xc, nc, a = _apply_block(_kind, p_l, xc, cfg, rules, mode,
                                     c_l, length)
            return (xc, aux + a), nc

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        xs = (p_seg,) if cache_seg is None else (p_seg, cache_seg)
        (x, aux_total), nc_seg = jax.lax.scan(
            body, (x, aux_total), xs, length=n
        )
        new_caches.append(nc_seg)
    return x, new_caches, aux_total


# ============================================================== entry points
def _embed_in(params, cfg, rules, tokens=None, embeds=None):
    if cfg.frontend == "embeddings":
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]
    return rules.constrain(x, rules.batch_spec(), None, None)


def forward(params, cfg: ModelConfig, rules: MeshRules, tokens=None,
            embeds=None):
    """Training forward: logits for all positions + MoE aux loss."""
    x = _embed_in(params, cfg, rules, tokens, embeds)
    x, _, aux = _run_segments(params, x, cfg, rules, "train", None, None)
    # stage back to dp-only batch sharding before the head: a direct
    # (dp x tp)-batch -> d-sharded reshard makes XLA SPMD fall back to
    # full replication ("involuntary full rematerialization"); batch
    # all-gather along the model axis is the efficient path.
    x = rules.constrain(x, rules.batch_spec(), None, None)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = rules.constrain(logits, rules.batch_spec(), None, rules.tp_axis)
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, rules: MeshRules,
            aux_coef: float = 0.01):
    logits, aux = forward(
        params, cfg, rules,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + aux_coef * aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return [
        init_segment_cache(kind, n, batch, cache_len, cfg, cfg.dtype)
        for kind, n in segments(cfg)
    ]


def prefill(params, cfg: ModelConfig, rules: MeshRules, tokens=None,
            embeds=None, cache_len: int | None = None):
    """Full-sequence pass -> (last-position logits (B, V), cache, length)."""
    x = _embed_in(params, cfg, rules, tokens, embeds)
    B, S = x.shape[0], x.shape[1]
    cache_len = cache_len or S
    caches = init_cache(cfg, B, cache_len)
    x, new_caches, _ = _run_segments(params, x, cfg, rules, "prefill",
                                     caches, None)
    x = rules.constrain(x, rules.batch_spec(), None, None)
    x_last = rms_norm(x[:, -1], params["final_norm"])
    logits = x_last @ params["lm_head"]
    logits = rules.constrain(logits, rules.batch_spec(), rules.tp_axis)
    return logits, new_caches, jnp.asarray(S, jnp.int32)


def decode_step(params, caches, length, cfg: ModelConfig, rules: MeshRules,
                tokens=None, embeds=None):
    """One-token decode.  tokens: (B,) int32 (or embeds (B, d)).
    Returns (logits (B, V), new_caches, length + 1)."""
    if cfg.frontend == "embeddings":
        x = embeds[:, None, :].astype(cfg.dtype)
    else:
        x = params["embed"][tokens][:, None, :]
    x = rules.constrain(x, rules.batch_spec(), None, None)
    x, new_caches, _ = _run_segments(params, x, cfg, rules, "decode",
                                     caches, length)
    x_last = rms_norm(x[:, 0], params["final_norm"])
    logits = x_last @ params["lm_head"]
    logits = rules.constrain(logits, rules.batch_spec(), rules.tp_axis)
    return logits, new_caches, length + 1
