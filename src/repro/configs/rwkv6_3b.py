"""RWKV6 (Finch) 3B: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    attention="none", mixer="rwkv6", rwkv_head_dim=64,
    paper_ref="arXiv:2404.05892",
)
