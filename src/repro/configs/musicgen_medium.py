"""MusicGen-medium: decoder-only over EnCodec tokens; MHA, GELU MLP.
Frontend (EnCodec codebook embedding/interleaving) is a STUB: input_specs
provides precomputed frame embeddings.  [arXiv:2306.05284; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    attention="full", mlp_type="gelu", frontend="embeddings",
    paper_ref="arXiv:2306.05284",
)
