"""DeepSeek-V2-Lite 16B: MLA (kv_lora=512, rope 64) + MoE 64 routed top-6,
2 shared experts, first layer dense.  [arXiv:2405.04434; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    attention="mla", mla_kv_lora=512, mla_rope_dim=64, mla_nope_dim=128,
    mla_v_dim=128, head_dim=192,
    moe_num_experts=64, moe_top_k=6, moe_d_ff=1408, moe_num_shared=2,
    moe_first_dense=1, moe_dense_d_ff=10944,
    paper_ref="arXiv:2405.04434",
)
