"""RecurrentGemma-9B (Griffin): RG-LRU + local attention 2:1, MQA kv=1.
[arXiv:2402.19427; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    attention="local", window=2048, mixer="rglru_hybrid", attn_every=3,
    lru_width=4096, conv_width=4,
    paper_ref="arXiv:2402.19427",
)
