"""Per-(arch, shape) performance presets — the §Perf-validated variants.

The baseline sweep (results/dryrun, variant=baseline) runs every cell with
the generic TP/FSDP sharding rules.  These presets encode the optimizations
validated in EXPERIMENTS.md §Perf, keyed by (arch, shape-kind); the
launcher (`dryrun --optimized`, `train --optimized`) applies them with
dataclasses.replace.  They are deliberately *job-kind-dependent*: e.g.
fsdp_only requires the global batch to cover the full mesh (train_4k's 256
on 256 chips) and would be wrong for decode_32k's batch of 128.
"""
from __future__ import annotations

import dataclasses

__all__ = ["apply_preset"]

_DENSE_FSDP_OK = {
    # train_4k cells where global_batch (256) covers the 16x16 mesh and
    # every block weight has a full-mesh-divisible dim
    "deepseek-7b", "qwen2.5-32b", "qwen2-72b", "h2o-danube-3-4b",
    "musicgen-medium", "llava-next-34b",
}


def apply_preset(cfg, shape):
    """Return cfg with the validated perf preset for this cell applied."""
    kv = {}
    # flash backward: strictly better for any training cell with attention
    if shape.kind == "train" and cfg.mixer in ("attn", "rglru_hybrid"):
        kv["flash_vjp"] = True
    # chunk-parallel rwkv recurrence: train + prefill
    if cfg.mixer == "rwkv6" and shape.kind != "decode":
        kv["rwkv_chunk"] = 32
        # batch-parallel rwkv blocks: full-mesh batch sharding when the
        # batch covers the mesh (train_4k), else dp-batch + FSDP weights —
        # either way the per-projection TP psums disappear.
        kv["rwkv_batch_parallel"] = True
    # FSDP-only (ZeRO-3): dense train cells whose batch covers the mesh
    if (
        shape.kind == "train"
        and cfg.name in _DENSE_FSDP_OK
        and shape.global_batch % 256 == 0
    ):
        kv["fsdp_only"] = True
    # gradient-accumulation microbatches: cells whose per-device
    # activation/remat footprint exceeds 16 GB HBM at full batch
    # NOTE: microbatching is incompatible with fsdp_only (per-microbatch
    # batch must still cover the full mesh), so the dense-FSDP cells rely
    # on ZeRO-3 sharding alone.
    _MICRO = {"qwen3-moe-235b-a22b": 8, "recurrentgemma-9b": 8,
              "deepseek-v2-lite-16b": 4}
    if shape.kind == "train" and cfg.name in _MICRO \
            and not kv.get("fsdp_only"):
        kv["train_microbatch"] = _MICRO[cfg.name]
    # MLA absorbed decode: attention in compressed-KV space
    if shape.kind == "decode" and cfg.attention == "mla":
        kv["mla_absorb"] = True
    # context-parallel prefill for windowed attention
    if (
        shape.kind == "prefill"
        and cfg.attention in ("swa", "local")
        and cfg.mixer == "attn"
        and shape.seq_len % 16 == 0
    ):
        kv["seq_parallel_prefill"] = True
    return dataclasses.replace(cfg, **kv) if kv else cfg
