"""DeepSeek-7B: llama-arch dense, MHA (kv=32).  [arXiv:2401.02954; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400, head_dim=128,
    attention="full", rope_theta=10_000.0,
    paper_ref="arXiv:2401.02954",
)
