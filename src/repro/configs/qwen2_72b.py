"""Qwen2-72B: dense, GQA kv=8, QKV bias.  [arXiv:2407.10671; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    attention="full", qkv_bias=True, rope_theta=1_000_000.0,
    paper_ref="arXiv:2407.10671",
)
