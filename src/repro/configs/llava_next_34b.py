"""LLaVA-NeXT-34B: Yi-34B-class backbone, GQA kv=8; anyres vision tiling
is a STUB (input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-*; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    attention="full", frontend="embeddings", rope_theta=5_000_000.0,
    paper_ref="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
