"""H2O-Danube3-4B: llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    attention="swa", window=4096, rope_theta=10_000.0,
    paper_ref="arXiv:2401.16818",
)
