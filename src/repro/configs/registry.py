"""Architecture registry: full configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCH_IDS = (
    "qwen2_5_32b",
    "deepseek_7b",
    "h2o_danube3_4b",
    "qwen2_72b",
    "rwkv6_3b",
    "musicgen_medium",
    "recurrentgemma_9b",
    "deepseek_v2_lite",
    "qwen3_moe_235b",
    "llava_next_34b",
    "logreg_paper",  # the paper's own model (see configs/logreg_paper.py)
)


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small dims, few layers/experts, runnable
    on one CPU in a test.  Preserves mixer pattern / FFN kind / frontend."""
    cfg = get_config(name)
    heads = 4
    kv = 2 if cfg.num_kv_heads < cfg.num_heads else heads
    layers = 3 if cfg.mixer == "rglru_hybrid" else 2
    if cfg.moe_first_dense:
        layers = max(layers, cfg.moe_first_dense + 1)
    updates = dict(
        name=cfg.name + "_smoke",
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=min(cfg.window, 32) if cfg.window else 0,
        lru_width=64 if cfg.lru_width else 0,
        rwkv_head_dim=16,
        remat=False,
    )
    if cfg.moe_num_experts:
        updates.update(
            moe_num_experts=8, moe_top_k=2, moe_d_ff=32,
            moe_num_shared=min(cfg.moe_num_shared, 1),
            moe_dense_d_ff=128 if cfg.moe_first_dense else 0,
        )
    if cfg.attention == "mla":
        updates.update(
            mla_kv_lora=32, mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16,
            head_dim=24,  # nope + rope for q
        )
    return dataclasses.replace(cfg, **updates)
