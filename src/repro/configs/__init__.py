from .registry import ARCH_IDS, get_config, smoke_config

__all__ = ["ARCH_IDS", "get_config", "smoke_config"]
