"""Qwen2.5-32B: dense, GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-*; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064, head_dim=128,
    attention="full", qkv_bias=True, rope_theta=1_000_000.0,
    paper_ref="hf:Qwen/Qwen2.5-0.5B",
)
