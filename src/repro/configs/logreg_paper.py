"""The paper's own model: L2-regularized logistic regression.

Not a transformer — exposed through the same registry so `--arch
logreg_paper` selects the paper pipeline in launch/train.py.  The four
evaluation studies are in repro.data.datasets.
"""
from ..models.config import ModelConfig

# Encoded as a degenerate ModelConfig for registry uniformity; the logreg
# driver reads d (features) from the dataset, not from here.
CONFIG = ModelConfig(
    name="logreg-paper", family="logreg",
    num_layers=0, d_model=84, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=2, attention="none",
    paper_ref="DOI 10.1371/journal.pone.0156479",
)
