"""Checkpoint/restart: atomic, retain-k, optional async writer thread.

npz-per-step with flattened pytree paths; writes go to a temp file and are
renamed into place (crash-safe).  ``CheckpointManager`` keeps the newest k
checkpoints, restores the latest on resume, and can hand writes to a
background thread so the train loop never blocks on disk (async writer
drains on close()).
"""
from __future__ import annotations

import os
import queue
import re
import threading

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]

_SEP = "||"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":
            # ml_dtypes (bfloat16 etc.) do not survive npz round-trips;
            # store as f32 (lossless for bf16) — load_pytree casts back
            # to the template dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_pytree(tree, path: str):
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_pytree(template, path: str):
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path, allow_pickle=False) as data:
        flat = dict(data)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path_k, leaf in leaves:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k
        )
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, retain: int = 3,
                 async_writes: bool = False):
        self.dir = directory
        self.retain = retain
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue | None = None
        self._thread = None
        if async_writes:
            self._q = queue.Queue()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, path = item
            save_pytree(tree, path)
            self._gc()

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def save(self, step: int, tree):
        path = self._path(step)
        if self._q is not None:
            # device->host copy happens here so the step can proceed
            host = jax.tree_util.tree_map(np.asarray, tree)
            self._q.put((host, path))
        else:
            save_pytree(tree, path)
            self._gc()

    def steps(self):
        pat = re.compile(r"ckpt_(\d+)\.npz$")
        out = []
        for f in os.listdir(self.dir):
            m = pat.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return load_pytree(template, self._path(step)), step

    def _gc(self):
        for s in self.steps()[: -self.retain]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def close(self):
        if self._q is not None:
            self._q.put(None)
            self._thread.join()
