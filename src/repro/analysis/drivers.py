"""Driver specs: which secure graphs the gate traces, and their taints.

Each :class:`DriverSpec` names one secure driver round graph, builds its
closed jaxpr on tiny synthetic shapes (``jax.make_jaxpr`` — no kernel
ever executes, Pallas included), and labels every flat input with its
taint.  The five ISSUE-mandated drivers map to eight specs:

* ``secure_fit_fused``   — ``SecureFitDriver.step``'s fused round
  (``newton._fused_secure_iteration``).
* ``coordinator_fused``  — the same graph in ``StudyCoordinator.step``
  fused trim (``include_count=True``, the coordinator wire tree).
* ``secure_fit_scan``    — ``rounds="scan"``'s whole-block graph
  (``scanfit.fit_scan_block``), shared by driver and coordinator.
* ``selection_scan``     — the CV sweep's multi-config scan body
  (``selection.path._cv_sweep_block``).
* ``secure_psum_replicated`` / ``secure_psum_sharded`` /
  ``secure_psum_tile`` — the 1D SPMD wire in all reveal/out modes,
  traced through ``shard_map`` over an **AbstractMesh** (no devices
  needed; the mesh's axis sizes feed the collective taint rules).
* ``secure_psum_2d``     — the (pod, share) mesh with the distributed
  Lagrange reveal.

Fused specs trace twice — ``protect="both"`` (everything shared) and
``protect="gradient"`` (the paper's pragmatic mode, exercising the
``declassify_sum`` plaintext-aggregation annotation).

Every spec's graph routes through the ONE
:class:`repro.core.collective.SecureCollective` chain, so the named
boundary pjits the taint rules key on (``_protect_flat`` /
``_reveal_flat`` / ``_distributed_reveal`` / ``declassify_sum``) are the
same four call sites the runtime ledger hooks and the byte telemetry
account — certifying a driver here certifies the only chain it can use.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .taint import PUBLIC, SECRET

__all__ = ["DriverSpec", "all_driver_specs", "toy_parts"]


@dataclasses.dataclass(frozen=True)
class DriverSpec:
    """One traced driver graph + the taint labels of its flat inputs."""

    name: str
    build: Callable  # () -> (closed_jaxpr, flat_in_taints)
    threshold: int
    # mesh axis sizes known OUTSIDE any shard_map in the traced graph
    # (shard_map eqns push their own mesh's sizes during the walk)
    axis_sizes: dict = dataclasses.field(default_factory=dict)
    # runnable form for the RUNTIME audit (``python -m repro.obs audit``):
    # () -> None, executes the same driver graph on the same toy shapes
    # so the privacy ledger's recorded counts can be reconciled against
    # the static census of the built jaxpr.  None: spec is trace-only.
    runner: Callable | None = None
    # real devices the runner needs (psum specs trace on an AbstractMesh
    # but execute on a concrete one)
    min_devices: int = 1


def toy_parts(num_parts: int = 3, n: int = 8, d: int = 4):
    """Tiny deterministic partitions (no rng: specs must be stable)."""
    parts = []
    for j in range(num_parts):
        base = np.arange(n * d, dtype=np.float64).reshape(n, d)
        X = np.tanh((base + j) / (n * d))
        y = ((base.sum(axis=1) + j) % 2).astype(np.float64)
        parts.append((jnp.asarray(X), jnp.asarray(y)))
    return parts


def _aggregator():
    from ..core.collective import SecureCollective

    return SecureCollective(backend="pallas")


def _packed(num_parts=3, n=8, d=4):
    from ..core.batched_summaries import pack_partitions

    return pack_partitions(toy_parts(num_parts, n, d))


def _fused_spec(name: str, protect: str, include_count: bool):
    def build():
        from ..core.newton import _fused_secure_iteration

        agg = _aggregator()
        packed = _packed()
        beta = jnp.zeros((packed.dim,), jnp.float64)
        key = jax.random.PRNGKey(0)

        def fn(beta, key, X, X32, y, counts):
            return _fused_secure_iteration(
                beta, key, X, X32, y, counts, 1.0, agg, protect, 0.0,
                True, points=None, include_count=include_count,
                summaries_backend="pallas",
            )

        closed = jax.make_jaxpr(fn)(
            beta, key, packed.X, packed.X32, packed.y, packed.counts
        )
        taints = [PUBLIC, PUBLIC, SECRET, SECRET, SECRET, SECRET]
        return closed, taints

    def runner():
        from ..core.newton import _fused_secure_iteration

        agg = _aggregator()
        packed = _packed()
        beta = jnp.zeros((packed.dim,), jnp.float64)
        out = _fused_secure_iteration(
            beta, jax.random.PRNGKey(0), packed.X, packed.X32, packed.y,
            packed.counts, 1.0, agg, protect, 0.0, True, points=None,
            include_count=include_count, summaries_backend="pallas",
        )
        jax.block_until_ready(out)

    return DriverSpec(name=name, build=build, runner=runner,
                      threshold=_aggregator().scheme.threshold)


def _scan_spec(name: str, protect: str, include_count: bool):
    def build():
        from ..core.scanfit import fit_scan_block

        agg = _aggregator()
        packed = _packed()
        beta = jnp.zeros((packed.dim,), jnp.float64)
        key = jax.random.PRNGKey(0)

        def fn(beta, obj_prev, conv, iters, key, rbase,
               X, X32, y, counts):
            return fit_scan_block(
                beta, obj_prev, conv, iters, key, rbase,
                X, X32, y, counts, 1.0,
                agg=agg, protect=protect, l1=0.0, tol=1e-10,
                interpret=True, points=None,
                include_count=include_count,
                summaries_backend="pallas", num_rounds=3,
                num_parts=packed.num_institutions, max_rounds=3,
            )

        closed = jax.make_jaxpr(fn)(
            beta, jnp.asarray(np.inf), jnp.asarray(False),
            jnp.zeros((), jnp.int32), key, jnp.zeros((), jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts,
        )
        taints = [PUBLIC] * 6 + [SECRET] * 4
        return closed, taints

    def runner():
        from ..core.scanfit import fit_scan_block

        agg = _aggregator()
        packed = _packed()
        beta = jnp.zeros((packed.dim,), jnp.float64)
        out = fit_scan_block(
            beta, jnp.asarray(np.inf), jnp.asarray(False),
            jnp.zeros((), jnp.int32), jax.random.PRNGKey(0),
            jnp.zeros((), jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts, 1.0,
            agg=agg, protect=protect, l1=0.0, tol=1e-10,
            interpret=True, points=None, include_count=include_count,
            summaries_backend="pallas", num_rounds=3,
            num_parts=packed.num_institutions, max_rounds=3,
        )
        jax.block_until_ready(out)

    return DriverSpec(name=name, build=build, runner=runner,
                      threshold=_aggregator().scheme.threshold)


def _selection_spec(name: str, protect: str):
    def build():
        from ..selection.folds import assign_folds, pack_fold_ids
        from ..selection.path import _cv_sweep_block

        agg = _aggregator()
        num_parts, n, d, num_folds = 3, 8, 4, 2
        packed = _packed(num_parts, n, d)
        fold_parts = [
            assign_folds(n, num_folds, j, 0) for j in range(num_parts)
        ]
        fold_ids = pack_fold_ids(fold_parts, packed.X.shape[1])
        lam_grid = (1.0, 0.5)
        cfg = len(lam_grid) * num_folds
        lams = jnp.asarray(np.repeat(lam_grid, num_folds), jnp.float64)
        fold_of = jnp.asarray(
            np.tile(np.arange(num_folds, dtype=np.int32), len(lam_grid))
        )
        key = jax.random.PRNGKey(0)

        def fn(betas, obj_prev, conv, iters, vdev, vcorr, vcnt, key,
               rbase, X, X32, y, counts, fold_ids, fold_of, lams):
            return _cv_sweep_block(
                betas, obj_prev, conv, iters, vdev, vcorr, vcnt, key,
                rbase, X, X32, y, counts, fold_ids, fold_of, lams,
                agg=agg, protect=protect, l1=0.0, tol=1e-10,
                interpret=True, points=None,
                summaries_backend="pallas", num_rounds=2,
                num_parts=packed.num_institutions, max_rounds=2,
            )

        closed = jax.make_jaxpr(fn)(
            jnp.zeros((cfg, d), jnp.float64),
            jnp.full((cfg,), np.inf, jnp.float64),
            jnp.zeros((cfg,), bool),
            jnp.zeros((cfg,), jnp.int32),
            jnp.zeros((cfg,), jnp.float64),
            jnp.zeros((cfg,), jnp.float64),
            jnp.zeros((cfg,), jnp.float64),
            key, jnp.zeros((), jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts,
            fold_ids, fold_of, lams,
        )
        # fold ids are institution-local row metadata: SECRET like the
        # rows they index; the config->fold map and the λ grid are public
        taints = [PUBLIC] * 9 + [SECRET] * 5 + [PUBLIC, PUBLIC]
        return closed, taints

    def runner():
        from ..selection.folds import assign_folds, pack_fold_ids
        from ..selection.path import _cv_sweep_block

        agg = _aggregator()
        num_parts, n, d, num_folds = 3, 8, 4, 2
        packed = _packed(num_parts, n, d)
        fold_parts = [
            assign_folds(n, num_folds, j, 0) for j in range(num_parts)
        ]
        fold_ids = pack_fold_ids(fold_parts, packed.X.shape[1])
        lam_grid = (1.0, 0.5)
        cfg = len(lam_grid) * num_folds
        lams = jnp.asarray(np.repeat(lam_grid, num_folds), jnp.float64)
        fold_of = jnp.asarray(
            np.tile(np.arange(num_folds, dtype=np.int32), len(lam_grid))
        )
        out = _cv_sweep_block(
            jnp.zeros((cfg, d), jnp.float64),
            jnp.full((cfg,), np.inf, jnp.float64),
            jnp.zeros((cfg,), bool),
            jnp.zeros((cfg,), jnp.int32),
            jnp.zeros((cfg,), jnp.float64),
            jnp.zeros((cfg,), jnp.float64),
            jnp.zeros((cfg,), jnp.float64),
            jax.random.PRNGKey(0), jnp.zeros((), jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts,
            fold_ids, fold_of, lams,
            agg=agg, protect=protect, l1=0.0, tol=1e-10,
            interpret=True, points=None,
            summaries_backend="pallas", num_rounds=2,
            num_parts=packed.num_institutions, max_rounds=2,
        )
        jax.block_until_ready(out)

    return DriverSpec(name=name, build=build, runner=runner,
                      threshold=_aggregator().scheme.threshold)


def _toy_tree(d: int = 12):
    g = np.linspace(-1.0, 1.0, d)
    return {
        "gradient": jnp.asarray(g),
        "bias": jnp.asarray(g[:4].reshape(2, 2) * 0.5),
    }


def _psum_spec(name: str, reveal: str, out: str, num_pods: int = 4):
    def build():
        from jax.sharding import AbstractMesh, PartitionSpec as P

        from ..core.collective import secure_psum
        from ..distributed.compat import shard_map
        from ..distributed.sharding import POD_AXIS

        agg = _aggregator()
        key = jax.random.PRNGKey(0)
        mesh = AbstractMesh(((POD_AXIS, num_pods),))
        fn = shard_map(
            lambda tree: secure_psum(
                tree, POD_AXIS, key, aggregator=agg, reveal=reveal,
                out=out,
            ),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        )
        tree = _toy_tree()
        closed = jax.make_jaxpr(fn)(tree)
        taints = [SECRET] * len(jax.tree_util.tree_leaves(tree))
        return closed, taints

    def runner():
        from jax.sharding import PartitionSpec as P

        from ..core.collective import secure_psum
        from ..distributed.compat import shard_map
        from ..distributed.multihost import pod_mesh
        from ..distributed.sharding import POD_AXIS

        agg = _aggregator()
        key = jax.random.PRNGKey(0)
        mesh = pod_mesh(num_pods)
        fn = jax.jit(shard_map(
            lambda tree: secure_psum(
                tree, POD_AXIS, key, aggregator=agg, reveal=reveal,
                out=out,
            ),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        ))
        jax.block_until_ready(fn(_toy_tree()))

    return DriverSpec(name=name, build=build, runner=runner,
                      min_devices=num_pods,
                      threshold=_aggregator().scheme.threshold)


def _psum_2d_spec(name: str, num_pods: int = 3):
    def build():
        from jax.sharding import AbstractMesh, PartitionSpec as P

        from ..distributed.compat import shard_map
        from ..distributed.multihost import secure_psum_2d
        from ..distributed.sharding import POD_AXIS, SHARE_AXIS

        agg = _aggregator()
        key = jax.random.PRNGKey(0)
        # one share column per reveal point: share axis == threshold
        mesh = AbstractMesh(
            ((POD_AXIS, num_pods), (SHARE_AXIS, agg.scheme.threshold))
        )
        fn = shard_map(
            lambda tree: secure_psum_2d(tree, key, aggregator=agg),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        )
        tree = _toy_tree()
        closed = jax.make_jaxpr(fn)(tree)
        taints = [SECRET] * len(jax.tree_util.tree_leaves(tree))
        return closed, taints

    def runner():
        from jax.sharding import PartitionSpec as P

        from ..distributed.compat import shard_map
        from ..distributed.multihost import pod_share_mesh, secure_psum_2d

        agg = _aggregator()
        key = jax.random.PRNGKey(0)
        mesh = pod_share_mesh(num_pods, agg.scheme.threshold)
        fn = jax.jit(shard_map(
            lambda tree: secure_psum_2d(tree, key, aggregator=agg),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        ))
        jax.block_until_ready(fn(_toy_tree()))

    return DriverSpec(name=name, build=build, runner=runner,
                      min_devices=num_pods * _aggregator().scheme.threshold,
                      threshold=_aggregator().scheme.threshold)


def all_driver_specs() -> list:
    """Every graph the standing gate certifies, in gate order."""
    return [
        _fused_spec("secure_fit_fused[protect=both]", "both", False),
        _fused_spec("secure_fit_fused[protect=gradient]", "gradient",
                    False),
        _fused_spec("coordinator_fused[protect=both]", "both", True),
        _fused_spec("coordinator_fused[protect=gradient]", "gradient",
                    True),
        _scan_spec("secure_fit_scan[protect=both]", "both", False),
        _scan_spec("secure_fit_scan[protect=gradient]", "gradient",
                   False),
        _selection_spec("selection_scan[protect=both]", "both"),
        _selection_spec("selection_scan[protect=gradient]", "gradient"),
        _psum_spec("secure_psum[replicated]", "replicated", "tree"),
        _psum_spec("secure_psum[sharded,tree]", "sharded", "tree"),
        _psum_spec("secure_psum[sharded,tile]", "sharded", "tile"),
        _psum_2d_spec("secure_psum_2d"),
    ]
