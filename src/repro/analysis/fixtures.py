"""Deliberately-leaky driver variants: the gate must FAIL on these.

Negative controls for ``scripts/static_checks.sh`` and
``tests/test_analysis.py``: each fixture is a small mutation of a real
driver round that commits one of the leak classes the taint verifier
exists to catch.  If the verifier ever certifies one of these, the gate
itself is broken — so the CLI runs them on every invocation and fails
unless every fixture produces an error finding.

* ``skip_protect``            — computes per-institution summaries and
  sums them with a plain (unannotated) ``jnp.sum``: SECRET data flows
  straight into the round's outputs (objective telemetry, beta).
* ``reveal_institution_slice``— protects correctly, then reveals ONE
  institution's share slice instead of the Algorithm-2 aggregate: the
  reconstruction is a per-institution summary.  The finding names the
  offending ``pjit(_reveal_flat)`` equation path.
* ``callback_leak``           — ships a per-institution deviance into a
  ``jax.debug.callback`` (a print/telemetry hook): host code outside
  the protocol would observe institution-local data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .drivers import DriverSpec, _aggregator, _packed
from .taint import PUBLIC, SECRET

__all__ = ["leak_fixture_specs"]


def _skip_protect_build():
    from ..core.batched_summaries import batched_local_summaries
    from ..core.batched_summaries import PackedPartitions
    from ..core.newton import newton_step, regularized_objective

    packed = _packed()
    beta = jnp.zeros((packed.dim,), jnp.float64)

    def fn(beta, X, X32, y, counts):
        sm = batched_local_summaries(
            beta, PackedPartitions(X, X32, y, counts),
            backend="pallas", interpret=True,
        )
        # LEAK: plain unannotated sums — no protect, no declassify_sum
        H = jnp.sum(sm.hessian, axis=0)
        g = jnp.sum(sm.gradient, axis=0)
        dev = jnp.sum(sm.deviance)
        obj = regularized_objective(dev, beta, 1.0)
        return newton_step(beta, H, g, 1.0), obj

    closed = jax.make_jaxpr(fn)(
        beta, packed.X, packed.X32, packed.y, packed.counts
    )
    return closed, [PUBLIC, SECRET, SECRET, SECRET, SECRET]


def _reveal_slice_build():
    from ..core.batched_summaries import batched_local_summaries
    from ..core.batched_summaries import PackedPartitions
    from ..core.secure_agg import FlatProtected

    agg = _aggregator()
    packed = _packed()
    beta = jnp.zeros((packed.dim,), jnp.float64)
    t = agg.scheme.threshold

    def fn(beta, key, X, X32, y, counts):
        sm = batched_local_summaries(
            beta, PackedPartitions(X, X32, y, counts),
            backend="pallas", interpret=True,
        )
        tree = {"gradient": sm.gradient, "deviance": sm.deviance}
        prot = agg.protect_batched(key, tree)
        # LEAK: slice institution 0's shares BEFORE Algorithm 2 — a
        # threshold reveal of this buffer reconstructs ONE institution's
        # summary, not the global aggregate
        inst0 = prot.buf[:t, :, 0]
        return agg.reveal(FlatProtected(inst0, prot.layout))

    closed = jax.make_jaxpr(fn)(
        beta, jax.random.PRNGKey(0), packed.X, packed.X32, packed.y,
        packed.counts,
    )
    return closed, [PUBLIC, PUBLIC, SECRET, SECRET, SECRET, SECRET]


def _callback_leak_build():
    from ..core.batched_summaries import batched_local_summaries
    from ..core.batched_summaries import PackedPartitions
    from ..core.newton import _fused_secure_iteration

    agg = _aggregator()
    packed = _packed()
    beta = jnp.zeros((packed.dim,), jnp.float64)

    def fn(beta, key, X, X32, y, counts):
        sm = batched_local_summaries(
            beta, PackedPartitions(X, X32, y, counts),
            backend="pallas", interpret=True,
        )
        # LEAK: per-institution deviances shipped to a host logging hook
        jax.debug.callback(lambda d: None, sm.deviance)
        return _fused_secure_iteration(
            beta, key, X, X32, y, counts, 1.0, agg, "both", 0.0, True,
            summaries_backend="pallas",
        )

    closed = jax.make_jaxpr(fn)(
        beta, jax.random.PRNGKey(0), packed.X, packed.X32, packed.y,
        packed.counts,
    )
    return closed, [PUBLIC, PUBLIC, SECRET, SECRET, SECRET, SECRET]


def leak_fixture_specs() -> list:
    """The negative controls, as DriverSpecs the same runner consumes."""
    t = _aggregator().scheme.threshold
    return [
        DriverSpec("LEAKY:skip_protect", _skip_protect_build, t),
        DriverSpec("LEAKY:reveal_institution_slice", _reveal_slice_build,
                   t),
        DriverSpec("LEAKY:callback_leak", _callback_leak_build, t),
    ]
