"""Static privacy-flow verifier + protocol lints: the standing gate.

Nothing in here executes a kernel or moves real data — every pass works
on traced jaxprs (``jax.make_jaxpr`` over tiny synthetic shapes, SPMD
graphs through ``AbstractMesh``), on Python ASTs, or on pure
configuration arithmetic.  Run the whole gate with::

    PYTHONPATH=src python -m repro.analysis

Module map:

* ``taint``    — the jaxpr taint verifier: institution-local inputs are
  SECRET, the encode+share kernel produces PROTECTED share buffers,
  Algorithm 2 (institution-axis / pod-axis sums) upgrades them to
  PROTECTED_AGG, and the threshold Lagrange reveal (or an annotated
  ``declassify_sum``) is the only transition back to PUBLIC.  SECRET or
  share material reaching an output, a host callback, or a reveal in the
  wrong state is an error.
* ``lints``    — the protocol lints: one-host-sync-per-block AST pass
  over the scan drivers, callback census of the round graphs, symbolic
  fixed-point headroom proof from config bounds, mesh-axis allowlist,
  the Pallas VMEM knob check (``kernels.tuning`` model, no
  compilation), and the collective boundary-ownership pass (the
  protect/reveal wrappers may only be CALLED from
  ``core/collective.py`` + the sanctioned audit fixture/kernel layer).
* ``drivers``  — the certified surface: ``DriverSpec`` builders tracing
  every secure driver round (fused, scan, selection sweep, 1D/2D SPMD
  ``secure_psum``) with the taint labels of their inputs.

Everything this gate certifies hangs off ONE chain: every driver routes
through :class:`repro.core.collective.SecureCollective`, whose four
named jit boundaries (``_protect_flat`` / ``_reveal_flat`` /
``_distributed_reveal`` / ``declassify_sum``) are simultaneously the
taint-rule anchors here, the runtime ledger's hook points
(``repro.obs.ledger``), the census the runtime audit reconciles
(``python -m repro.obs audit``), and the ``round_bytes`` telemetry
model — so a certified graph is the only graph a driver can execute,
and the ownership lint turns any bypass into a gate error.
* ``fixtures`` — deliberately-leaky driver variants the gate must FAIL
  on (negative controls, run by the CLI on every invocation).
* ``report``   — ``Finding``/``AnalysisReport`` records shared by all
  passes, with the declassification audit trail.
* ``__main__`` — the CLI gate: verifies every driver spec, runs the
  lints, then the leak fixtures; exit status 0 only if all drivers are
  clean AND every fixture is caught.
"""
from .report import AnalysisReport, Finding
from .taint import (PROTECTED, PROTECTED_AGG, PUBLIC, SECRET, iter_eqns,
                    verify_jaxpr)

__all__ = [
    "AnalysisReport",
    "Finding",
    "PUBLIC",
    "PROTECTED_AGG",
    "PROTECTED",
    "SECRET",
    "verify_jaxpr",
    "iter_eqns",
]
