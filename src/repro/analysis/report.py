"""Findings and reports for the privacy-flow static gate.

A pass (taint verifier or protocol lint) produces :class:`Finding`
records; one analyzed target (a traced driver jaxpr, a source file, a
config) collects them into an :class:`AnalysisReport`.  The report is
the unit the CLI prints and ``scripts/static_checks.sh`` gates on:
``ok`` iff no finding at severity "error".

Severities:

* ``error``   — a privacy-flow violation or protocol-invariant break;
  the gate fails.
* ``warning`` — the pass could not prove the property (e.g. an unknown
  mesh-axis size); surfaced but non-fatal.
* ``info``    — a proved positive fact worth recording (e.g. a
  sanctioned declassification site, a headroom margin).
"""
from __future__ import annotations

import dataclasses

__all__ = ["Finding", "AnalysisReport", "SEVERITIES"]

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One fact a pass established about one program point.

    ``where`` is the jaxpr equation path (e.g.
    ``eqn[3]:pjit(_reveal_flat)`` nested as ``.../eqn[0]:scan/...``) or
    a ``file:line`` location for source-level lints.
    """

    pass_name: str   # "taint", "host-sync", "headroom", "mesh-axis", ...
    severity: str    # one of SEVERITIES
    where: str       # jaxpr eqn path or file:line
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}")

    def format(self) -> str:
        return f"[{self.severity}] {self.pass_name}: {self.where}: " \
               f"{self.message}"


@dataclasses.dataclass
class AnalysisReport:
    """All findings for one analyzed target."""

    target: str
    findings: list = dataclasses.field(default_factory=list)
    # sanctioned declassification sites the taint pass certified: the
    # audit trail of every place SECRET data legally became PUBLIC
    declassifications: list = dataclasses.field(default_factory=list)

    def add(self, finding: Finding):
        if finding not in self.findings:
            self.findings.append(finding)

    def extend(self, findings):
        for f in findings:
            self.add(f)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def errors(self) -> list:
        return [f for f in self.findings if f.severity == "error"]

    def format(self, verbose: bool = False) -> str:
        status = "PASS" if self.ok else "FAIL"
        lines = [f"{status}  {self.target}"]
        for f in self.findings:
            if f.severity == "info" and not verbose:
                continue
            lines.append(f"  {f.format()}")
        if verbose:
            for d in self.declassifications:
                lines.append(f"  [declassified] {d}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "target": self.target,
            "ok": self.ok,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "declassifications": list(self.declassifications),
        }
