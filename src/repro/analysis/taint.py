"""Jaxpr taint verifier: SECRET may only reach PUBLIC through a reveal.

The pass walks a driver round's closed jaxpr (``jax.make_jaxpr`` output)
with a four-level taint lattice, join = max:

* ``PUBLIC`` (0)        — revealed aggregates, beta, lambda, rng keys.
* ``PROTECTED_AGG`` (1) — the share buffer of the *aggregated* secret
  (Algorithm 2 has run over an institution/pod axis of size >= 2).
  Structurally still shares, but the underlying secret is the global
  sum — the only thing a reveal may reconstruct.
* ``PROTECTED`` (2)     — per-institution Shamir share buffers straight
  out of the encode+share kernel.  Revealing these reconstructs ONE
  institution's summary: a violation.
* ``SECRET`` (3)        — institution-local inputs (X, y, counts, fold
  ids) and anything derived from them before protection.

Transitions the verifier recognizes (everything else joins its inputs):

* ``pjit(_protect_flat)`` — the fused fixed-point-encode + Horner share
  kernel: outputs are PROTECTED whatever came in (SECRET -> PROTECTED).
* ``reduce_sum`` over the institution axis of a batched share buffer
  (axis ndim-3 of a >=5D PROTECTED operand — the (w, R, [C,] S, rows,
  128) layout — with size >= 2): Algorithm 2, PROTECTED ->
  PROTECTED_AGG.  A reduction over any *other* axis of a share buffer
  (rows, lanes, residues) does NOT aggregate institutions and keeps the
  taint, so slicing tricks cannot launder a single contribution.
* ``psum`` / ``reduce_scatter`` over a mesh axis of size >= 2 on a
  PROTECTED operand: the SPMD form of Algorithm 2 -> PROTECTED_AGG.
* ``pjit(_reveal_flat)`` — the fused Lagrange+CRT reconstruction: the
  ONLY declassification of share material.  Requires (a) input taint
  exactly PROTECTED_AGG (SECRET means protect was skipped; PROTECTED
  means a per-institution buffer is being revealed) and (b) a
  threshold-satisfying share axis (leading dim >= t).  Outputs PUBLIC.
* ``pjit(_distributed_reveal)`` — the 2D-mesh collective reveal: same
  contract, with the share *mesh axis* (its size must be >= t) standing
  in for the stacked share dim.
* ``pjit(declassify_sum)`` — the sanctioned *plaintext* aggregation
  annotation (``core.secure_agg.declassify_sum``) used by the
  ``protect != "both"`` modes the paper allows: requires an actually
  aggregating reduction (>= 2 addends); SECRET -> PUBLIC with the site
  recorded in the report's declassification audit trail.

Violations: SECRET/PROTECTED reaching a host callback
(``debug_callback`` / ``io_callback`` / ``pure_callback``) or any
jaxpr output (outputs feed RoundReport telemetry and downstream hosts).
Sub-jaxprs of pjit/scan/cond/while/shard_map are walked recursively
(scan and while to a carry fixpoint); shard_map pushes its mesh's axis
sizes so collective rules know whether an axis actually aggregates.
"""
from __future__ import annotations

import dataclasses
import math

from jax import core as jax_core

from .report import AnalysisReport, Finding

__all__ = [
    "PUBLIC",
    "PROTECTED_AGG",
    "PROTECTED",
    "SECRET",
    "TAINT_NAMES",
    "verify_jaxpr",
    "iter_eqns",
]

PUBLIC, PROTECTED_AGG, PROTECTED, SECRET = 0, 1, 2, 3
TAINT_NAMES = {
    PUBLIC: "PUBLIC",
    PROTECTED_AGG: "PROTECTED_AGG",
    PROTECTED: "PROTECTED",
    SECRET: "SECRET",
}

# host-callback primitives: taint > PUBLIC crossing one is a leak (the
# callback's payload materializes on the host outside the protocol)
CALLBACK_PRIMS = {"debug_callback", "io_callback", "pure_callback"}

# collective primitives that sum over a mesh axis (Algorithm 2 on the
# wire when applied to a share buffer)
_SUM_COLLECTIVES = {"psum", "reduce_scatter", "psum_scatter"}


@dataclasses.dataclass
class _Ctx:
    """Walk state threaded through sub-jaxpr recursion."""

    threshold: int
    axis_sizes: dict
    report: AnalysisReport
    mute: int = 0  # >0 during fixpoint warm-up passes (findings suppressed)

    def add(self, severity, where, message):
        if not self.mute:
            self.report.add(Finding("taint", severity, where, message))

    def declassified(self, where, what):
        if not self.mute:
            entry = f"{where}: {what}"
            if entry not in self.report.declassifications:
                self.report.declassifications.append(entry)


def _join(taints):
    return max(taints, default=PUBLIC)


def _read(env, atom):
    if isinstance(atom, jax_core.Literal):
        return PUBLIC
    return env.get(atom, PUBLIC)


def _eqn_label(eqn) -> str:
    name = eqn.primitive.name
    inner = eqn.params.get("name")
    return f"{name}({inner})" if inner else name


def _sub_jaxpr(val):
    """Normalize ClosedJaxpr/Jaxpr params to (jaxpr, has_consts)."""
    if hasattr(val, "jaxpr"):
        return val.jaxpr
    return val


def _prod(shape):
    return math.prod(shape) if shape else 1


# -- declassifier / transition rules for named pjit calls -----------------


def _rule_protect_flat(eqn, ins, ctx, where):
    return [PROTECTED] * len(eqn.outvars)


def _share_buf_invar(eqn):
    """The share-buffer operand: the highest-rank uint32 input."""
    best = None
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is None or not hasattr(aval, "shape"):
            continue
        if best is None or len(aval.shape) > len(best.aval.shape):
            best = v
    return best


def _rule_reveal_flat(eqn, ins, ctx, where):
    buf = _share_buf_invar(eqn)
    t = ctx.threshold
    if buf is not None and len(buf.aval.shape) >= 1:
        k = buf.aval.shape[0]
        if k < t:
            ctx.add(
                "error", where,
                f"reveal from {k} share slices < threshold t={t}: "
                "below-threshold reconstruction",
            )
    taint = _join(ins)
    if taint == SECRET:
        ctx.add(
            "error", where,
            "reveal of UNPROTECTED institution-local data (the operand "
            "never went through the encode+share kernel)",
        )
    elif taint == PROTECTED:
        ctx.add(
            "error", where,
            "reveal of a PER-INSTITUTION share buffer: Algorithm 2 "
            "(the institution-axis aggregation) never ran, so this "
            "reconstructs a single institution's summary",
        )
    else:
        ctx.declassified(
            where,
            "threshold Lagrange reveal of the aggregated share buffer",
        )
    return [PUBLIC] * len(eqn.outvars)


def _rule_distributed_reveal(eqn, ins, ctx, where):
    from ..distributed.sharding import SHARE_AXIS

    t = ctx.threshold
    share_sz = ctx.axis_sizes.get(SHARE_AXIS)
    if share_sz is None:
        ctx.add(
            "warning", where,
            f"distributed reveal outside a mesh with a '{SHARE_AXIS}' "
            "axis: cannot prove the center count >= t",
        )
    elif share_sz < t:
        ctx.add(
            "error", where,
            f"distributed reveal over a share axis of {share_sz} "
            f"centers < threshold t={t}",
        )
    taint = _join(ins)
    if taint == SECRET:
        ctx.add(
            "error", where,
            "distributed reveal of UNPROTECTED institution-local data",
        )
    elif taint == PROTECTED:
        ctx.add(
            "error", where,
            "distributed reveal of a PER-INSTITUTION share slice "
            "(pod-axis aggregation never ran)",
        )
    else:
        ctx.declassified(
            where, "distributed (share-axis collective) Lagrange reveal"
        )
    return [PUBLIC] * len(eqn.outvars)


def _rule_declassify_sum(eqn, ins, ctx, where):
    taint = _join(ins)
    in_elems = max(
        (_prod(v.aval.shape) for v in eqn.invars
         if hasattr(getattr(v, "aval", None), "shape")),
        default=1,
    )
    out_elems = max(
        (_prod(v.aval.shape) for v in eqn.outvars
         if hasattr(getattr(v, "aval", None), "shape")),
        default=1,
    )
    if taint in (PROTECTED, PROTECTED_AGG):
        ctx.add(
            "error", where,
            "declassify_sum applied to SHARE material — shares must go "
            "through the threshold reveal, never a plaintext sum",
        )
    elif in_elems < 2 * max(out_elems, 1):
        ctx.add(
            "error", where,
            f"declassify_sum does not aggregate ({in_elems} -> "
            f"{out_elems} elements): a non-reducing 'sum' would "
            "declassify an individual contribution",
        )
    elif taint == SECRET:
        ctx.declassified(
            where,
            "annotated plaintext aggregation over the institution axis "
            f"({in_elems // max(out_elems, 1)} addends)",
        )
    return [PUBLIC] * len(eqn.outvars)


_PJIT_RULES = {
    "_protect_flat": _rule_protect_flat,
    "_reveal_flat": _rule_reveal_flat,
    "_distributed_reveal": _rule_distributed_reveal,
    "declassify_sum": _rule_declassify_sum,
}


# -- structural recursion --------------------------------------------------


def _eval_jaxpr(jaxpr, in_taints, ctx, path):
    env = {}
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = t
    for v in jaxpr.constvars:
        env[v] = PUBLIC  # trace-time constants (keys, static tables)
    for i, eqn in enumerate(jaxpr.eqns):
        where = f"{path}/eqn[{i}]:{_eqn_label(eqn)}"
        ins = [_read(env, a) for a in eqn.invars]
        outs = _eval_eqn(eqn, ins, ctx, where)
        for v, t in zip(eqn.outvars, outs):
            if not isinstance(v, jax_core.DropVar):
                env[v] = t
    return [_read(env, a) for a in jaxpr.outvars]


def _fixpoint_body(body_jaxpr, consts, carry, xs, ctx, path,
                   num_carry: int, max_iters: int = 8):
    """Carry-taint fixpoint for scan/while bodies.

    Warm-up passes run muted (findings would duplicate per iteration);
    one final unmuted pass at the fixed carry taints collects findings.
    """
    carry_t = list(carry)
    ctx.mute += 1
    try:
        for _ in range(max_iters):
            outs = _eval_jaxpr(body_jaxpr, consts + carry_t + xs, ctx,
                               path)
            new_carry = [max(a, b)
                         for a, b in zip(carry_t, outs[:num_carry])]
            if new_carry == carry_t:
                break
            carry_t = new_carry
    finally:
        ctx.mute -= 1
    outs = _eval_jaxpr(body_jaxpr, consts + carry_t + xs, ctx, path)
    return carry_t, outs


def _eval_eqn(eqn, ins, ctx, where):
    prim = eqn.primitive.name
    params = eqn.params

    if prim in CALLBACK_PRIMS:
        taint = _join(ins)
        if taint > PUBLIC:
            ctx.add(
                "error", where,
                f"{TAINT_NAMES[taint]} data reaches host callback "
                f"'{prim}': callback payloads leave the protocol "
                "(logs, telemetry, debuggers)",
            )
        return [PUBLIC] * len(eqn.outvars)

    if prim == "pjit":
        name = params.get("name", "")
        rule = _PJIT_RULES.get(name)
        if rule is not None:
            return rule(eqn, ins, ctx, where)
        sub = _sub_jaxpr(params["jaxpr"])
        return _eval_jaxpr(sub, ins, ctx, where)

    if prim == "closed_call" or prim == "core_call":
        sub = _sub_jaxpr(params["call_jaxpr"])
        return _eval_jaxpr(sub, ins, ctx, where)

    if prim == "scan":
        sub = _sub_jaxpr(params["jaxpr"])
        nc, ncar = params["num_consts"], params["num_carry"]
        consts, carry, xs = ins[:nc], ins[nc:nc + ncar], ins[nc + ncar:]
        carry_t, outs = _fixpoint_body(
            sub, consts, carry, xs, ctx, where, ncar
        )
        return carry_t + outs[ncar:]

    if prim == "while":
        cond_sub = _sub_jaxpr(params["cond_jaxpr"])
        body_sub = _sub_jaxpr(params["body_jaxpr"])
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        cond_consts = ins[:cn]
        body_consts = ins[cn:cn + bn]
        carry = ins[cn + bn:]
        carry_t, _ = _fixpoint_body(
            body_sub, body_consts, carry, [], ctx, where, len(carry)
        )
        _eval_jaxpr(cond_sub, cond_consts + carry_t, ctx,
                    f"{where}/cond")
        return carry_t

    if prim == "cond":
        branches = params["branches"]
        ops = ins[1:]
        branch_outs = [
            _eval_jaxpr(_sub_jaxpr(b), ops, ctx, f"{where}/branch{i}")
            for i, b in enumerate(branches)
        ]
        return [max(ts) for ts in zip(*branch_outs)]

    if prim == "shard_map":
        sub = _sub_jaxpr(params["jaxpr"])
        mesh = params.get("mesh")
        saved = ctx.axis_sizes
        if mesh is not None and hasattr(mesh, "shape"):
            ctx.axis_sizes = {**saved, **dict(mesh.shape)}
        try:
            return _eval_jaxpr(sub, ins, ctx, where)
        finally:
            ctx.axis_sizes = saved

    if prim == "reduce_sum":
        taint = _join(ins)
        if taint == PROTECTED:
            aval = eqn.invars[0].aval
            axes = tuple(params.get("axes", ()))
            ndim = len(aval.shape)
            # the batched share layout is (w, R, [C,] S, rows, lanes):
            # the institution axis sits at ndim-3 in every variant, and
            # ONLY a reduction there is Algorithm 2
            if (ndim >= 5 and axes == (ndim - 3,)
                    and aval.shape[ndim - 3] >= 2):
                return [PROTECTED_AGG] * len(eqn.outvars)
        return [taint] * len(eqn.outvars)

    if prim in _SUM_COLLECTIVES:
        taint = _join(ins)
        if taint == PROTECTED:
            size = _collective_axis_size(params, ctx)
            if size is None:
                ctx.add(
                    "warning", where,
                    f"'{prim}' over a mesh axis of unknown size on a "
                    "share buffer: cannot prove it aggregates >= 2 "
                    "institutions",
                )
                return [PROTECTED] * len(eqn.outvars)
            if size >= 2:
                return [PROTECTED_AGG] * len(eqn.outvars)
            return [PROTECTED] * len(eqn.outvars)
        return [taint] * len(eqn.outvars)

    # default: outputs join the inputs (sound for every elementwise /
    # structural primitive; opaque calls — pallas_call, custom_jvp,
    # linear solves — conservatively propagate their strongest input)
    return [_join(ins)] * len(eqn.outvars)


def _collective_axis_size(params, ctx):
    """Total size of a sum-collective's named axes, if statically known."""
    names = params.get("axes", params.get("axis_name", ()))
    if not isinstance(names, (tuple, list)):
        names = (names,)
    named = [n for n in names if isinstance(n, str)]
    if "axis_size" in params and params["axis_size"] is not None:
        return params["axis_size"]
    if not named:
        return None
    total = 1
    for n in named:
        sz = ctx.axis_sizes.get(n)
        if sz is None:
            return None
        total *= sz
    return total


# -- entry points ----------------------------------------------------------


def verify_jaxpr(closed_jaxpr, in_taints, threshold: int,
                 axis_sizes: dict | None = None,
                 target: str = "jaxpr",
                 report: AnalysisReport | None = None) -> AnalysisReport:
    """Run the taint pass over one closed jaxpr.

    ``in_taints`` aligns 1:1 with the jaxpr's flat invars (use
    ``jax.tree_util.tree_leaves`` on a taint pytree shaped like the
    traced function's arguments).  Outputs carrying taint above PUBLIC
    are violations: driver outputs feed RoundReport telemetry, host
    convergence checks, and checkpoint files.
    """
    jaxpr = closed_jaxpr.jaxpr
    if len(in_taints) != len(jaxpr.invars):
        raise ValueError(
            f"{target}: got {len(in_taints)} taints for "
            f"{len(jaxpr.invars)} jaxpr inputs"
        )
    rep = report or AnalysisReport(target=target)
    ctx = _Ctx(threshold=threshold, axis_sizes=dict(axis_sizes or {}),
               report=rep)
    out_taints = _eval_jaxpr(jaxpr, list(in_taints), ctx, target)
    for i, t in enumerate(out_taints):
        if t == SECRET:
            rep.add(Finding(
                "taint", "error", f"{target}/outvars[{i}]",
                "output carries SECRET taint: institution-local data "
                "reaches a revealed/telemetry output",
            ))
        elif t in (PROTECTED, PROTECTED_AGG):
            rep.add(Finding(
                "taint", "error", f"{target}/outvars[{i}]",
                f"output carries {TAINT_NAMES[t]} share material: "
                "share buffers must never leave the round graph",
            ))
    if not rep.declassifications and any(
        t == SECRET for t in in_taints
    ) and rep.ok:
        rep.add(Finding(
            "taint", "warning", target,
            "SECRET inputs but no declassification site found: the "
            "graph never reveals (vacuously safe — check the spec)",
        ))
    return rep


def iter_eqns(jaxpr, path: str = "", axis_sizes: dict | None = None):
    """Yield ``(path, eqn, axis_sizes)`` over a jaxpr and all sub-jaxprs.

    Structural walk used by the lint passes (mesh-axis checks, callback
    census).  ``axis_sizes`` carries the innermost enclosing shard_map
    mesh's axis sizes at each yield point.
    """
    sizes = dict(axis_sizes or {})
    jaxpr = _sub_jaxpr(jaxpr)
    for i, eqn in enumerate(jaxpr.eqns):
        where = f"{path}/eqn[{i}]:{_eqn_label(eqn)}"
        yield where, eqn, sizes
        inner_sizes = sizes
        if eqn.primitive.name == "shard_map":
            mesh = eqn.params.get("mesh")
            if mesh is not None and hasattr(mesh, "shape"):
                inner_sizes = {**sizes, **dict(mesh.shape)}
        for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
            if key in eqn.params:
                sub = eqn.params[key]
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from iter_eqns(sub, where, inner_sizes)
        for bi, b in enumerate(eqn.params.get("branches", ())):
            yield from iter_eqns(b, f"{where}/branch{bi}", inner_sizes)
