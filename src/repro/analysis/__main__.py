"""The standing static gate: ``python -m repro.analysis``.

Runs, in order:

1. the taint verifier + per-graph lints (callback census, mesh axes)
   over every certified driver spec (``drivers.all_driver_specs``),
2. the source-level and config-level lints (host-sync AST pass,
   fixed-point headroom proof, Pallas knob check, obs purity pass,
   collective boundary-ownership pass),
3. the leak fixtures (``fixtures.leak_fixture_specs``) — deliberately
   broken drivers the verifier MUST flag; a fixture passing clean means
   the gate itself regressed.

Exit status 0 iff every driver/lint report is clean AND every fixture
is caught.  ``--verbose`` shows info findings and the declassification
audit trail; ``--json`` emits machine-readable reports; ``--drivers``
filters specs by substring (fixtures still run unless
``--no-fixtures``).
"""
from __future__ import annotations

import argparse
import json
import sys


def _analyze_spec(spec, *, expect_leak: bool = False):
    from .lints import lint_mesh_axes, lint_no_callbacks
    from .report import AnalysisReport
    from .taint import verify_jaxpr

    closed, taints = spec.build()
    report = AnalysisReport(target=spec.name)
    verify_jaxpr(closed, taints, spec.threshold,
                 axis_sizes=spec.axis_sizes, target=spec.name,
                 report=report)
    if not expect_leak:
        # leak fixtures get taint-only treatment: the callback fixture
        # *should* trip the census too, but the taint finding is the one
        # the negative control pins
        lint_no_callbacks(closed, spec.name, report)
        lint_mesh_axes(closed, spec.name, report)
    return report


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="privacy-flow taint verifier + protocol lints",
    )
    parser.add_argument("--drivers", default="",
                        help="only run driver specs containing SUBSTR")
    parser.add_argument("--verbose", action="store_true",
                        help="show info findings + declassification trail")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit reports as JSON")
    parser.add_argument("--no-fixtures", action="store_true",
                        help="skip the leak-fixture negative controls")
    args = parser.parse_args(argv)

    from .drivers import all_driver_specs
    from .fixtures import leak_fixture_specs
    from .lints import (SummaryBounds, lint_collective_sites, lint_headroom,
                        lint_host_sync, lint_kernel_knobs, lint_obs_purity)

    reports = []
    failed = False

    specs = [s for s in all_driver_specs() if args.drivers in s.name]
    for spec in specs:
        rep = _analyze_spec(spec)
        reports.append(rep)
        failed |= not rep.ok

    if not args.drivers:
        reports.append(lint_host_sync())
        # deployment-shaped bounds: lane-aligned d, benchmark-scale rows,
        # a full cohort — the envelope every shipped config sits inside
        reports.append(lint_headroom(
            SummaryBounds(d=128, n_max=100_000, num_parts=16)
        ))
        reports.append(lint_kernel_knobs())
        reports.append(lint_obs_purity())
        reports.append(lint_collective_sites())
        failed |= not all(r.ok for r in reports[-5:])

    caught = []
    if not args.no_fixtures:
        for spec in leak_fixture_specs():
            rep = _analyze_spec(spec, expect_leak=True)
            if rep.ok:
                failed = True
                caught.append((rep, False))
            else:
                caught.append((rep, True))

    if args.as_json:
        payload = {
            "reports": [r.to_dict() for r in reports],
            "fixtures": [
                {"caught": was_caught, **r.to_dict()}
                for r, was_caught in caught
            ],
            "ok": not failed,
        }
        print(json.dumps(payload, indent=2))
        return 1 if failed else 0

    for rep in reports:
        print(rep.format(verbose=args.verbose))
    for rep, was_caught in caught:
        if was_caught:
            errs = rep.errors()
            print(f"CAUGHT  {rep.target} ({len(errs)} error finding(s))")
            for f in errs if args.verbose else errs[:1]:
                print(f"  {f.format()}")
        else:
            print(f"MISSED  {rep.target} — the leak fixture passed the "
                  "gate: the verifier has regressed")
    print(f"\ngate: {'FAIL' if failed else 'PASS'} "
          f"({len(specs)} drivers, {len(caught)} fixtures)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
