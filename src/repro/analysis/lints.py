"""Protocol lints: the static twins of the runtime protocol invariants.

Four passes, each producing :class:`~repro.analysis.report.Finding`
records (see ``report.py``):

* :func:`lint_host_sync` — AST pass pinning "ONE host sync per scan
  block" over the three scan drivers.  Values bound from a round
  dispatch (``fit_scan_block`` / ``_cv_sweep_block`` /
  ``_fused_secure_iteration``) are device-resident; materializing one on
  the host (``float``/``int``/``bool``/``np.asarray``/``.item()``/
  ``jax.device_get``) is a sync.  Each monitored function must contain
  exactly ONE sync site, annotated with a ``# host-sync:`` comment;
  every unannotated materialization of a device value is a violation.
  ``jax.device_get`` rebinding names to the host side is tracked, so
  bookkeeping on already-fetched values stays clean.
* :func:`lint_no_callbacks` — jaxpr census: a scan-resident round graph
  must contain ZERO host-callback equations (a callback inside the scan
  body is a hidden per-round sync AND a telemetry channel).
* :func:`lint_headroom` — symbolic fixed-point pass: from configuration
  bounds alone (:class:`SummaryBounds`), prove the two overflow
  invariants the runtime asserts dynamically — the CRT aggregation bound
  ``S * max(p_r) < 2**64`` (``check_aggregation_headroom``'s static
  twin) and the codec capacity bound ``S * max|summary| < capacity``
  (``SecureAggregator.headroom_ok``'s static twin).
* :func:`lint_mesh_axes` — every collective axis in a traced graph must
  be one of the protocol's named mesh axes (``POD_AXIS``/``SHARE_AXIS``)
  and bound by the enclosing ``shard_map`` mesh.
* :func:`lint_kernel_knobs` — the compiled-path Pallas blocking knobs
  checked against the ``kernels.tuning`` VMEM working-set model at the
  gate's dims, without compiling anything.
* :func:`lint_collective_sites` — AST pass pinning the PR-10 ownership
  contract: the protect/reveal boundary wrappers (``_protect_flat`` /
  ``_reveal_flat`` / ``_distributed_reveal``) may be CALLED only inside
  ``core/collective.py`` — the one chain every driver routes through —
  plus the two sanctioned exceptions (the deliberate-leak audit fixture
  in ``obs/audit.py``; the raw kernel layer ``kernels/ops.py``).  A new
  call site anywhere else is a driver growing its own private
  protect -> reveal chain, exactly the drift this layer exists to stop.
  Imports/re-exports are fine — only ``ast.Call`` nodes count.
* :func:`lint_obs_purity` — AST pass over the observability core
  modules (``obs/trace.py``, ``obs/ledger.py``, ``obs/metrics.py``):
  stdlib-only imports (so the jax-free runtime layer can use them, and
  so instrumentation can never introduce a device dependency), zero
  host callbacks, zero device materializers.  The one sanctioned
  exception is the lazy ``import jax.profiler`` inside
  ``SpanTracer._annotation`` — the optional profile-annotation hook.
"""
from __future__ import annotations

import ast
import dataclasses
import math
import pathlib

from .report import AnalysisReport, Finding
from .taint import CALLBACK_PRIMS, iter_eqns

__all__ = [
    "MONITORED_DRIVERS",
    "SYNC_MARK",
    "SummaryBounds",
    "lint_host_sync",
    "lint_no_callbacks",
    "lint_headroom",
    "lint_mesh_axes",
    "lint_kernel_knobs",
    "lint_obs_purity",
    "lint_collective_sites",
    "BOUNDARY_CALL_EXEMPT",
]


# -- host-sync lint --------------------------------------------------------

SYNC_MARK = "# host-sync:"

# round-dispatch callables: binding their result makes a name device-resident
DISPATCH_FNS = {"fit_scan_block", "_cv_sweep_block", "_fused_secure_iteration"}

# module path (relative to the repro package) -> monitored driver methods
MONITORED_DRIVERS = (
    ("core/newton.py", "SecureFitDriver", ("_round_fused", "step_block")),
    ("core/protocol.py", "StudyCoordinator",
     ("_round_fused", "step_block")),
    ("selection/path.py", "PathDriver", ("run_chunk",)),
)

_SCALAR_MATERIALIZERS = {"float", "int", "bool"}
_MODULE_MATERIALIZERS = {
    ("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
    ("numpy", "array"),
    ("jax", "device_get"), ("jax", "block_until_ready"),
}
# marker comment must sit within this many lines above the sync call
_MARK_WINDOW = 5


def _materializer_kind(call: ast.Call):
    f = call.func
    if isinstance(f, ast.Name) and f.id in _SCALAR_MATERIALIZERS:
        return f.id
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name) and \
                (f.value.id, f.attr) in _MODULE_MATERIALIZERS:
            return f"{f.value.id}.{f.attr}"
        if f.attr == "item" and not call.args and not call.keywords:
            return ".item()"
    return None


def _is_intrinsic_sync(kind: str) -> bool:
    """device_get/block_until_ready sync regardless of argument taint."""
    return kind in ("jax.device_get", "jax.block_until_ready")


def _arg_names(call: ast.Call):
    names = set()
    for sub in call.args + [kw.value for kw in call.keywords]:
        for n in ast.walk(sub):
            if isinstance(n, ast.Name):
                names.add(n.id)
    return names


def _call_callee(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _target_names(stmt):
    targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
    names = []
    for t in targets:
        if isinstance(t, ast.Name):
            names.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            names.extend(e.id for e in t.elts if isinstance(e, ast.Name))
    return names


def _own_calls(stmt: ast.stmt):
    """Call nodes in this statement's own expressions, NOT in nested
    statements (compound statements would otherwise re-yield their
    bodies' calls)."""
    out = []

    def rec(node):
        for ch in ast.iter_child_nodes(node):
            if isinstance(ch, ast.stmt):
                continue
            if isinstance(ch, ast.Call):
                out.append(ch)
            rec(ch)

    rec(stmt)
    return out


def _find_function(tree: ast.Module, cls: str, fn: str):
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == cls:
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef) and sub.name == fn:
                    return sub
    return None


def _lint_function(fn_node: ast.FunctionDef, mark_lines: set, where: str,
                   report: AnalysisReport):
    """One monitored driver method: exactly one marked sync, no strays."""
    stmts = sorted(
        (n for n in ast.walk(fn_node) if isinstance(n, ast.stmt)),
        key=lambda n: (n.lineno, n.col_offset),
    )
    device: set = set()
    candidates = []  # (lineno, site) of every device materialization

    for stmt in stmts:
        # phase 1: check every materializer call inside this statement
        # against the CURRENT device set (binding applies afterwards)
        for call in _own_calls(stmt):
            kind = _materializer_kind(call)
            if kind is None:
                continue
            touched = _arg_names(call) & device
            if _is_intrinsic_sync(kind) or touched:
                site = f"{where}:{call.lineno} {kind}"
                if touched:
                    site += f"({', '.join(sorted(touched))})"
                candidates.append((call.lineno, site))
        # phase 2: binding effects, in source order
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            calls = [c for c in ast.walk(stmt.value)
                     if isinstance(c, ast.Call)]
            names = _target_names(stmt)
            if any(_call_callee(c) == "device_get" for c in calls):
                device.difference_update(names)  # fetched -> host side
            elif any(_call_callee(c) in DISPATCH_FNS for c in calls):
                device.update(names)
            elif any(isinstance(n, ast.Name) and n.id in device
                     for n in ast.walk(stmt.value)):
                device.update(names)  # derived from a device value

    # a marker blesses only the FIRST materialization at/after it (within
    # the window) — trailing reads can't ride an earlier annotation
    candidates.sort()
    blessed = set()
    for m in sorted(mark_lines):
        for idx, (lineno, _) in enumerate(candidates):
            if idx not in blessed and m <= lineno <= m + _MARK_WINDOW:
                blessed.add(idx)
                break
    syncs = [site for idx, (_, site) in enumerate(candidates)
             if idx in blessed]
    for idx, (_, site) in enumerate(candidates):
        if idx not in blessed:
            report.add(Finding(
                "host-sync", "error", site,
                "unannotated host materialization of a device-resident "
                "round value — a hidden sync (mark the ONE intended "
                f"site with '{SYNC_MARK}' or keep the value on device)",
            ))

    if len(syncs) == 1:
        report.add(Finding(
            "host-sync", "info", syncs[0],
            "the one marked host sync of this driver block",
        ))
    elif not syncs:
        report.add(Finding(
            "host-sync", "error", where,
            f"no marked host-sync site found (expected exactly one "
            f"'{SYNC_MARK}'-annotated readback)",
        ))
    else:
        report.add(Finding(
            "host-sync", "error", where,
            f"{len(syncs)} marked host-sync sites ({'; '.join(syncs)}): "
            "a scan driver block must sync exactly once",
        ))


def lint_host_sync(report: AnalysisReport | None = None, *,
                   modules=None) -> AnalysisReport:
    """Pin "one host sync per scan block" over the driver sources.

    ``modules`` (for tests/fixtures) maps a display name to
    ``(source_text, [(class_name, fn_name), ...])``; default is the real
    monitored driver set read from the package sources.
    """
    rep = report or AnalysisReport(target="host-sync")
    if modules is None:
        pkg = pathlib.Path(__file__).resolve().parents[1]
        modules = {}
        for rel, cls, fns in MONITORED_DRIVERS:
            src = (pkg / rel).read_text()
            modules[rel] = (src, [(cls, fn) for fn in fns])
    for name, (src, targets) in modules.items():
        tree = ast.parse(src)
        mark_lines = {
            i for i, line in enumerate(src.splitlines(), start=1)
            if SYNC_MARK in line
        }
        for cls, fn in targets:
            node = _find_function(tree, cls, fn)
            if node is None:
                rep.add(Finding(
                    "host-sync", "error", f"{name}:{cls}.{fn}",
                    "monitored driver method not found — update "
                    "MONITORED_DRIVERS if it moved",
                ))
                continue
            _lint_function(node, mark_lines, f"{name}:{cls}.{fn}", rep)
    return rep


def lint_no_callbacks(closed_jaxpr, target: str,
                      report: AnalysisReport | None = None
                      ) -> AnalysisReport:
    """A scan-resident round graph must contain zero host callbacks."""
    rep = report or AnalysisReport(target=target)
    found = 0
    for where, eqn, _ in iter_eqns(closed_jaxpr.jaxpr, target):
        if eqn.primitive.name in CALLBACK_PRIMS:
            found += 1
            rep.add(Finding(
                "host-sync", "error", where,
                f"host callback '{eqn.primitive.name}' inside a scan "
                "driver graph: a hidden per-round sync (and telemetry "
                "channel) that breaks the one-sync-per-block contract",
            ))
    if not found:
        rep.add(Finding(
            "host-sync", "info", target,
            "callback-free graph: the block's only host point is the "
            "trace readback after dispatch",
        ))
    return rep


# -- fixed-point headroom lint ---------------------------------------------


@dataclasses.dataclass(frozen=True)
class SummaryBounds:
    """Configuration-level magnitude bounds on one institution's summary.

    From these four deployment facts the lint derives worst-case bounds
    on every summary statistic an institution ever encodes, then proves
    the aggregation headroom invariants symbolically:

    * hessian entry:  ``0.25 * n_max * x_max**2``  (logistic w <= 1/4)
    * gradient entry: ``n_max * x_max``            (|y - p| <= 1)
    * deviance:       ``2 * n_max * (log 2 + d * x_max * beta_max)``
    * count:          ``n_max``
    """

    d: int
    n_max: int
    num_parts: int
    x_max: float = 1.0
    beta_max: float = 10.0

    def eta_max(self) -> float:
        return self.d * self.x_max * self.beta_max

    def max_abs(self) -> float:
        hess = 0.25 * self.n_max * self.x_max ** 2
        grad = self.n_max * self.x_max
        dev = 2.0 * self.n_max * (math.log(2.0) + self.eta_max())
        return max(hess, grad, dev, float(self.n_max))


def lint_headroom(bounds: SummaryBounds, aggregator=None,
                  report: AnalysisReport | None = None) -> AnalysisReport:
    """Prove the overflow invariants from config bounds, statically.

    The static twin of the runtime pair ``check_aggregation_headroom``
    (CRT residue sums fit uint64) and ``FixedPointCodec.check_headroom``
    / ``SecureAggregator.headroom_ok`` (the decoded aggregate fits the
    codec's signed capacity).
    """
    if aggregator is None:
        from ..core.secure_agg import SecureAggregator

        aggregator = SecureAggregator(backend="pallas")
    rep = report or AnalysisReport(target="headroom")
    field = aggregator.scheme.field
    s = bounds.num_parts

    worst = s * max(field.moduli)
    if worst >= 2 ** 64:
        rep.add(Finding(
            "headroom", "error", "aggregation",
            f"S * max(p_r) = {s} * {max(field.moduli)} = {worst} >= "
            "2**64: the Algorithm-2 uint64 residue accumulator can wrap "
            f"— at these moduli at most {2 ** 64 // max(field.moduli)} "
            "institutions are admissible",
        ))
    else:
        rep.add(Finding(
            "headroom", "info", "aggregation",
            f"S * max(p_r) = {worst} < 2**64 "
            f"({math.log2(2 ** 64 / worst):.1f} bits of accumulator "
            "headroom)",
        ))

    cap = aggregator.codec.capacity()
    need = bounds.max_abs() * s
    if not aggregator.headroom_ok(bounds.max_abs(), s):
        rep.add(Finding(
            "headroom", "error", "codec",
            f"worst-case aggregate {need:.3g} >= codec capacity "
            f"{cap:.3g} (frac_bits={aggregator.codec.frac_bits}): the "
            "encoded aggregate would saturate — shrink n_max/num_parts "
            "or the payload bounds",
        ))
    else:
        rep.add(Finding(
            "headroom", "info", "codec",
            f"worst-case aggregate {need:.3g} < capacity {cap:.3g} "
            f"({math.log2(cap / need):.1f} bits of codec headroom)",
        ))
    return rep


# -- mesh-axis lint --------------------------------------------------------


def _eqn_axis_names(eqn):
    names = []
    for key in ("axes", "axis_name", "axis"):
        val = eqn.params.get(key)
        if val is None:
            continue
        if not isinstance(val, (tuple, list)):
            val = (val,)
        names.extend(v for v in val if isinstance(v, str))
    return names


def lint_mesh_axes(closed_jaxpr, target: str,
                   report: AnalysisReport | None = None) -> AnalysisReport:
    """Every collective axis must be a protocol mesh axis, bound in-mesh."""
    from ..distributed.sharding import POD_AXIS, SHARE_AXIS

    allowed = {POD_AXIS, SHARE_AXIS}
    rep = report or AnalysisReport(target=target)
    seen = 0
    for where, eqn, sizes in iter_eqns(closed_jaxpr.jaxpr, target):
        for name in _eqn_axis_names(eqn):
            seen += 1
            if name not in allowed:
                rep.add(Finding(
                    "mesh-axes", "error", where,
                    f"collective over unknown axis '{name}' — protocol "
                    f"collectives run only over {sorted(allowed)}",
                ))
            elif sizes and name not in sizes:
                rep.add(Finding(
                    "mesh-axes", "error", where,
                    f"axis '{name}' is not bound by the enclosing "
                    f"shard_map mesh (mesh axes: {sorted(sizes)})",
                ))
            elif not sizes:
                rep.add(Finding(
                    "mesh-axes", "warning", where,
                    f"collective over '{name}' outside any shard_map "
                    "mesh in the traced graph: axis size unprovable",
                ))
    if seen:
        rep.add(Finding(
            "mesh-axes", "info", target,
            f"{seen} collective axis reference(s) checked",
        ))
    return rep


# -- obs purity lint -------------------------------------------------------

# the observability core: host-side bookkeeping the drivers import at
# load time — must work in jax-free processes and may never observe a
# device value (PUBLIC host floats only ride the existing readbacks)
OBS_CORE_MODULES = ("obs/trace.py", "obs/ledger.py", "obs/metrics.py")

# (module, enclosing function, imported module): the one sanctioned
# non-stdlib import — the lazy, failure-tolerant profiler hook
_OBS_IMPORT_EXCEPTIONS = {
    ("obs/trace.py", "_annotation", "jax.profiler"),
    ("obs/trace.py", "_annotation", "jax"),
}

_BANNED_IMPORT_ROOTS = {"jax", "jaxlib", "numpy", "np", "torch"}
# attribute/function names that pull data off a device or register a
# host callback — instrumentation observing through these would turn the
# obs layer into a hidden sync (and a taint sink)
_BANNED_NAMES = {
    "device_get", "block_until_ready", "pure_callback", "io_callback",
    "callback", "device_put", "asarray",
}


def _enclosing_functions(tree: ast.Module):
    """Map every node id to the name of its innermost enclosing def."""
    owner: dict[int, str] = {}

    def walk(node, fn):
        for ch in ast.iter_child_nodes(node):
            nfn = ch.name if isinstance(
                ch, (ast.FunctionDef, ast.AsyncFunctionDef)) else fn
            owner[id(ch)] = nfn
            walk(ch, nfn)

    walk(tree, "")
    return owner


def lint_obs_purity(report: AnalysisReport | None = None, *,
                    modules=None) -> AnalysisReport:
    """Pin the observability layer to pure host-side stdlib Python.

    ``modules`` (for tests) maps a display name to source text; default
    is the real obs core read from the package sources.
    """
    rep = report or AnalysisReport(target="obs-purity")
    if modules is None:
        pkg = pathlib.Path(__file__).resolve().parents[1]
        modules = {rel: (pkg / rel).read_text()
                   for rel in OBS_CORE_MODULES}
    for name, src in modules.items():
        tree = ast.parse(src)
        owner = _enclosing_functions(tree)
        clean = True
        for node in ast.walk(tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                mods = ([a.name for a in node.names]
                        if isinstance(node, ast.Import)
                        else [node.module or ""])
                for mod in mods:
                    root = mod.split(".")[0]
                    if root not in _BANNED_IMPORT_ROOTS:
                        continue
                    fn = owner.get(id(node), "")
                    if (name, fn, mod) in _OBS_IMPORT_EXCEPTIONS:
                        continue
                    clean = False
                    rep.add(Finding(
                        "obs-purity", "error", f"{name}:{node.lineno}",
                        f"import of '{mod}' in the obs core — the "
                        "tracer/ledger/metrics must stay stdlib-only "
                        "(jax-free processes import them; only the lazy "
                        "profiler hook may touch jax)",
                    ))
            elif isinstance(node, ast.Attribute) and \
                    node.attr in _BANNED_NAMES:
                fn = owner.get(id(node), "")
                if (name, fn, "jax") in _OBS_IMPORT_EXCEPTIONS:
                    continue  # inside the sanctioned profiler hook
                clean = False
                rep.add(Finding(
                    "obs-purity", "error", f"{name}:{node.lineno}",
                    f"'.{node.attr}' in the obs core — a device "
                    "materializer or host callback would make "
                    "instrumentation a hidden sync; obs records only "
                    "host floats the drivers already read back",
                ))
        if clean:
            rep.add(Finding(
                "obs-purity", "info", name,
                "stdlib-only, callback-free, no device materializers",
            ))
    return rep


# -- collective ownership lint ---------------------------------------------

# the jit-boundary wrappers only core/collective.py may invoke
_BOUNDARY_FNS = ("_protect_flat", "_reveal_flat", "_distributed_reveal")

# files (package-relative) where calling a boundary wrapper is sanctioned:
# the owner, the deliberate-leak audit fixture, and the raw kernel layer
BOUNDARY_CALL_EXEMPT = (
    "core/collective.py",
    "obs/audit.py",
    "kernels/ops.py",
)


def lint_collective_sites(report: AnalysisReport | None = None, *,
                          modules=None) -> AnalysisReport:
    """Every protect/reveal boundary CALL lives in core/collective.py.

    Walks the package sources (or ``modules``, a display-name -> source
    map, for tests) and flags any ``ast.Call`` whose callee — bare name
    or attribute — is one of the three boundary wrappers, outside the
    exempt files.  Re-exporting or importing the names is allowed (the
    compat surface in ``core/secure_agg.py`` does exactly that); only
    invoking them builds a second chain.
    """
    rep = report or AnalysisReport(target="collective-sites")
    if modules is None:
        pkg = pathlib.Path(__file__).resolve().parents[1]
        modules = {
            str(p.relative_to(pkg)): p.read_text()
            for p in sorted(pkg.rglob("*.py"))
        }
    calls = 0
    for name, src in modules.items():
        exempt = name in BOUNDARY_CALL_EXEMPT
        for node in ast.walk(ast.parse(src)):
            if not isinstance(node, ast.Call):
                continue
            callee = _call_callee(node)
            if callee not in _BOUNDARY_FNS:
                continue
            calls += 1
            if not exempt:
                rep.add(Finding(
                    "collective-sites", "error", f"{name}:{node.lineno}",
                    f"direct call to boundary wrapper '{callee}' outside "
                    "core/collective.py — drivers must route through "
                    "SecureCollective so the one chain stays the only "
                    "chain (ledger hooks, taint rules and byte telemetry "
                    "all anchor there)",
                ))
    rep.add(Finding(
        "collective-sites", "info", "collective-sites",
        f"{calls} boundary call site(s) scanned; owner + "
        f"{len(BOUNDARY_CALL_EXEMPT) - 1} sanctioned exceptions "
        "(obs/audit.py leak fixture, kernels/ops.py raw layer)",
    ))
    return rep


# -- Pallas kernel knob lint -----------------------------------------------


def lint_kernel_knobs(report: AnalysisReport | None = None, *,
                      knobs=None, d: int = 128, num_configs: int = 8,
                      num_residues: int = 2, threshold: int = 2,
                      num_points: int = 3) -> AnalysisReport:
    """Check the compiled-path blocking knobs without compiling.

    Reuses the ``kernels.tuning`` VMEM working-set model at the gate's
    deployment-shaped dims (lane-aligned d, a CV-sweep config batch, the
    default 3-center share layout).
    """
    from ..kernels.tuning import (VMEM_LIMIT_BYTES,
                                  validate_real_kernel_knobs)

    rep = report or AnalysisReport(target="kernel-knobs")
    try:
        results = validate_real_kernel_knobs(
            knobs, d=d, num_configs=num_configs,
            num_residues=num_residues, threshold=threshold,
            num_points=num_points,
        )
    except ValueError as e:
        rep.add(Finding(
            "kernel-knobs", "error", "kernels.tuning",
            f"compiled-path knob rejected: {e}",
        ))
        return rep
    for r in results:
        pct = 100.0 * r["vmem_bytes"] / VMEM_LIMIT_BYTES
        rep.add(Finding(
            "kernel-knobs", "info", r["kernel"],
            f"working set {r['vmem_bytes']} B = {pct:.1f}% of the "
            f"{VMEM_LIMIT_BYTES} B VMEM budget",
        ))
    return rep
