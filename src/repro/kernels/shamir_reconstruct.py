"""Pallas TPU kernel: Lagrange reconstruction + CRT decode for Shamir shares.

The mirror of ``shamir_poly_pallas``: reconstruction at x = 0 is a public
linear combination sum_i L_i(0) * share_i (mod p) — k fused modular
multiply-adds per element, fully data-parallel.  The Lagrange weights
L_i(0) depend only on the (public) evaluation points, so they are computed
host-side with Python big-ints and baked into the kernel as static uint32
constants; no in-graph modular inverses.

Field elements use the same 16-bit-limb ``mulmod31`` representation as
share generation (the VPU has no 64-bit multiply).  Both residues of the
CRT pair are processed in ONE kernel launch: the block carries a leading
residue axis and each residue's weights/modulus are unrolled statically.

With ``garner=True`` the kernel additionally fuses the first (and only
modular) step of CRT recombination — Garner's mixed-radix digit

    k = (r2 - r1) * p1^{-1}  (mod p2)

— which is pure 31-bit field math and therefore VPU-native.  The caller
finishes with ``x = r1 + p1 * k`` in uint64 outside the kernel (three
elementwise ops); everything superlinear stays in the kernel.

Grid: shares reshaped to (R, k, rows, 128) tiles by ops.py; one program per
(block_rows, 128) tile reconstructs all residues for its tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .shamir_poly import addmod, mulmod31

__all__ = ["shamir_reconstruct_pallas", "lagrange_weights_host"]

DEFAULT_BLOCK_ROWS = 256


def lagrange_weights_host(
    points, moduli
) -> tuple[tuple[int, ...], ...]:
    """L_i(0) per residue as nested Python-int tuples (static kernel args).

    ``points`` are the public 1-based share evaluation points; weights are
    computed with big-int modular inverses host-side (leaks nothing).
    """
    if len(set(points)) != len(points):
        raise ValueError(
            f"reconstruction points must be distinct, got {tuple(points)}"
        )
    out = []
    for p in moduli:
        row = []
        for i, xi in enumerate(points):
            num, den = 1, 1
            for j, xj in enumerate(points):
                if i == j:
                    continue
                num = (num * xj) % p
                den = (den * ((xj - xi) % p)) % p
            row.append((num * pow(den, p - 2, p)) % p)
        out.append(tuple(row))
    return tuple(out)


def _kernel(shares_ref, out_ref, *, lams, moduli, garner):
    num_residues = len(moduli)
    recs = []
    for r in range(num_residues):
        p = moduli[r]
        acc = mulmod31(shares_ref[r, 0], np.uint32(lams[r][0]), p)
        for i in range(1, len(lams[r])):
            term = mulmod31(shares_ref[r, i], np.uint32(lams[r][i]), p)
            acc = addmod(acc, term, p)
        recs.append(acc)
    if garner:
        # Garner digit for the CRT pair (p1 > p2): k = (r2 - r1)/p1 mod p2.
        p1, p2 = moduli
        assert p1 > p2, "garner layout assumes moduli sorted descending"
        inv_p1 = np.uint32(pow(p1 % p2, p2 - 2, p2))
        pp2 = np.uint32(p2)
        r1, r2 = recs
        r1m = jnp.where(r1 >= pp2, r1 - pp2, r1)  # r1 < p1 = p2 + (c2 - c1)
        diff = jnp.where(r2 >= r1m, r2 - r1m, r2 + (pp2 - r1m))
        out_ref[0, ...] = r1
        out_ref[1, ...] = mulmod31(diff, inv_p1, p2)
    else:
        for r in range(num_residues):
            out_ref[r, ...] = recs[r]


@functools.partial(
    jax.jit,
    static_argnames=("lams", "moduli", "garner", "block_rows", "interpret"),
)
def shamir_reconstruct_pallas(
    shares: jnp.ndarray,  # (R, k, rows, 128) uint32, reduced per residue
    lams: tuple[tuple[int, ...], ...],  # static public Lagrange weights
    moduli: tuple[int, ...],
    garner: bool = False,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (R, rows, 128) uint32: reconstructed residues, or with
    ``garner=True`` (R == 2 only) the pair (r1, garner digit k)."""
    num_residues, k, rows, lanes = shares.shape
    assert lanes == 128 and rows % block_rows == 0, "ops.py reshapes/pads"
    assert len(moduli) == num_residues and len(lams) == num_residues
    assert all(len(l) == k for l in lams)
    if garner and num_residues != 2:
        raise ValueError("garner fusion needs exactly 2 residues")
    grid = (rows // block_rows,)
    kernel = functools.partial(
        _kernel, lams=lams, moduli=moduli, garner=garner
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (num_residues, k, block_rows, 128), lambda i: (0, 0, i, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (num_residues, block_rows, 128), lambda i: (0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (num_residues, rows, 128), jnp.uint32
        ),
        interpret=interpret,
    )(shares)
