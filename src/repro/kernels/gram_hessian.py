"""Pallas TPU kernel: blocked X^T diag(w) X (the paper's Hessian hot spot).

The per-institution Hessian H_j = sum_i w_ii x_i x_i^T dominates local
compute (O(N d^2) vs O(N d) for everything else).  TPU mapping: stream X
through VMEM in (block_n, d) tiles, rescale rows by w on the VPU, and feed
the MXU with (d, block_n) @ (block_n, d) accumulating into a resident
(d, d) f32 tile.  d is padded to a multiple of 128 by ops.py so both MXU
matmul dimensions are hardware-aligned; block_n defaults to 512 rows, giving
a working set of  block_n*d + d*d + block_n  f32 words — < 2 MB for d <= 512,
comfortably inside the ~16 MB VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_hessian_pallas"]

DEFAULT_BLOCK_N = 512


def _kernel(x_ref, w_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    xw = x * w_ref[...].astype(jnp.float32)[:, None]
    # (d, block_n) @ (block_n, d) on the MXU, f32 accumulation
    o_ref[...] += jax.lax.dot_general(
        xw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_hessian_pallas(
    X: jnp.ndarray, w: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    """X: (N, d) with N % block_n == 0 and d % 128 == 0 (ops.py pads).

    interpret=True executes the kernel body on CPU (this container);
    on real TPU hardware pass interpret=False.
    """
    n, d = X.shape
    assert n % block_n == 0, "caller pads N"
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(X, w)
