"""Pallas TPU kernels: causal flash attention backward (dq / dk / dv).

Completes the kernel story started by flash_attention.py: the backward
recomputes block scores from (q, k) and the saved softmax statistics
(m, l) — residuals stay O(S·D) and the (bq, bk) score/ds tiles never
leave VMEM.  Two kernels, each with a sequential minor grid axis feeding
a VMEM accumulator:

  * ``_dq_kernel``   grid (B·H,  nq, nk): dq_i   += ds_ij @ k_j
  * ``_dkdv_kernel`` grid (B·KVH, nk, nq·G): dk_j += ds_ijᵀ @ q_i,
                     dv_j += p_ijᵀ @ do_i  — GQA group members are
                     walked in the minor axis so dk/dv accumulate the
                     group sum in scratch (no G× HBM partials).

where  p_ij = exp(q_i k_jᵀ·scale − m_i) / l_i  (causal-masked) and
``ds_ij = p_ij ∘ (do_i v_jᵀ − delta_i)``, delta = Σ_d do∘o precomputed
host-side (one cheap fused reduce).

ops.flash_attention_bwd is the jit'd wrapper; the oracle is
``jax.grad`` of ref.flash_attention (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_dq_pallas", "flash_dkdv_pallas"]

NEG_INF = -1e30


def _block_tiles(q, k, v, do, scale, qi_pos, kj_pos, m, linv, seq_len):
    """Shared per-(q block, k block) backward math.  All f32.

    q/do: (bq, D); k/v: (bk, D); m/linv: (bq, 1).
    Returns (p, ds): (bq, bk) each.
    """
    s = jax.lax.dot_general(
        q * scale, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    mask = (kj_pos <= qi_pos) & (kj_pos < seq_len)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - m) * linv
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return p, p * dp


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, linv_ref, delta_ref,
               o_ref, dq_scr, *, scale, block_q, block_k, nk, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    qi_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kj_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, pdp = _block_tiles(q, k, v, do, scale, qi_pos, kj_pos,
                              m_ref[0][:, None], linv_ref[0][:, None],
                              seq_len)
        ds = pdp - p * delta_ref[0][:, None]
        dq_scr[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = dq_scr[...].astype(o_ref.dtype)


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, linv_ref, delta_ref,
                 dk_ref, dv_ref, dk_scr, dv_scr, *, scale, block_q,
                 block_k, n_minor, group, seq_len):
    ki = pl.program_id(1)
    mi = pl.program_id(2)  # walks (g, q_block) pairs
    nq = n_minor // group
    qi = mi % nq

    @pl.when(mi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    qi_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kj_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, pdp = _block_tiles(q, k, v, do, scale, qi_pos, kj_pos,
                              m_ref[0][:, None], linv_ref[0][:, None],
                              seq_len)
        ds = pdp - p * delta_ref[0][:, None]
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dk_scr[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(mi == n_minor - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "group", "seq_len", "block_q", "block_k", "interpret"))
def flash_dq_pallas(q, k, v, do, m, linv, delta, group, seq_len,
                    block_q=512, block_k=512, interpret=True):
    """dq: q/do (B*H, S, D); k/v (B*KVH, S, D); m/linv/delta (B*H, S)."""
    BH, S, D = q.shape
    nq, nk = S // block_q, S // block_k
    scale = D**-0.5
    kernel = functools.partial(
        _dq_kernel, scale=scale, block_q=block_q, block_k=block_k, nk=nk,
        seq_len=seq_len)
    stat = pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            stat, stat, stat,
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, m, linv, delta)


@functools.partial(jax.jit, static_argnames=(
    "group", "seq_len", "block_q", "block_k", "interpret"))
def flash_dkdv_pallas(q, k, v, do, m, linv, delta, group, seq_len,
                      block_q=512, block_k=512, interpret=True):
    """dk, dv: shapes as in flash_dq_pallas; returns (B*KVH, S, D) pair."""
    BH, S, D = q.shape
    BKV = k.shape[0]
    nq, nk = S // block_q, S // block_k
    n_minor = nq * group
    scale = D**-0.5
    kernel = functools.partial(
        _dkdv_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_minor=n_minor, group=group, seq_len=seq_len)

    def q_idx(b, j, mi, g=group, nqq=nq):
        return (b * g + mi // nqq, mi % nqq, 0)

    def stat_idx(b, j, mi, g=group, nqq=nq):
        return (b * g + mi // nqq, mi % nqq)

    qspec = pl.BlockSpec((1, block_q, D), q_idx)
    stat = pl.BlockSpec((1, block_q), stat_idx)
    kv = pl.BlockSpec((1, block_k, D), lambda b, j, mi: (b, j, 0))
    out = pl.BlockSpec((1, block_k, D), lambda b, j, mi: (b, j, 0))
    dk, dv = pl.pallas_call(
        kernel,
        grid=(BKV, nk, n_minor),
        in_specs=[qspec, kv, kv, qspec, stat, stat, stat],
        out_specs=[out, out],
        out_shape=[jax.ShapeDtypeStruct((BKV, S, D), k.dtype),
                   jax.ShapeDtypeStruct((BKV, S, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, m, linv, delta)
    return dk, dv
