"""Block-size knobs for the ``interpret=False`` (compiled) kernel path.

CPU CI always runs the Pallas kernels through the interpreter, where block
sizes are semantically irrelevant (the interpreter materializes whole
operands).  On a real TPU backend the same ``block_n`` / ``block_rows``
statics decide the per-grid-step VMEM working set — a bad knob fails at
compile time with an opaque allocation error, long after the benchmark
has burned its setup work.

This module makes the knobs *inspectable*: one record per kernel family
with the default blocking the code actually uses, and a pure-arithmetic
working-set model (`vmem_bytes`) so ``validate_real_kernel_knobs`` can
reject a configuration BEFORE any compilation is attempted.  The
benchmarks expose it behind ``--real-kernels``; on the CPU CI mesh the
validation still runs (it is just arithmetic) and the flag is otherwise
a documented no-op — nothing about the interpreted kernels changes.

The model intentionally over-counts slightly (inputs + outputs resident
simultaneously, no double-buffering discount), so a passing knob has
real headroom.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "KernelKnobs",
    "DEFAULT_KNOBS",
    "VMEM_LIMIT_BYTES",
    "vmem_bytes",
    "validate_real_kernel_knobs",
]

# Per-core VMEM on current TPU generations is 16 MiB; leave the usual
# ~25% to the compiler for scratch/semaphores and validate against 12.
VMEM_LIMIT_BYTES = 12 * 1024 * 1024

LANES = 128


@dataclasses.dataclass(frozen=True)
class KernelKnobs:
    """One kernel family's compiled-path blocking statics."""

    kernel: str
    block_n: int = 0       # data rows per grid step (IRLS kernels)
    block_rows: int = 0    # (rows, 128) tile rows (protocol kernels)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# The defaults the kernels ship with: fused_irls.DEFAULT_BLOCK_N and the
# min(256, rows) flat blocking in kernels/ops.py._flat_blocking.
DEFAULT_KNOBS = {
    "fused_irls": KernelKnobs("fused_irls", block_n=512),
    "fused_irls_cv": KernelKnobs("fused_irls_cv", block_n=512),
    "shamir_protect_flat": KernelKnobs("shamir_protect_flat",
                                       block_rows=256),
    "shamir_reveal_flat": KernelKnobs("shamir_reveal_flat", block_rows=256),
}


def vmem_bytes(knobs: KernelKnobs, *, d: int = 128, num_configs: int = 1,
               num_residues: int = 2, threshold: int = 2,
               num_points: int = 2, payload_bytes: int = 8) -> int:
    """Per-grid-step working set, in bytes, from static shapes alone.

    * ``fused_irls``: one (block_n, d) payload tile + its float32 mirror
      + y/count rows, beta in, and the (d, d) + (d,) + scalar
      accumulators (float32).
    * ``fused_irls_cv``: the same tile shared across ``num_configs``
      betas/accumulators, plus the fold-id row.
    * ``shamir_protect_flat``: (block_rows, 128) float64 payload +
      (R, t-1, block_rows, 128) uint32 coefficients +
      (R, P, block_rows, 128) uint32 share output.
    * ``shamir_reveal_flat``: (P, R, block_rows, 128) uint32 shares +
      (block_rows, 128) float64 output.
    """
    k = knobs.kernel
    if k in ("fused_irls", "fused_irls_cv"):
        bn = knobs.block_n
        tile = bn * d * (payload_bytes + 4) + bn * (4 + 4)  # X, Xm, y, cnt
        per_cfg = d * d * 4 + 2 * d * 4 + 8  # H + g/beta + dev
        return tile + num_configs * per_cfg
    if k == "shamir_protect_flat":
        br = knobs.block_rows
        payload = br * LANES * 8
        coeffs = num_residues * (threshold - 1) * br * LANES * 4
        out = num_residues * num_points * br * LANES * 4
        return payload + coeffs + out
    if k == "shamir_reveal_flat":
        br = knobs.block_rows
        shares = num_points * num_residues * br * LANES * 4
        return shares + br * LANES * 8
    raise ValueError(f"unknown kernel family {k!r}")


def validate_real_kernel_knobs(knobs=None, *, d: int = 128,
                               num_configs: int = 1, num_residues: int = 2,
                               threshold: int = 2, num_points: int = 2,
                               vmem_limit_bytes: int = VMEM_LIMIT_BYTES):
    """Check every knob record against alignment + VMEM, pre-compilation.

    Returns one report dict per kernel (``{kernel, knob, vmem_bytes,
    vmem_limit_bytes, ok}``); raises ``ValueError`` on the first knob
    that could not compile at ``interpret=False`` — misaligned blocks or
    a working set past the limit.  Pure arithmetic: safe (and meaningful
    as documentation) on the CPU CI mesh where the interpreter would
    ignore the knobs entirely.
    """
    knobs = dict(DEFAULT_KNOBS if knobs is None else knobs)
    reports = []
    for name, kn in knobs.items():
        if kn.block_n:
            if kn.block_n % 8:
                raise ValueError(
                    f"{name}: block_n={kn.block_n} breaks the (8, 128) "
                    "float32 sublane tile"
                )
            if d % LANES:
                raise ValueError(
                    f"{name}: d={d} must be lane-aligned (multiple of "
                    f"{LANES}) for the compiled path — ops.py pads"
                )
        if kn.block_rows and kn.block_rows % 8:
            raise ValueError(
                f"{name}: block_rows={kn.block_rows} breaks the (8, 128) "
                "sublane tile"
            )
        need = vmem_bytes(
            kn, d=d, num_configs=num_configs, num_residues=num_residues,
            threshold=threshold, num_points=num_points,
        )
        ok = need <= vmem_limit_bytes
        if not ok:
            raise ValueError(
                f"{name}: working set {need} bytes exceeds VMEM budget "
                f"{vmem_limit_bytes} — shrink block_n/block_rows "
                f"({kn})"
            )
        reports.append({
            "kernel": name,
            "block_n": kn.block_n,
            "block_rows": kn.block_rows,
            "vmem_bytes": need,
            "vmem_limit_bytes": vmem_limit_bytes,
            "ok": ok,
        })
    return reports
