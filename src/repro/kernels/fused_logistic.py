"""Pallas TPU kernel: fused sigmoid / gradient / deviance / IRLS-weight pass.

A straightforward implementation reads X three times (for z = X beta, for
g = X^T (y - p), and for the weights feeding the Hessian).  At d = 84 the
arithmetic intensity is low, so the paper's local phase is HBM-bandwidth
bound on TPU; fusing everything into one streaming pass makes X's single
HBM->VMEM trip feed all four outputs.

Per (block_n, d) tile: z = X_b beta (MXU), p = sigmoid(z) (VPU),
g += X_b^T (y_b - p) (MXU), dev += -2 sum(y z - softplus(z)) (VPU reduce),
w_b = p (1 - p) written out per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_logistic_pallas"]

DEFAULT_BLOCK_N = 512


def _kernel(beta_ref, x_ref, y_ref, g_ref, dev_ref, w_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        dev_ref[...] = jnp.zeros_like(dev_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    y = y_ref[...].astype(jnp.float32)  # (block_n,)
    beta = beta_ref[...].astype(jnp.float32)  # (d,)
    z = x @ beta  # MXU (block_n,)
    p = jax.nn.sigmoid(z)
    resid = y - p
    g_ref[...] += x.T @ resid  # MXU (d,)
    # dev contribution: -2 (y z - log(1+e^z)); stable softplus
    softplus = jnp.logaddexp(0.0, z)
    dev_ref[...] += -2.0 * jnp.sum(y * z - softplus)
    w_ref[...] = p * (1.0 - p)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_logistic_pallas(
    beta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray,
    block_n: int = DEFAULT_BLOCK_N, interpret: bool = True,
):
    """Returns (g (d,), dev (), w (N,)).  N % block_n == 0, d % 128 == 0."""
    n, d = X.shape
    assert n % block_n == 0, "caller pads N"
    grid = (n // block_n,)
    g, dev, w = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((), lambda i: ()),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(beta, X, y)
    return g, dev, w
