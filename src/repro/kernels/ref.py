"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gram_hessian", "fused_irls", "shamir_shares",
           "flash_attention"]


def gram_hessian(X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """X^T diag(w) X in f32 accumulation — the paper's H_j hot spot."""
    Xw = X.astype(jnp.float32) * w.astype(jnp.float32)[:, None]
    return jnp.dot(Xw.T, X.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def fused_irls(beta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray,
               counts: jnp.ndarray | None = None):
    """Batched masked IRLS summaries oracle: (H (S,d,d), g (S,d), dev (S,)).

    X: (S, N_max, d); rows >= counts[s] are masked out of every sum.
    Computed in the input dtype (f64 in tests) — the kernel's f32 Gram
    accumulation is compared against this at matmul tolerance.
    """
    s_dim, n, _ = X.shape
    if counts is None:
        counts = jnp.full((s_dim,), n, jnp.int32)
    mask = (jnp.arange(n)[None, :] < counts[:, None]).astype(X.dtype)
    z = jnp.einsum("snd,d->sn", X, beta.astype(X.dtype))
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p) * mask
    H = jnp.einsum("sni,snj->sij", X * w[..., None], X)
    g = jnp.einsum("snd,sn->sd", X, (y - p) * mask)
    dev = -2.0 * jnp.sum(
        (y * z - jnp.logaddexp(0.0, z)) * mask, axis=1
    )
    return H, g, dev


def shamir_shares(secret: jnp.ndarray, coeffs: jnp.ndarray, num_shares: int,
                  modulus: int) -> jnp.ndarray:
    """Horner evaluation of q(x) = secret + sum_k coeffs[k] x^(k+1) at
    x = 1..num_shares, all mod ``modulus``.  uint64 arithmetic (products of
    reduced 31-bit values fit).  secret: (n,), coeffs: (t-1, n) uint64.
    Returns (num_shares, n) uint64.
    """
    p = jnp.uint64(modulus)
    t_minus_1 = coeffs.shape[0]

    def eval_at(x_int):
        x = jnp.uint64(x_int)
        acc = jnp.zeros_like(secret)
        for k in range(t_minus_1 - 1, -1, -1):
            acc = (acc * x + coeffs[k]) % p
        return (acc * x + secret) % p

    return jnp.stack([eval_at(j) for j in range(1, num_shares + 1)], axis=0)


def flash_attention(q, k, v):
    """Causal GQA attention oracle: q (B, S, H, D); k/v (B, S, KVH, D).

    Plain materialized-scores softmax in f32 — the ground truth for the
    Pallas flash kernel (which must match without ever materializing the
    S x S scores in HBM).
    """
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, S, KVH, G, D) * D**-0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf, k.astype(jnp.float32))
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
