"""Jit'd public wrappers around the Pallas kernels (padding, reshaping).

These are what the rest of the framework calls; each has the same signature
semantics as its pure-jnp oracle in ref.py.  ``interpret`` defaults to True
because this container is CPU-only; a TPU deployment flips it to False (the
kernels are written against TPU BlockSpec/VMEM semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .fused_irls import (
    fused_irls_cv_pallas,
    fused_irls_cv_sim,
    fused_irls_pallas,
    fused_irls_sim,
    gram_hessian_pallas,
)
from .shamir_poly import shamir_encode_share_pallas, shamir_poly_pallas
from .shamir_reconstruct import (
    lagrange_weights_host,
    shamir_reconstruct_pallas,
)

__all__ = ["gram_hessian", "fused_irls", "fused_irls_cv", "shamir_shares",
           "shamir_reconstruct", "shamir_protect_flat", "shamir_reveal_flat",
           "flash_attention", "flash_attention_bwd"]


def _pad_to(x, multiple, axis, value=0.0):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def gram_hessian(X, w, block_n: int = 512, interpret: bool = True):
    """X^T diag(w) X with automatic N/d padding (padded rows get w = 0)."""
    n, d = X.shape
    d_pad = int(np.ceil(d / 128) * 128)
    bn = min(block_n, int(np.ceil(n / 8) * 8)) if n < block_n else block_n
    Xp = _pad_to(_pad_to(X, bn, 0), 128, 1)
    wp = _pad_to(w, bn, 0)  # zero weight rows contribute nothing
    H = gram_hessian_pallas(Xp, wp, block_n=bn, interpret=interpret)
    return H[:d, :d]


def fused_irls(beta, X, y, counts=None, block_n: int = 512,
               interpret: bool = True, mxu_operand=None,
               simulate: bool | None = None):
    """Batched masked IRLS summaries: (H (S,d,d) f32, g (S,d), dev (S,)).

    X: (S, N_max, d); y: (S, N_max); counts: (S,) true (ragged) row counts,
    default N_max everywhere.  Pads N_max to a block multiple and d to 128
    (row masking makes the N padding exact; zero d-columns are benign and
    sliced off).  ``mxu_operand`` is the pre-cast f32 copy of X fed to the
    Gram matmul — pass it from a hot loop to cast once instead of per call;
    on TPU X is already f32 and the two operands are the same array.

    ``simulate`` (default: follows ``interpret``) evaluates the kernel's
    numerics contract as plain XLA ops instead of through the Pallas
    interpreter, whose per-program whole-operand copies dominate at
    production N on CPU.  Pass ``simulate=False`` with ``interpret=True``
    to force the real kernel through the interpreter (tests do, to pin
    kernel == simulation); on TPU (``interpret=False``) the compiled
    kernel always runs.
    """
    s_dim, n, d = X.shape
    if counts is None:
        counts = jnp.full((s_dim,), n, jnp.int32)
    if simulate is None:
        simulate = interpret
    if simulate and interpret:
        Xm = X.astype(jnp.float32) if mxu_operand is None else mxu_operand
        return fused_irls_sim(beta, X, Xm, y, counts.astype(jnp.int32))
    bn = min(block_n, int(np.ceil(n / 8) * 8)) if n < block_n else block_n
    Xp = _pad_to(_pad_to(X, bn, 1), 128, 2)
    if mxu_operand is None:
        Xmp = Xp.astype(jnp.float32)
    else:
        Xmp = _pad_to(_pad_to(mxu_operand, bn, 1), 128, 2)
    yp = _pad_to(y, bn, 1)
    betap = _pad_to(beta, 128, 0)
    H, g, dev = fused_irls_pallas(
        betap, Xp, Xmp, yp, counts.astype(jnp.int32),
        block_n=bn, interpret=interpret,
    )
    return H[:, :d, :d], g[:, :d], dev


def fused_irls_cv(betas, X, y, fold_ids, fold_of, counts=None,
                  block_n: int = 512, interpret: bool = True,
                  mxu_operand=None, simulate: bool | None = None):
    """Cross-validated batched IRLS summaries over a (config, institution)
    grid: (H (C,S,d,d) f32, g (C,S,d), dev_train (C,S), dev_val (C,S),
    correct_val (C,S), count_val (C,S)).

    ``betas`` is (C, d) — one iterate per (lambda x fold) path config;
    ``fold_ids`` is (S, N_max) int32 per-row fold assignment and
    ``fold_of`` (C,) the held-out fold per config (-1 = none, i.e. a
    full-data fit sharing the launch).  Same padding/``simulate``
    semantics as ``fused_irls``: rows beyond ``counts`` are masked
    regardless of their fold id, so N/d padding is exact.
    """
    s_dim, n, d = X.shape
    if counts is None:
        counts = jnp.full((s_dim,), n, jnp.int32)
    if simulate is None:
        simulate = interpret
    fold_ids = fold_ids.astype(jnp.int32)
    fold_of = fold_of.astype(jnp.int32)
    if simulate and interpret:
        Xm = X.astype(jnp.float32) if mxu_operand is None else mxu_operand
        return fused_irls_cv_sim(
            betas, X, Xm, y, counts.astype(jnp.int32), fold_ids, fold_of
        )
    bn = min(block_n, int(np.ceil(n / 8) * 8)) if n < block_n else block_n
    Xp = _pad_to(_pad_to(X, bn, 1), 128, 2)
    if mxu_operand is None:
        Xmp = Xp.astype(jnp.float32)
    else:
        Xmp = _pad_to(_pad_to(mxu_operand, bn, 1), 128, 2)
    yp = _pad_to(y, bn, 1)
    fidp = _pad_to(fold_ids, bn, 1)  # padded rows are row-masked anyway
    betap = _pad_to(betas, 128, 1)
    H, g, dtr, dva, acc, nva = fused_irls_cv_pallas(
        betap, Xp, Xmp, yp, counts.astype(jnp.int32), fidp, fold_of,
        block_n=bn, interpret=interpret,
    )
    return H[:, :, :d, :d], g[:, :, :d], dtr, dva, acc, nva


def shamir_shares(
    secret: jnp.ndarray,  # (n,) uint32 or uint64, reduced mod modulus
    coeffs: jnp.ndarray,  # (t-1, n) same dtype, reduced
    num_shares: int,
    modulus: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """(num_shares, n) shares; 32-bit limb kernel (TPU has no 64-bit VPU)."""
    assert modulus < 2**31, "kernel field elements must fit 31 bits"
    n = secret.shape[0]
    rows = max(1, int(np.ceil(n / 128)))
    block_rows = min(256, rows)
    rows_pad = int(np.ceil(rows / block_rows) * block_rows)
    total = rows_pad * 128

    def to_tile(x):
        flat = jnp.pad(x.astype(jnp.uint32), (0, total - n))
        return flat.reshape(rows_pad, 128)

    secret_t = to_tile(secret)
    coeffs_t = jnp.stack([to_tile(c) for c in coeffs], axis=0)
    out = shamir_poly_pallas(
        secret_t, coeffs_t, num_shares, modulus,
        block_rows=block_rows, interpret=interpret,
    )
    return out.reshape(num_shares, total)[:, :n].astype(secret.dtype)


def _flat_blocking(rows: int, interpret: bool) -> tuple[int, int]:
    """(rows_padded, block_rows) for an already (rows, 128)-tiled buffer.

    Interpret mode runs the grid as a Python loop, so a single whole-buffer
    program minimizes dispatch overhead; compiled TPU mode tiles to VMEM-
    sized blocks.
    """
    if interpret:
        return rows, rows
    block_rows = min(256, rows)
    rows_pad = int(np.ceil(rows / block_rows) * block_rows)
    return rows_pad, block_rows


def _pad_rows(x, rows_pad, axis):
    pad = rows_pad - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def shamir_protect_flat(
    buf: jnp.ndarray,  # (rows, 128) float payload tiles
    coeffs: jnp.ndarray,  # (R, t-1, rows, 128) uint32, reduced per residue
    num_shares: int,
    moduli: tuple[int, ...],
    frac_bits: int,
    interpret: bool = True,
    points: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    """Fused fixed-point encode + share of a flat buffer in ONE launch.

    Returns (len(points), R, rows, 128) uint32 — the holder axis leads so
    a Computation Center's slice is ``out[j]``.  ``points`` (default the
    full 1..num_shares fan-out) selects which public evaluation points are
    emitted: the in-SPMD ``secure_psum`` wire only transmits a threshold
    subset, so it asks for exactly those slices and the kernel never
    evaluates the rest.  Zero-padded tail rows encode to zero shares
    (benign through aggregate/reveal).
    """
    rows = buf.shape[0]
    rows_pad, block_rows = _flat_blocking(rows, interpret)
    bufp = _pad_rows(buf, rows_pad, 0)
    coeffsp = _pad_rows(coeffs, rows_pad, 2)
    if coeffsp.shape[1] == 0:  # t = 1: a zero high coefficient is a no-op
        coeffsp = jnp.zeros(
            (coeffs.shape[0], 1) + bufp.shape, dtype=jnp.uint32
        )
    out = shamir_encode_share_pallas(
        bufp, coeffsp, num_shares, tuple(moduli), frac_bits,
        block_rows=block_rows, interpret=interpret,
        points=tuple(points) if points is not None else None,
    )  # (R, len(points), rows_pad, 128)
    return jnp.swapaxes(out, 0, 1)[:, :, :rows]


def shamir_reveal_flat(
    shares: jnp.ndarray,  # (k, R, rows, 128) uint32 aggregate share slices
    points: tuple[int, ...],  # public 1-based holder ids of the k slices
    moduli: tuple[int, ...],
    frac_bits: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused Lagrange reconstruction + CRT decode -> (rows, 128) float64.

    The modular hot loop (k multiply-adds per residue + the Garner digit)
    runs in one kernel launch; only the final uint64 recombination and the
    fixed-point rescale are host-graph elementwise ops.
    """
    k, num_residues, rows = shares.shape[0], shares.shape[1], shares.shape[2]
    assert len(points) == k
    rows_pad, block_rows = _flat_blocking(rows, interpret)
    stacked = _pad_rows(jnp.swapaxes(shares, 0, 1), rows_pad, 2)
    lams = lagrange_weights_host(tuple(points), tuple(moduli))
    garner = num_residues == 2
    rec = shamir_reconstruct_pallas(
        stacked, lams, tuple(moduli), garner=garner,
        block_rows=block_rows, interpret=interpret,
    )[:, :rows]  # (R, rows, 128)
    modulus_product = 1
    for p in moduli:
        modulus_product *= p
    half = jnp.uint64((modulus_product - 1) // 2)
    if garner:
        # x = r1 + p1 * k_digit < p1*p2 < 2**62: exact in uint64
        x = rec[0].astype(jnp.uint64) + jnp.uint64(moduli[0]) * rec[1].astype(
            jnp.uint64
        )
    else:
        x = rec[0].astype(jnp.uint64)
    neg = -((jnp.uint64(modulus_product) - x).astype(jnp.int64))
    signed = jnp.where(x <= half, x.astype(jnp.int64), neg)
    return signed.astype(jnp.float64) / jnp.float64(1 << frac_bits)


def shamir_reconstruct(
    secret_shares: jnp.ndarray,  # (k, n) uint32/uint64, reduced mod modulus
    points,  # 1-based evaluation points of the k share rows
    modulus: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n,) reconstructed secret — per-residue mirror of shamir_shares."""
    assert modulus < 2**31, "kernel field elements must fit 31 bits"
    k, n = secret_shares.shape
    rows = max(1, int(np.ceil(n / 128)))
    rows_pad, block_rows = _flat_blocking(rows, interpret)
    total = rows_pad * 128
    flat = jnp.pad(secret_shares.astype(jnp.uint32), ((0, 0), (0, total - n)))
    tiles = flat.reshape(1, k, rows_pad, 128)
    lams = lagrange_weights_host(tuple(points), (modulus,))
    out = shamir_reconstruct_pallas(
        tiles, lams, (modulus,), garner=False,
        block_rows=block_rows, interpret=interpret,
    )
    return out.reshape(total)[:n].astype(secret_shares.dtype)


def flash_attention(q, k, v, block_q: int = 512, block_k: int = 512,
                    interpret: bool = True):
    """Causal GQA flash attention.  q: (B, S, H, D); k/v: (B, S, KVH, D).

    Pads S to a block multiple and D to 128; GQA mapped in the kernel
    index map (no KV broadcast in HBM).  Same semantics as
    ref.flash_attention.
    """
    from .flash_attention import flash_attention_pallas

    B, S, H, D = q.shape
    KVH = k.shape[2]
    group = H // KVH
    bq = min(block_q, max(8, int(np.ceil(S / 8) * 8)))
    bk = min(block_k, bq)
    s_pad = int(np.ceil(S / max(bq, bk)) * max(bq, bk))
    d_pad = int(np.ceil(D / 128) * 128)

    def prep(t, heads):
        t = jnp.pad(t, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))
        return jnp.moveaxis(t, 2, 1).reshape(B * heads, s_pad, d_pad)

    qp, kp, vp = prep(q, H), prep(k, KVH), prep(v, KVH)
    # padded D columns are zero => contribute nothing to scores; the
    # kernel normalizes with the true seq_len mask.
    scale_fix = (d_pad / D) ** 0.5  # kernel scales by d_pad**-0.5
    o, m, l = flash_attention_pallas(
        qp * scale_fix, kp, vp, group=group, seq_len=S,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    o = o.reshape(B, H, s_pad, d_pad)[:, :, :S, :D]
    return jnp.moveaxis(o, 1, 2)


def flash_attention_bwd(q, k, v, do, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True):
    """Flash backward: (dq, dk, dv) for causal GQA attention.

    q/do: (B, S, H, D); k/v: (B, S, KVH, D).  Re-runs the fwd kernel for
    (o, m, l) — in a fused deployment those come from the saved forward —
    then the dq and dk/dv kernels.  Oracle: jax.grad of ref.flash_attention.
    """
    from .flash_attention import flash_attention_pallas
    from .flash_attention_bwd import flash_dkdv_pallas, flash_dq_pallas

    B, S, H, D = q.shape
    KVH = k.shape[2]
    group = H // KVH
    bq = min(block_q, max(8, int(np.ceil(S / 8) * 8)))
    bk = min(block_k, bq)
    s_pad = int(np.ceil(S / max(bq, bk)) * max(bq, bk))
    d_pad = int(np.ceil(D / 128) * 128)

    def prep(t, heads):
        t = jnp.pad(t, ((0, 0), (0, s_pad - S), (0, 0), (0, d_pad - D)))
        return jnp.moveaxis(t, 2, 1).reshape(B * heads, s_pad, d_pad)

    scale_fix = (d_pad / D) ** 0.5
    qp = prep(q, H) * scale_fix
    kp, vp, dop = prep(k, KVH), prep(v, KVH), prep(do, H)
    o, m, l = flash_attention_pallas(
        qp, kp, vp, group=group, seq_len=S, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    linv = 1.0 / jnp.maximum(l, 1e-30)
    delta = jnp.sum(dop.astype(jnp.float32) * o.astype(jnp.float32), -1)
    args = (qp, kp, vp, dop, m, linv, delta)
    kw = dict(group=group, seq_len=S, block_q=bq, block_k=bk,
              interpret=interpret)
    dq = flash_dq_pallas(*args, **kw)
    dk, dv = flash_dkdv_pallas(*args, **kw)

    def unprep(t, heads):
        t = t.reshape(B, heads, s_pad, d_pad)[:, :, :S, :D]
        return jnp.moveaxis(t, 1, 2)

    # undo the d-pad rescale on dq (dq carries one factor of scale)
    return (unprep(dq, H) * scale_fix, unprep(dk, KVH), unprep(dv, KVH))
