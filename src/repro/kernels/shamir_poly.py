"""Pallas TPU kernel: Shamir share generation in 32-bit limbs.

Share generation evaluates one random degree-(t-1) polynomial per secret
element at x = 1..w — t-1 fused modular multiply-adds per element, fully
data-parallel.  The TPU adaptation is the interesting part: the VPU has no
64-bit integer multiply, so the uint64 reference math does not port.  We
represent reduced field elements (< 2**31) in uint32 and implement

    mulmod(a, b) mod p,  p = 2**31 - c  (pseudo-Mersenne; c = 1 or 19)

with 16-bit limb decomposition: a = a0 + a1*2**16, b = b0 + b1*2**16, all
four partial products < 2**32 fit uint32, and each partial is folded with
x mod p = (x & (2**31-1)) + c * (x >> 31)  (one conditional subtract after).
Multiplication by the Horner point x <= w (small public constant) only needs
the b1 < 2**15 case, keeping every intermediate in range.  This replaces the
big-int field arithmetic a CPU implementation would use — same field, same
security, MXU/VPU-native word sizes.

Grid: secrets reshaped to (rows, 128) lanes by ops.py; one program per
(block_rows, 128) tile computes all w shares for its tile (w is small and
static).  Working set: (t-1 + 1 + w) * block_rows * 128 uint32 words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "shamir_poly_pallas",
    "shamir_encode_share_pallas",
    "mulmod31",
    "addmod",
]

DEFAULT_BLOCK_ROWS = 256
MASK31 = np.uint32(2**31 - 1)  # numpy scalar: safe inside pallas kernels


def addmod(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(a + b) mod p for reduced uint32 inputs (sum < 2**32)."""
    s = a + b
    pp = np.uint32(p)
    return jnp.where(s >= pp, s - pp, s)


def _fold(x: jnp.ndarray, p: int, c: int) -> jnp.ndarray:
    """x mod p for x < 2**32, p = 2**31 - c: fold high bit with weight c."""
    r = (x & MASK31) + np.uint32(c) * (x >> np.uint32(31))
    pp = np.uint32(p)
    r = jnp.where(r >= pp, r - pp, r)  # r < 2**31 + 19*1 after one fold
    return jnp.where(r >= pp, r - pp, r)


def mulmod31(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(a * b) mod p via 16-bit limbs, p = 2**31 - c, a,b reduced < p.

    a0b0 < 2**32, cross terms < 2**31 each; shifts are folded with the
    pseudo-Mersenne identity 2**31 === c (mod p):
      2**16 * x mod p and 2**32 * x mod p = c * (2 * x) ... handled by
      iterated folding of (x << 16).
    """
    c = 2**31 - p
    a0 = a & np.uint32(0xFFFF)
    a1 = a >> np.uint32(16)  # < 2**15
    b0 = b & np.uint32(0xFFFF)
    b1 = b >> np.uint32(16)  # < 2**15

    def shl16_mod(x):
        # (x * 2**16) mod p for reduced x < p: split off top 15 bits
        hi = x >> np.uint32(15)  # < 2**16
        lo = x & np.uint32(0x7FFF)  # < 2**15
        # x*2**16 = hi*2**31 + lo*2**16  ===  hi*c + lo*2**16 (mod p)
        return _fold((lo << np.uint32(16)) + np.uint32(c) * hi, p, c)

    t00 = _fold(a0 * b0, p, c)  # < 2**32 -> reduced
    t01 = _fold(a0 * b1, p, c)
    t10 = _fold(a1 * b0, p, c)
    t11 = _fold(a1 * b1, p, c)
    mid = shl16_mod(addmod(t01, t10, p))
    hi = shl16_mod(shl16_mod(t11))
    return addmod(addmod(t00, mid, p), hi, p)


def _kernel(secret_ref, coeffs_ref, out_ref, *, num_shares, p):
    t_minus_1 = coeffs_ref.shape[0]
    secret = secret_ref[...]
    for j in range(1, num_shares + 1):
        x = np.uint32(j)
        acc = jnp.zeros_like(secret)
        for k in range(t_minus_1 - 1, -1, -1):
            acc = addmod(mulmod31(acc, x, p), coeffs_ref[k], p)
        out_ref[j - 1, ...] = addmod(mulmod31(acc, x, p), secret, p)


@functools.partial(
    jax.jit, static_argnames=("num_shares", "modulus", "block_rows", "interpret")
)
def shamir_poly_pallas(
    secret: jnp.ndarray,  # (rows, 128) uint32, reduced mod modulus
    coeffs: jnp.ndarray,  # (t-1, rows, 128) uint32, reduced
    num_shares: int,
    modulus: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns (num_shares, rows, 128) uint32 shares."""
    rows, lanes = secret.shape
    assert lanes == 128 and rows % block_rows == 0, "ops.py reshapes/pads"
    t_minus_1 = coeffs.shape[0]
    grid = (rows // block_rows,)
    kernel = functools.partial(_kernel, num_shares=num_shares, p=modulus)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec((t_minus_1, block_rows, 128), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (num_shares, block_rows, 128), lambda i: (0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (num_shares, rows, 128), jnp.uint32
        ),
        interpret=interpret,
    )(secret, coeffs)


def _float_mod(s_abs, neg, p: int):
    """|s| (float, integer-valued, < 2**62) mod p, sign-corrected, as uint32.

    Exploits that a rounded float has at most mantissa-many significant
    bits: splitting at 2**31 via divide/floor/multiply-subtract is EXACT
    (both halves inherit <= mantissa bits), giving hi < 2**30 and
    lo < 2**31 that fit uint32, then 2**31 === c (mod p) folds the split.
    """
    c = 2**31 - p
    hi_f = jnp.floor(s_abs * (2.0**-31))
    lo_f = s_abs - hi_f * (2.0**31)
    hi = hi_f.astype(jnp.uint32)
    lo = lo_f.astype(jnp.uint32)
    m = addmod(_fold(lo, p, c), mulmod31(hi, np.uint32(c), p), p)
    pp = np.uint32(p)
    return jnp.where(neg & (m > 0), pp - m, m)


def _encode_share_kernel(
    x_ref, coeffs_ref, out_ref, *, points, moduli, scale, max_signed
):
    t_minus_1 = coeffs_ref.shape[1]
    x = x_ref[...]
    s = jnp.clip(jnp.round(x * scale), -float(max_signed), float(max_signed))
    neg = s < 0
    s_abs = jnp.abs(s)
    for r, p in enumerate(moduli):
        secret = _float_mod(s_abs, neg, p)
        for out_idx, j in enumerate(points):
            xj = np.uint32(j)
            acc = jnp.zeros_like(secret)
            for k in range(t_minus_1 - 1, -1, -1):
                acc = addmod(mulmod31(acc, xj, p), coeffs_ref[r, k], p)
            out_ref[r, out_idx, ...] = addmod(
                mulmod31(acc, xj, p), secret, p
            )


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_shares", "moduli", "frac_bits", "block_rows", "interpret",
        "points",
    ),
)
def shamir_encode_share_pallas(
    x: jnp.ndarray,  # (rows, 128) float32/float64 payload
    coeffs: jnp.ndarray,  # (R, t-1, rows, 128) uint32, reduced per residue
    num_shares: int,
    moduli: tuple[int, ...],
    frac_bits: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
    points: tuple[int, ...] | None = None,
) -> jnp.ndarray:
    """Fused fixed-point encode + Horner share evaluation, all residues in
    one launch.  Returns (R, len(points), rows, 128) uint32 — the uint64
    encoded tensor of the two-stage path never materializes.

    ``points`` (default 1..num_shares) are the public evaluation points to
    emit, statically unrolled like the full-fan-out loop — the sharded
    ``secure_psum`` wire only ever transmits a threshold subset of slices,
    so it evaluates only those, skipping (w - t)/w of the Horner work.
    Slice j of the output is the share at ``points[j]`` on every path.

    Equivalent to ``FixedPointCodec.encode`` followed by the share kernel:
    s = round(x * 2**frac_bits) clipped to +-max_signed, lifted to residues
    via the exact float split in ``_float_mod`` (float64 payloads are exact
    to the codec's full 61-bit range; float32 payloads to 2**24 * scale —
    on-TPU deployments feed f32 and rely on the same contract).
    """
    rows, lanes = x.shape
    assert lanes == 128 and rows % block_rows == 0, "ops.py reshapes/pads"
    if points is None:
        points = tuple(range(1, num_shares + 1))
    assert all(1 <= p <= num_shares for p in points)
    num_residues, t_minus_1 = coeffs.shape[0], coeffs.shape[1]
    assert len(moduli) == num_residues
    max_signed = 1
    for p in moduli:
        max_signed *= p
    max_signed = (max_signed - 1) // 2
    grid = (rows // block_rows,)
    kernel = functools.partial(
        _encode_share_kernel,
        points=points,
        moduli=moduli,
        scale=float(1 << frac_bits),
        max_signed=max_signed,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, 128), lambda i: (i, 0)),
            pl.BlockSpec(
                (num_residues, t_minus_1, block_rows, 128),
                lambda i: (0, 0, i, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (num_residues, len(points), block_rows, 128),
            lambda i: (0, 0, i, 0),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (num_residues, len(points), rows, 128), jnp.uint32
        ),
        interpret=interpret,
    )(x, coeffs)
