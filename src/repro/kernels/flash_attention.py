"""Pallas TPU kernel: causal flash attention forward (LM-side hot spot).

The §Perf analysis (EXPERIMENTS.md) shows the remaining memory-bound bytes
in dense train/prefill cells are the per-block score tensors the XLA-level
flash path still materializes to HBM ((B, KVH, G, Q, bk) f32 fusions).
This kernel keeps them VMEM-resident: one (block_q, D) query tile is held
against streamed (block_k, D) K/V tiles; scores, the online-softmax state
(m, l) and the output accumulator never touch HBM.  HBM traffic becomes
exactly q + k + v + o — the flash-attention bound.

Mapping:
  grid = (B*H, S/block_q, S/block_k); the k axis is the minor (sequential)
  grid dimension, so VMEM scratch (m, l, acc) persists across it — the
  standard Pallas flash pattern.  GQA is handled in the index map: query
  head h reads KV head h // group from the (B*KVH, S, D) K/V arrays — no
  broadcast copies in HBM.  Causal masking uses global positions; fully
  masked (future) K blocks are skipped with pl.when.

VMEM budget at (block_q, block_k, D) = (512, 512, 128), f32 accumulators:
q 256 KB + k/v 2x128 KB (bf16) + acc 256 KB + scores 1 MB  ~<2 MB, well
inside ~16 MB with double buffering.

ops.flash_attention is the jit'd wrapper (padding, GQA reshape);
ref.flash_attention is the pure-jnp oracle; validated in interpret mode
across shapes/dtypes in tests/test_kernels_flash.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, m_scr, l_scr,
            acc_scr, *, scale, block_q, block_k, nk, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    # skip fully-future K blocks (strictly above the causal diagonal)
    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale     # (bq, D)
        k = k_ref[0].astype(jnp.float32)             # (bk, D)
        v = v_ref[0].astype(jnp.float32)             # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                            # (bq, bk)
        mask = (k_pos <= q_pos) & (k_pos < seq_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)
        m_ref[0] = m_scr[...][:, 0]
        l_ref[0] = l_scr[...][:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("group", "block_q", "block_k", "seq_len", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,   # (B*H, S_pad, D)
    k: jnp.ndarray,   # (B*KVH, S_pad, D)
    v: jnp.ndarray,   # (B*KVH, S_pad, D)
    group: int,       # H // KVH
    seq_len: int,     # true (unpadded) length, for masking
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Causal flash attention -> (o, m, l).  Caller pads S to a block
    multiple and D to 128 (ops.py).  interpret=True executes on CPU; the
    (m, l) softmax statistics feed the backward kernels."""
    BH, S, D = q.shape
    assert S % block_q == 0 and S % block_k == 0, "caller pads S"
    nq, nk = S // block_q, S // block_k
    scale = D**-0.5
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, nk=nk,
        seq_len=seq_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),   # l (running denom)
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
