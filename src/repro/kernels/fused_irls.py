"""Pallas TPU kernel: the paper's full per-institution IRLS local phase,
batched over institutions, in ONE streaming pass over X.

Per Newton iteration every institution j computes (Algorithm 1, steps 4-6)

    z = X_j beta,  p = sigmoid(z),  w = p (1 - p)
    H_j = X_j^T diag(w) X_j          (Eq. 4, O(N d^2) — the hot term)
    g_j = X_j^T (y_j - p)            (Eq. 5)
    dev_j = -2 sum(y z - softplus z) (Eq. 6)

The pre-fusion pipeline ran three separate passes (z/g/dev kernel, then a
weighted-Gram kernel re-reading X with w round-tripped through HBM) and a
Python loop over institutions.  Here one kernel with grid (S, N/block_n)
streams each institution's (block_n, d) tile through VMEM exactly once and
emits all three summaries for all S institutions; the IRLS weights live
only in VMEM registers between the sigmoid and the Gram update — they are
never written to HBM.

Ragged institutions are padded to a common N_max and masked inside the
kernel with per-institution row counts, so one launch covers uneven
partition sizes (the paper's horizontal split is never exactly even).

Precision contract: the Gram/Hessian accumulates in float32 on the MXU
(`mxu_ref` is a separate operand so a CPU/interpret profile can keep the
main payload in float64 — on TPU both refs alias one f32 array).  The
gradient/deviance accumulate in the payload dtype.  H only preconditions
the Newton step — the fixed point solves g(beta) = lam beta — so f32 H
changes the trajectory, not the answer; g/dev precision is what bounds the
final beta and the deviance-based convergence test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_irls_pallas", "fused_irls_sim", "fused_irls_cv_pallas",
           "fused_irls_cv_sim", "gram_hessian_pallas"]

DEFAULT_BLOCK_N = 512


def _irls_kernel(beta_ref, x_ref, xm_ref, y_ref, cnt_ref,
                 h_ref, g_ref, dev_ref, *, block_n):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        g_ref[...] = jnp.zeros_like(g_ref)
        dev_ref[...] = jnp.zeros_like(dev_ref)

    x = x_ref[0]  # (block_n, d) payload dtype
    y = y_ref[0]  # (block_n,)
    beta = beta_ref[...].astype(x.dtype)  # (d,)
    # ragged mask: absolute row id vs this institution's true row count
    row = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, 1), 0
    )[:, 0]
    valid = (row < cnt_ref[0]).astype(x.dtype)  # (block_n,)

    z = x @ beta  # (block_n,)
    p = jax.nn.sigmoid(z)
    w = (p * (1.0 - p)) * valid  # IRLS weights: VMEM-resident only
    resid = (y - p) * valid
    g_ref[0] += x.T @ resid
    softplus = jnp.logaddexp(jnp.zeros_like(z), z)
    dev_ref[0] += -2.0 * jnp.sum((y * z - softplus) * valid)
    # MXU Gram update in f32; weights fold into the left operand
    xm = xm_ref[0]  # (block_n, d) float32
    h_ref[0] += jax.lax.dot_general(
        xm * w.astype(jnp.float32)[:, None], xm,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_irls_pallas(
    beta: jnp.ndarray,  # (d,)
    X: jnp.ndarray,  # (S, N_max, d) payload dtype (f32 on TPU)
    Xm: jnp.ndarray,  # (S, N_max, d) float32 MXU operand (== X on TPU)
    y: jnp.ndarray,  # (S, N_max) payload dtype
    counts: jnp.ndarray,  # (S,) int32 true row counts (<= N_max)
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """All-institution summaries in one launch.

    Returns (H (S, d, d) f32, g (S, d), dev (S,)); g/dev in X.dtype.
    N_max % block_n == 0 and d % 128 == 0 (ops.py pads); rows >= counts[s]
    are masked out, so tail padding may hold anything.
    """
    s_dim, n, d = X.shape
    assert n % block_n == 0, "caller pads N_max"
    grid = (s_dim, n // block_n)
    kernel = functools.partial(_irls_kernel, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda s, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n), lambda s, i: (s, i)),
            pl.BlockSpec((1,), lambda s, i: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, d), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, d), lambda s, i: (s, 0)),
            pl.BlockSpec((1,), lambda s, i: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, d, d), jnp.float32),
            jax.ShapeDtypeStruct((s_dim, d), X.dtype),
            jax.ShapeDtypeStruct((s_dim,), X.dtype),
        ],
        interpret=interpret,
    )(beta, X, Xm, y, counts)


@jax.jit
def fused_irls_sim(beta, X, Xm, y, counts):
    """Functional simulation of ``fused_irls_pallas`` — same numerics
    contract (f32 Gram accumulation from the MXU operand, g/dev in the
    payload dtype, row masks), evaluated as plain XLA ops.

    This is what ``interpret=True`` callers run at production sizes: the
    Pallas interpreter emulates every grid program with whole-operand
    copies, which at (S, 2e5, d) costs ~6x the arithmetic itself on CPU.
    The blocked kernel remains the compiled TPU path; tests pin the two
    against each other (they differ only in f32 summation order).

    One deliberate upgrade over the TPU kernel: with a float32 payload
    the kernel accumulates g/dev in f32 (the hardware dtype); the sim
    always accumulates them in f64 (free on CPU via
    ``preferred_element_type``), which keeps the secure protocol's
    fixed-point codec the dominant error term.  The kernel == sim
    pinning test therefore runs with an f64 payload, where the two
    contracts coincide.

    Two contraction styles, each where the CPU backend is fastest: the
    O(N d) z/g/dev reductions run batched (or, for the mixed
    f32-operand/f64-accumulation case, unrolled — the batched form hits
    a ~10x-slow generic emitter), while the O(N d^2) Gram unrolls into
    per-institution 2D contractions mirroring the kernel's (S, blocks)
    grid; the batched (S, N, d) dot emitter is ~40% slower with much
    higher variance.  The 3-operand einsum folds the IRLS row scaling
    into the Gram contraction instead of materializing a scaled copy of
    Xm.
    """
    s_dim, n = X.shape[0], X.shape[1]
    mask = (
        jnp.arange(n, dtype=jnp.int32)[None, :] < counts[:, None]
    ).astype(jnp.float64)
    if X.dtype == jnp.float32:
        z = jax.lax.dot_general(
            X, beta.astype(jnp.float32), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float64,
        )
    else:
        z = jnp.einsum("snd,d->sn", X, beta.astype(X.dtype))
    p = jax.nn.sigmoid(z)
    w32 = ((p * (1.0 - p)) * mask).astype(jnp.float32)
    H = jnp.stack([
        jnp.einsum(
            "n,ni,nj->ij", w32[j], Xm[j], Xm[j],
            preferred_element_type=jnp.float32,
        )
        for j in range(s_dim)
    ])
    resid = (y - p) * mask
    if X.dtype == jnp.float32:
        r32 = resid.astype(jnp.float32)
        g = jnp.stack([
            jax.lax.dot_general(
                r32[j], X[j], (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float64,
            )
            for j in range(s_dim)
        ])
    else:
        g = jnp.einsum("snd,sn->sd", X, resid)
    dev = -2.0 * jnp.sum((y * z - jnp.logaddexp(0.0, z)) * mask, axis=1)
    return H, g, dev


# -- cross-validated variant: fold masks composed into the row masks ---------
#
# The selection subsystem advances C = (lambda x fold) path points at once.
# Config c trains on every row whose fold id differs from fold_of[c] and
# evaluates held-out deviance/accuracy on the rows it excludes — the fold
# mask composes with the ragged row-count mask INSIDE the kernel, so one
# streaming pass over the same packed (S, N_max, d) batch emits train-fold
# summaries AND validation metrics for every (config, institution) pair
# without ever materializing per-fold repacks of X.  fold_of[c] == -1
# means "no held-out fold" (a full-data path fit riding in the same batch:
# fold ids are never negative, so the val mask is empty and the train mask
# reduces to the plain row mask).

def _irls_cv_kernel(beta_ref, x_ref, xm_ref, y_ref, cnt_ref, fid_ref,
                    fold_ref, h_ref, g_ref, dtr_ref, dva_ref, acc_ref,
                    nva_ref, *, block_n):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        g_ref[...] = jnp.zeros_like(g_ref)
        dtr_ref[...] = jnp.zeros_like(dtr_ref)
        dva_ref[...] = jnp.zeros_like(dva_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        nva_ref[...] = jnp.zeros_like(nva_ref)

    x = x_ref[0]  # (block_n, d) payload dtype
    y = y_ref[0]  # (block_n,)
    beta = beta_ref[0].astype(x.dtype)  # (d,) — this config's iterate
    row = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, 1), 0
    )[:, 0]
    valid = row < cnt_ref[0]  # ragged row mask
    hold = jnp.logical_and(valid, fid_ref[0] == fold_ref[0])
    tmask = jnp.logical_and(valid, jnp.logical_not(hold)).astype(x.dtype)
    vmask = hold.astype(x.dtype)

    z = x @ beta
    p = jax.nn.sigmoid(z)
    w = (p * (1.0 - p)) * tmask  # train-fold IRLS weights, VMEM-only
    g_ref[0, 0] += x.T @ ((y - p) * tmask)
    ll = y * z - jnp.logaddexp(jnp.zeros_like(z), z)
    dtr_ref[0, 0] += -2.0 * jnp.sum(ll * tmask)
    dva_ref[0, 0] += -2.0 * jnp.sum(ll * vmask)
    correct = (z > 0.0) == (y > 0.5)
    acc_ref[0, 0] += jnp.sum(jnp.where(correct, vmask, 0.0))
    nva_ref[0, 0] += jnp.sum(vmask)
    xm = xm_ref[0]  # (block_n, d) float32 MXU operand
    h_ref[0, 0] += jax.lax.dot_general(
        xm * w.astype(jnp.float32)[:, None], xm,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_irls_cv_pallas(
    betas: jnp.ndarray,  # (C, d) one iterate per path config
    X: jnp.ndarray,  # (S, N_max, d) payload dtype (f32 on TPU)
    Xm: jnp.ndarray,  # (S, N_max, d) float32 MXU operand (== X on TPU)
    y: jnp.ndarray,  # (S, N_max) payload dtype
    counts: jnp.ndarray,  # (S,) int32 true row counts (<= N_max)
    fold_ids: jnp.ndarray,  # (S, N_max) int32 per-row fold assignment
    fold_of: jnp.ndarray,  # (C,) int32 held-out fold per config (-1: none)
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Every (config, institution) train summary + held-out metric in one
    launch: H (C, S, d, d) f32, g (C, S, d), dev_train (C, S),
    dev_val (C, S), correct_val (C, S), count_val (C, S); g and the
    scalar reductions in X.dtype.  Grid (C, S, N/block_n): X streams
    through VMEM once per config with the fold mask applied in-register.
    """
    c_dim = betas.shape[0]
    s_dim, n, d = X.shape
    assert n % block_n == 0, "caller pads N_max"
    grid = (c_dim, s_dim, n // block_n)
    kernel = functools.partial(_irls_cv_kernel, block_n=block_n)
    scalar = lambda: jax.ShapeDtypeStruct((c_dim, s_dim), X.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda c, s, i: (c, 0)),
            pl.BlockSpec((1, block_n, d), lambda c, s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n, d), lambda c, s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n), lambda c, s, i: (s, i)),
            pl.BlockSpec((1,), lambda c, s, i: (s,)),
            pl.BlockSpec((1, block_n), lambda c, s, i: (s, i)),
            pl.BlockSpec((1,), lambda c, s, i: (c,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d, d), lambda c, s, i: (c, s, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda c, s, i: (c, s, 0)),
            pl.BlockSpec((1, 1), lambda c, s, i: (c, s)),
            pl.BlockSpec((1, 1), lambda c, s, i: (c, s)),
            pl.BlockSpec((1, 1), lambda c, s, i: (c, s)),
            pl.BlockSpec((1, 1), lambda c, s, i: (c, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_dim, s_dim, d, d), jnp.float32),
            jax.ShapeDtypeStruct((c_dim, s_dim, d), X.dtype),
            scalar(), scalar(), scalar(), scalar(),
        ],
        interpret=interpret,
    )(betas, X, Xm, y, counts, fold_ids, fold_of)


@jax.jit
def fused_irls_cv_sim(betas, X, Xm, y, counts, fold_ids, fold_of):
    """Functional simulation of ``fused_irls_cv_pallas`` as plain XLA ops
    — the CPU/interpret execution shape at production N, mirroring
    ``fused_irls_sim``'s contracts: f32 Gram accumulation from the MXU
    operand, f64 gradient/deviance accumulation regardless of payload
    dtype, fold∘row masks identical to the kernel.

    Contraction styles follow the same CPU-emitter measurements as the
    non-CV sim: the O(C S N d) z/g reductions run as clean 2D gemms
    (z batched over configs, g unrolled per institution), while the
    O(C S N d^2) Gram — the flop wall — runs as a ``lax.map`` over the
    config axis of per-institution 2D contractions, so the traced graph
    stays small at any path length while each contraction hits the fast
    gemm emitter.
    """
    s_dim, n = X.shape[0], X.shape[1]
    row_ok = jnp.arange(n, dtype=jnp.int32)[None, :] < counts[:, None]
    on_fold = fold_ids[None] == fold_of[:, None, None]  # (C, S, N)
    hold = row_ok[None] & on_fold
    tmask = (row_ok[None] & ~on_fold).astype(jnp.float64)
    vmask = hold.astype(jnp.float64)
    z = jax.lax.dot_general(
        X, betas.astype(X.dtype), (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float64,
    )  # (S, N, C)
    z = jnp.moveaxis(z, -1, 0)  # (C, S, N)
    p = jax.nn.sigmoid(z)
    ll = y[None] * z - jnp.logaddexp(0.0, z)
    dev_tr = -2.0 * jnp.sum(ll * tmask, axis=2)
    dev_va = -2.0 * jnp.sum(ll * vmask, axis=2)
    acc_va = jnp.sum(
        jnp.where((z > 0.0) == (y[None] > 0.5), vmask, 0.0), axis=2
    )
    n_va = jnp.sum(vmask, axis=2)
    resid = (y[None] - p) * tmask  # (C, S, N) f64
    g = jnp.stack([
        jax.lax.dot_general(
            resid[:, s], X[s], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float64,
        )
        for s in range(s_dim)
    ], axis=1)  # (C, S, d)
    w32 = ((p * (1.0 - p)) * tmask).astype(jnp.float32)

    def gram_one_config(w_c):  # (S, N) f32 -> (S, d, d) f32
        return jnp.stack([
            jax.lax.dot_general(
                Xm[s] * w_c[s][:, None], Xm[s],
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            for s in range(s_dim)
        ])

    H = jax.lax.map(gram_one_config, w32)  # (C, S, d, d)
    return H, g, dev_tr, dev_va, acc_va, n_va


# -- explicit-weight Gram (legacy public op) ---------------------------------
def _gram_kernel(x_ref, w_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    xw = x * w_ref[...].astype(jnp.float32)[:, None]
    o_ref[...] += jax.lax.dot_general(
        xw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_hessian_pallas(
    X: jnp.ndarray, w: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    """X^T diag(w) X for caller-supplied weights (X: (N, d), N % block_n
    == 0, d % 128 == 0 — ops.py pads).  The secure-fit hot path derives w
    from beta inside ``fused_irls_pallas`` instead; this variant stays for
    workloads that reweight rows externally (e.g. offset/exposure models).
    """
    n, d = X.shape
    assert n % block_n == 0, "caller pads N"
    grid = (n // block_n,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(X, w)
