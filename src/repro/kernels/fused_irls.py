"""Pallas TPU kernel: the paper's full per-institution IRLS local phase,
batched over institutions, in ONE streaming pass over X.

Per Newton iteration every institution j computes (Algorithm 1, steps 4-6)

    z = X_j beta,  p = sigmoid(z),  w = p (1 - p)
    H_j = X_j^T diag(w) X_j          (Eq. 4, O(N d^2) — the hot term)
    g_j = X_j^T (y_j - p)            (Eq. 5)
    dev_j = -2 sum(y z - softplus z) (Eq. 6)

The pre-fusion pipeline ran three separate passes (z/g/dev kernel, then a
weighted-Gram kernel re-reading X with w round-tripped through HBM) and a
Python loop over institutions.  Here one kernel with grid (S, N/block_n)
streams each institution's (block_n, d) tile through VMEM exactly once and
emits all three summaries for all S institutions; the IRLS weights live
only in VMEM registers between the sigmoid and the Gram update — they are
never written to HBM.

Ragged institutions are padded to a common N_max and masked inside the
kernel with per-institution row counts, so one launch covers uneven
partition sizes (the paper's horizontal split is never exactly even).

Precision contract: the Gram/Hessian accumulates in float32 on the MXU
(`mxu_ref` is a separate operand so a CPU/interpret profile can keep the
main payload in float64 — on TPU both refs alias one f32 array).  The
gradient/deviance accumulate in the payload dtype.  H only preconditions
the Newton step — the fixed point solves g(beta) = lam beta — so f32 H
changes the trajectory, not the answer; g/dev precision is what bounds the
final beta and the deviance-based convergence test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_irls_pallas", "fused_irls_sim", "gram_hessian_pallas"]

DEFAULT_BLOCK_N = 512


def _irls_kernel(beta_ref, x_ref, xm_ref, y_ref, cnt_ref,
                 h_ref, g_ref, dev_ref, *, block_n):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        g_ref[...] = jnp.zeros_like(g_ref)
        dev_ref[...] = jnp.zeros_like(dev_ref)

    x = x_ref[0]  # (block_n, d) payload dtype
    y = y_ref[0]  # (block_n,)
    beta = beta_ref[...].astype(x.dtype)  # (d,)
    # ragged mask: absolute row id vs this institution's true row count
    row = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, 1), 0
    )[:, 0]
    valid = (row < cnt_ref[0]).astype(x.dtype)  # (block_n,)

    z = x @ beta  # (block_n,)
    p = jax.nn.sigmoid(z)
    w = (p * (1.0 - p)) * valid  # IRLS weights: VMEM-resident only
    resid = (y - p) * valid
    g_ref[0] += x.T @ resid
    softplus = jnp.logaddexp(jnp.zeros_like(z), z)
    dev_ref[0] += -2.0 * jnp.sum((y * z - softplus) * valid)
    # MXU Gram update in f32; weights fold into the left operand
    xm = xm_ref[0]  # (block_n, d) float32
    h_ref[0] += jax.lax.dot_general(
        xm * w.astype(jnp.float32)[:, None], xm,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_irls_pallas(
    beta: jnp.ndarray,  # (d,)
    X: jnp.ndarray,  # (S, N_max, d) payload dtype (f32 on TPU)
    Xm: jnp.ndarray,  # (S, N_max, d) float32 MXU operand (== X on TPU)
    y: jnp.ndarray,  # (S, N_max) payload dtype
    counts: jnp.ndarray,  # (S,) int32 true row counts (<= N_max)
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """All-institution summaries in one launch.

    Returns (H (S, d, d) f32, g (S, d), dev (S,)); g/dev in X.dtype.
    N_max % block_n == 0 and d % 128 == 0 (ops.py pads); rows >= counts[s]
    are masked out, so tail padding may hold anything.
    """
    s_dim, n, d = X.shape
    assert n % block_n == 0, "caller pads N_max"
    grid = (s_dim, n // block_n)
    kernel = functools.partial(_irls_kernel, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda s, i: (0,)),
            pl.BlockSpec((1, block_n, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, block_n), lambda s, i: (s, i)),
            pl.BlockSpec((1,), lambda s, i: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, d), lambda s, i: (s, 0, 0)),
            pl.BlockSpec((1, d), lambda s, i: (s, 0)),
            pl.BlockSpec((1,), lambda s, i: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, d, d), jnp.float32),
            jax.ShapeDtypeStruct((s_dim, d), X.dtype),
            jax.ShapeDtypeStruct((s_dim,), X.dtype),
        ],
        interpret=interpret,
    )(beta, X, Xm, y, counts)


@jax.jit
def fused_irls_sim(beta, X, Xm, y, counts):
    """Functional simulation of ``fused_irls_pallas`` — same numerics
    contract (f32 Gram accumulation from the MXU operand, g/dev in the
    payload dtype, row masks), evaluated as plain XLA ops.

    This is what ``interpret=True`` callers run at production sizes: the
    Pallas interpreter emulates every grid program with whole-operand
    copies, which at (S, 2e5, d) costs ~6x the arithmetic itself on CPU.
    The blocked kernel remains the compiled TPU path; tests pin the two
    against each other (they differ only in f32 summation order).

    One deliberate upgrade over the TPU kernel: with a float32 payload
    the kernel accumulates g/dev in f32 (the hardware dtype); the sim
    always accumulates them in f64 (free on CPU via
    ``preferred_element_type``), which keeps the secure protocol's
    fixed-point codec the dominant error term.  The kernel == sim
    pinning test therefore runs with an f64 payload, where the two
    contracts coincide.

    Two contraction styles, each where the CPU backend is fastest: the
    O(N d) z/g/dev reductions run batched (or, for the mixed
    f32-operand/f64-accumulation case, unrolled — the batched form hits
    a ~10x-slow generic emitter), while the O(N d^2) Gram unrolls into
    per-institution 2D contractions mirroring the kernel's (S, blocks)
    grid; the batched (S, N, d) dot emitter is ~40% slower with much
    higher variance.  The 3-operand einsum folds the IRLS row scaling
    into the Gram contraction instead of materializing a scaled copy of
    Xm.
    """
    s_dim, n = X.shape[0], X.shape[1]
    mask = (
        jnp.arange(n, dtype=jnp.int32)[None, :] < counts[:, None]
    ).astype(jnp.float64)
    if X.dtype == jnp.float32:
        z = jax.lax.dot_general(
            X, beta.astype(jnp.float32), (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float64,
        )
    else:
        z = jnp.einsum("snd,d->sn", X, beta.astype(X.dtype))
    p = jax.nn.sigmoid(z)
    w32 = ((p * (1.0 - p)) * mask).astype(jnp.float32)
    H = jnp.stack([
        jnp.einsum(
            "n,ni,nj->ij", w32[j], Xm[j], Xm[j],
            preferred_element_type=jnp.float32,
        )
        for j in range(s_dim)
    ])
    resid = (y - p) * mask
    if X.dtype == jnp.float32:
        r32 = resid.astype(jnp.float32)
        g = jnp.stack([
            jax.lax.dot_general(
                r32[j], X[j], (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float64,
            )
            for j in range(s_dim)
        ])
    else:
        g = jnp.einsum("snd,sn->sd", X, resid)
    dev = -2.0 * jnp.sum((y * z - jnp.logaddexp(0.0, z)) * mask, axis=1)
    return H, g, dev


# -- explicit-weight Gram (legacy public op) ---------------------------------
def _gram_kernel(x_ref, w_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_n, d)
    xw = x * w_ref[...].astype(jnp.float32)[:, None]
    o_ref[...] += jax.lax.dot_general(
        xw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gram_hessian_pallas(
    X: jnp.ndarray, w: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    """X^T diag(w) X for caller-supplied weights (X: (N, d), N % block_n
    == 0, d % 128 == 0 — ops.py pads).  The secure-fit hot path derives w
    from beta inside ``fused_irls_pallas`` instead; this variant stays for
    workloads that reweight rows externally (e.g. offset/exposure models).
    """
    n, d = X.shape
    assert n % block_n == 0, "caller pads N"
    grid = (n // block_n,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(X, w)
