"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper in
ops.py, and a pure-jnp oracle in ref.py.  Validated in interpret mode on CPU
(tests/test_kernels.py); written against TPU VMEM/MXU semantics.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
