"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper in
ops.py, and a pure-jnp oracle in ref.py.  Validated in interpret mode on CPU
(tests/test_kernels.py); written against TPU VMEM/MXU semantics.

The secure-aggregation pipeline is fully kernelized: ``shamir_poly``
(share generation + fused fixed-point encode) and ``shamir_reconstruct``
(Lagrange interpolation + CRT Garner digit) cover protect and reveal end
to end over flat (rows, 128) tile buffers — see ``core.secure_agg`` for
the backend switch that routes production traffic through them.
"""
from . import ops, ref, tuning

__all__ = ["ops", "ref", "tuning"]
