"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (important: smoke tests must see 1 CPU device;
only dryrun.py forces 512 placeholder devices via XLA_FLAGS).
"""
from __future__ import annotations

from ..distributed.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The paper's institutions map onto the "pod" axis (one institution = one
    pod); "model" carries TP/EP/sequence-sharded KV.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    from ..distributed.sharding import POD_AXIS

    axes = (POD_AXIS, "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(axes=("data", "model")):
    """1x1 mesh over the single local device (smoke tests, examples)."""
    shape = (1,) * len(axes)
    return make_mesh(shape, axes)
