"""Trip-count-aware cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified in this container: a 10-iteration scanned matmul
reports 1x flops), which would corrupt every roofline term for scanned-layer
models.  This module re-derives the three roofline inputs by walking the
HLO text itself:

* **flops** — from ``dot`` ops (2 * prod(result_shape) * prod(contracting
  dims)); everything else is negligible at transformer scale.
* **bytes** — HBM-traffic estimate: operand + result buffer sizes of
  top-level ops (fusion boundaries), i.e. the same convention XLA's own
  "bytes accessed" uses, but loop-aware.
* **collective bytes** — per collective kind (shapes in post-partitioning
  HLO are already per-device).  all-reduce counts 2x its result bytes
  (the reduce-scatter + all-gather phases of a ring); reduce-scatter
  counts its OPERAND bytes (the ring moves the full input, the result is
  the 1/D-sized shard); all-gather counts its result bytes (the full
  gathered buffer).  The conventions are mutually consistent: a
  reduce-scatter + all-gather pair over the same logical buffer sums to
  exactly the all-reduce figure.  The factors themselves live in
  ``repro.obs.metrics`` — the ONE definition shared with the round
  drivers' byte gauges, pinned by ``tests/test_byte_accounting.py``.

While trip counts are recovered from the loop condition's ROOT compare
constant; nested loops multiply.  All numbers are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from ..obs.metrics import (
    ALL_GATHER_FACTOR,
    ALL_REDUCE_FACTOR,
    REDUCE_SCATTER_FACTOR,
)

__all__ = ["HloCost", "analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\{)")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops whose operands/results plausibly touch HBM at fusion granularity
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int)
    )
    # diagnostics for §Perf: HBM bytes attributed per op kind, and per
    # (kind, result-type) bucket — the hillclimb reads these to find what
    # actually moves the memory term.
    bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    bytes_by_bucket: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    # bytes from loop-invariant pure transforms of parameters (dtype
    # converts / layout copies of weights): charged once, not per trip —
    # they are hoistable, and on TPU the bf16->f32 converts the CPU
    # backend inserts around dots do not exist at all.
    hoistable_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def top_buckets(self, n: int = 12):
        return sorted(self.bytes_by_bucket.items(), key=lambda kv: -kv[1])[:n]

    def scaled(self, k: float) -> "HloCost":
        out = HloCost(self.flops * k, self.bytes * k)
        out.hoistable_bytes = self.hoistable_bytes  # NOT trip-scaled
        for key, v in self.collective_bytes.items():
            out.collective_bytes[key] = v * k
        for key, v in self.collective_count.items():
            out.collective_count[key] = int(v * k)
        for key, v in self.bytes_by_kind.items():
            out.bytes_by_kind[key] = v * k
        for key, v in self.bytes_by_bucket.items():
            out.bytes_by_bucket[key] = v * k
        return out

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.hoistable_bytes += other.hoistable_bytes
        for key, v in other.collective_bytes.items():
            self.collective_bytes[key] += v
        for key, v in other.collective_count.items():
            self.collective_count[key] += v
        for key, v in other.bytes_by_kind.items():
            self.bytes_by_kind[key] += v
        for key, v in other.bytes_by_bucket.items():
            self.bytes_by_bucket[key] += v


class _Op:
    __slots__ = ("name", "type_str", "kind", "rest", "line")

    def __init__(self, name, type_str, kind, rest, line):
        self.name = name
        self.type_str = type_str
        self.kind = kind
        self.rest = rest
        self.line = line


def _parse_computations(text: str):
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("(" in stripped or
                                           stripped.startswith("ENTRY")):
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, kind, rest = m.groups()
            comps[cur].append(_Op(name, type_str, kind, rest, stripped))
    return comps, entry


def _dot_flops(op: _Op, dims_table: dict) -> float:
    _, res_dims = _first_shape(op.type_str)
    # lhs shape: inline type if present, else symbol-table lookup of the
    # first %operand reference
    lhs_m = _SHAPE_RE.search(op.rest)
    lhs_dims = None
    if lhs_m:
        lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d]
    else:
        refs = re.findall(r"%([\w.\-]+)", op.rest)
        if refs and refs[0] in dims_table:
            lhs_dims = dims_table[refs[0]][1]
    if lhs_dims is None:
        return 0.0
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cdims:
        for idx in cdims.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    n = 1
    for d in res_dims:
        n *= d
    return 2.0 * n * contract


def _operand_bytes(op: _Op, shapes: dict) -> int:
    """Sum operand buffer sizes by looking up named operands."""
    total = 0
    for ref in re.findall(r"%([\w.\-]+)", op.rest.split(")")[0]):
        if ref in shapes:
            total += shapes[ref]
    # operands may also carry inline types (newer HLO): count those too if
    # no named refs resolved
    if total == 0:
        args = op.rest.split("),")[0]
        total = _shape_bytes(args)
    return total


_SLICE_KINDS = ("dynamic-slice", "slice", "gather")
_TRANSFORM_KINDS = {
    "parameter", "constant", "convert", "copy", "bitcast", "reshape",
    "transpose", "bitcast-convert", "broadcast", "iota",
}


def _is_param_transform(called_ops: list) -> bool:
    """True if the fusion only re-types/re-lays-out its parameters (or
    broadcasts constants) — i.e. loop-invariant, hoistable work."""
    return bool(called_ops) and all(
        op.kind in _TRANSFORM_KINDS for op in called_ops
    )


def _root_dus_update_bytes(called_ops: list):
    """If the fusion ROOT is a dynamic-update-slice, return
    (update_slice_bytes, target_param_name); else None.

    Scan bodies write their per-step output into the stacked result via
    in-place DUS — charging the full aliased buffer per trip overstates
    HBM traffic by the trip count.
    """
    shapes = {op.name: _shape_bytes(op.type_str) for op in called_ops}
    by_name = {op.name: op for op in called_ops}
    params = {op.name for op in called_ops if op.kind == "parameter"}
    passthrough = ("convert", "copy", "bitcast", "reshape",
                   "bitcast-convert", "transpose")

    def to_param(name, depth=0):
        if name in params:
            return name
        op = by_name.get(name)
        if op is not None and op.kind in passthrough and depth < 4:
            refs = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
            if refs:
                return to_param(refs[0], depth + 1)
        return None

    for op in called_ops:
        if op.kind == "dynamic-update-slice":
            refs = re.findall(r"%([\w.\-]+)", op.rest.split(")")[0])
            if len(refs) >= 2:
                target = to_param(refs[0])
                if target is not None:
                    upd = shapes.get(refs[1], 0)
                    if upd == 0:  # update computed inline in the fusion:
                        # approximate with the target's per-trip slice
                        upd = shapes.get(refs[1], shapes.get(target, 0))
                    return 2 * upd, target  # write + worst-case read
    return None


def _fusion_operand_bytes(called_ops: list, skip_params=()) -> int:
    """Operand bytes a fusion actually reads, from its called computation.

    A fusion whose parameter is only ever consumed by (dynamic-)slice ops
    reads just the slice, not the whole buffer — the dominant case is a
    scanned layer stack (L, ...) sliced per iteration.  Charging the full
    stack per trip overstates HBM traffic by ~L x; XLA's own cost analysis
    uses the sliced convention, and so do we.
    """
    _PASS_THROUGH = ("reshape", "bitcast", "transpose", "copy",
                     "bitcast-convert")

    def consumers_of(name):
        pat = re.compile(r"%" + re.escape(name) + r"\b")
        return [o for o in called_ops
                if o.kind != "parameter" and o.name != name
                and (pat.search(o.rest) or pat.search(o.line))]

    def sliced_bytes(name, depth=0):
        """Bytes read from ``name`` if every consumption path ends in a
        slice (following layout-only pass-through ops); None otherwise."""
        if depth > 4:
            return None
        cons = consumers_of(name)
        if not cons:
            return None
        total = 0
        for o in cons:
            if o.kind in _SLICE_KINDS:
                total += _shape_bytes(o.type_str)
            elif o.kind in _PASS_THROUGH:
                sub = sliced_bytes(o.name, depth + 1)
                if sub is None:
                    return None
                total += sub
            else:
                return None
        return total

    total = 0
    for op in called_ops:
        if op.kind != "parameter":
            continue
        if op.name in skip_params:
            continue  # aliased in-place target: no full-buffer read
        pbytes = _shape_bytes(op.type_str)
        sb = sliced_bytes(op.name)
        total += sb if sb is not None else pbytes
    return total


def _trip_count_from_config(line: str) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    if m:
        return max(1, int(m.group(1)))
    return None


def _trip_count(cond_ops: list) -> int:
    """Extract N from the loop condition's ROOT compare against constant."""
    consts = {}
    for op in cond_ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond_ops:
        if op.kind == "compare" and "ROOT" in op.line:
            for ref in re.findall(r"%([\w.\-]+)", op.rest):
                if ref in consts:
                    return max(1, consts[ref])
    # fallback: largest s32 constant in the condition
    if consts:
        return max(1, max(consts.values()))
    return 1


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    shapes_by_comp = {
        c: {op.name: _shape_bytes(op.type_str) for op in ops}
        for c, ops in comps.items()
    }
    dims_by_comp = {
        c: {op.name: _first_shape(op.type_str) for op in ops}
        for c, ops in comps.items()
    }
    memo: dict[str, HloCost] = {}

    def cost_of(comp: str) -> HloCost:
        if comp in memo:
            return memo[comp]
        memo[comp] = HloCost()  # cycle guard
        total = HloCost()
        shapes = shapes_by_comp[comp]
        dims_table = dims_by_comp[comp]
        for op in comps[comp]:
            if op.kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.line)
                cond = re.search(r"condition=%?([\w.\-]+)", op.line)
                if body and body.group(1) in comps:
                    n = _trip_count_from_config(op.line)
                    if n is None:
                        n = _trip_count(comps[cond.group(1)]) \
                            if cond and cond.group(1) in comps else 1
                    total.add(cost_of(body.group(1)).scaled(n))
                continue
            if op.kind == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", op.line)
                if called and called.group(1) in comps:
                    inner = cost_of(called.group(1))
                    # only flops + collectives propagate from inside a
                    # fusion; bytes are the fusion's own operands/results
                    total.flops += inner.flops
                    for key, v in inner.collective_bytes.items():
                        total.collective_bytes[key] += v
                    called_ops = comps[called.group(1)]
                    if _is_param_transform(called_ops):
                        b = _shape_bytes(op.type_str)
                        total.hoistable_bytes += 2 * b  # one read+write
                        total.bytes_by_bucket[
                            f"hoisted-transform {op.type_str[:40]}"
                        ] += 2 * b
                        continue
                    dus = _root_dus_update_bytes(called_ops)
                    if dus is not None:
                        # in-place scan-output write: the full result
                        # buffer is aliased; real traffic is the updated
                        # slice (write) + non-target operands (reads).
                        upd_bytes, target = dus
                        b = upd_bytes + _fusion_operand_bytes(
                            called_ops, skip_params={target}
                        )
                    else:
                        b = _shape_bytes(op.type_str)
                        b += _fusion_operand_bytes(called_ops)
                else:
                    b = _shape_bytes(op.type_str) + _operand_bytes(op,
                                                                   shapes)
                total.bytes += b
                total.bytes_by_kind["fusion"] += b
                total.bytes_by_bucket[f"fusion {op.type_str[:48]}"] += b
                continue
            if op.kind in ("call", "conditional"):
                for called in re.findall(
                    r"(?:to_apply|calls|branch_computations=\{)"
                    r"=?%?([\w.\-]+)", op.line
                ):
                    if called in comps:
                        total.add(cost_of(called))
                continue
            if op.kind == "dot":
                total.flops += _dot_flops(op, dims_table)
                b = _shape_bytes(op.type_str) + _operand_bytes(op, shapes)
                total.bytes += b
                total.bytes_by_kind["dot"] += b
                total.bytes_by_bucket[f"dot {op.type_str[:48]}"] += b
                continue
            if op.kind in COLLECTIVES:
                result_b = _shape_bytes(op.type_str)
                if op.kind == "reduce-scatter":
                    # the ring moves the full OPERAND; the result is the
                    # 1/D shard (so RS + AG == all-reduce's 2x result)
                    nbytes = _operand_bytes(op, shapes) or result_b
                    factor = REDUCE_SCATTER_FACTOR
                elif op.kind == "all-reduce":
                    nbytes = result_b
                    factor = ALL_REDUCE_FACTOR
                elif op.kind == "all-gather":
                    nbytes = result_b
                    factor = ALL_GATHER_FACTOR
                else:
                    nbytes = result_b
                    factor = 1.0
                total.collective_bytes[op.kind] += factor * nbytes
                total.collective_count[op.kind] += 1
                total.bytes += result_b
                total.bytes_by_kind[op.kind] += result_b
                total.bytes_by_bucket[
                    f"{op.kind} {op.type_str[:48]}"
                ] += factor * nbytes
                continue
            if op.kind in _SKIP_BYTES:
                continue
            # generic op: count its result (operands usually other ops'
            # results, already counted once as outputs)
            b = _shape_bytes(op.type_str)
            total.bytes += b
            total.bytes_by_kind[op.kind] += b
            if b > 1 << 20:
                total.bytes_by_bucket[f"{op.kind} {op.type_str[:48]}"] += b
        memo[comp] = total
        return total

    if entry is None:
        raise ValueError("no ENTRY computation found")
    cost = cost_of(entry)
    cost.bytes += cost.hoistable_bytes  # charged once in the total
    return cost
