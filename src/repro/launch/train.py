"""End-to-end training driver.

Two pipelines behind one CLI, selected by ``--arch``:

* ``--arch logreg_paper`` — the paper's pipeline: S institutions run
  Algorithm 1 (distributed summaries -> Shamir shares -> secure aggregation
  at the Computation Centers -> Newton step) with straggler/center-failure
  tolerance and checkpoint/restart of protocol state.

* ``--arch <lm-arch>`` — LM training on the unified decoder stack, with the
  paper's technique as a first-class optimizer feature: ``--secure-agg
  shamir`` replaces the cross-institution gradient reduction with
  secret-shared aggregation (core.secure_agg), exactly the role H_j/g_j
  sharing plays in Algorithm 1.  ``--institutions S`` splits every global
  batch S ways; per-institution grads are protected before any aggregation.
  Supports AdamW, grad clipping, checkpoint/restart (atomic, retain-k,
  async), deterministic failure injection and elastic re-meshing plans.

Examples (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.train --arch logreg_paper \
      --study synthetic --protect gradient
  PYTHONPATH=src python -m repro.launch.train --arch deepseek_7b --smoke \
      --steps 20 --secure-agg shamir --institutions 4
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_32b --smoke \
      --steps 50 --checkpoint-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    # --- logreg pipeline
    ap.add_argument("--study", default="synthetic",
                    help="insurance | parkinsons.motor | parkinsons.total | "
                         "synthetic")
    ap.add_argument("--protect", default="gradient",
                    choices=["none", "gradient", "hessian", "both"])
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--l1", type=float, default=0.0,
                    help="L1 penalty (elastic net); institution protocol "
                         "unchanged, center solver switches to prox-Newton")
    ap.add_argument("--tol", type=float, default=1e-10)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="row-count scale for quick runs")
    ap.add_argument("--centers", type=int, default=3)
    ap.add_argument("--threshold", type=int, default=2)
    ap.add_argument("--rounds", default="step", choices=["step", "scan"],
                    help="round execution for the secure fit: 'step' "
                         "re-enters Python every Newton round; 'scan' runs "
                         "whole blocks of rounds as ONE lax.scan — one host "
                         "sync per block (requires --fused)")
    ap.add_argument("--rounds-per-sync", type=int, default=None,
                    metavar="K",
                    help="scan block size: K rounds per host sync (default "
                         "None = the whole fit as one block; smaller blocks "
                         "let the fault supervisor and checkpoints cut in)")
    ap.add_argument("--fused", action="store_true",
                    help="cohort-level batched coordinator rounds (pallas "
                         "backend); per-round parity with the loop oracle "
                         "within fixed-point quantization")
    ap.add_argument("--select-lambda", default=None, metavar="GRID",
                    help="choose λ by secure K-fold cross-validation over "
                         "a comma-separated descending grid (e.g. "
                         "'30,10,3,1,0.3') instead of fitting --lam; runs "
                         "the batched scanned sweep (pallas backend), "
                         "prints the CV curve, picks the 1-SE λ, and "
                         "refits on all data")
    ap.add_argument("--folds", type=int, default=5,
                    help="CV folds for --select-lambda")
    ap.add_argument("--deadline", type=float, default=None,
                    help="straggler deadline (simulated seconds)")
    # --- LM pipeline
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--secure-agg", default="none",
                    choices=["none", "shamir"])
    ap.add_argument("--secure-backend", default="pallas",
                    choices=["pallas", "reference"],
                    help="shamir aggregation wire: 'pallas' runs the whole "
                         "cohort round on the flat-buffer uint32 wire (one "
                         "batched encode+share launch, one exact uint64 "
                         "reduction, t-subset reveal); 'reference' keeps "
                         "the per-leaf uint64 oracle loop")
    ap.add_argument("--institutions", type=int, default=4,
                    help="batch splits treated as paper institutions")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression "
                         "(plain mode only)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject an institution failure at this step")
    # --- common
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="write metrics JSON here")
    return ap.parse_args(argv)


# --------------------------------------------------------------- logreg path
def run_logreg(args) -> dict:
    import jax
    import jax.numpy as jnp

    from ..checkpoint import CheckpointManager
    from ..core.newton import centralized_fit
    from ..core.protocol import Institution, StudyCoordinator
    from ..core.secure_agg import SecureAggregator
    from ..core.shamir import ShamirScheme
    from ..data.datasets import load_study

    study = load_study(args.study, seed=args.seed, scale=args.scale)
    if args.select_lambda:
        from ..selection import SelectionCoordinator

        lambdas = [float(x) for x in args.select_lambda.split(",")]
        agg = SecureAggregator(
            scheme=ShamirScheme(threshold=args.threshold,
                                num_shares=args.centers,
                                backend="pallas"),
            overflow_check=True,
        )
        insts = [
            Institution(f"inst{j}", Xj, yj)
            for j, (Xj, yj) in enumerate(study.parts)
        ]
        coord = SelectionCoordinator(
            insts, lambdas, num_folds=args.folds, l1=args.l1,
            protect=args.protect, aggregator=agg, deadline=args.deadline,
            tol=args.tol, seed=args.seed,
        )
        report = coord.run_path()
        print("\n".join(report.summary_lines()))
        out = {
            "pipeline": "logreg_paper", "study": study.name,
            "mode": "select-lambda",
            "lambdas": list(report.lambdas),
            "folds": args.folds,
            "cv_mean_deviance": [float(v) for v in report.cv_mean],
            "cv_se": [float(v) for v in report.cv_se],
            "cv_accuracy": [float(v) for v in report.cv_accuracy],
            "lambda_best": report.lambda_best,
            "lambda_1se": report.lambda_1se,
            "secure_rounds": report.rounds_total,
            "bytes_per_round": report.bytes_per_round,
            "bytes_transmitted": report.bytes_total,
            "nonzero_coefs": int((np.abs(report.beta) > 1e-6).sum()),
            "features": study.num_features,
            "protect": args.protect,
        }
        print(json.dumps(out, indent=2))
        return out
    if args.l1 > 0.0:
        from ..core.newton import secure_fit

        res = secure_fit(study.parts, lam=args.lam, l1=args.l1,
                         tol=args.tol, protect=args.protect)
        out = {
            "pipeline": "logreg_paper", "study": study.name,
            "regularization": f"elastic-net lam={args.lam} l1={args.l1}",
            "iterations": res.iterations, "converged": res.converged,
            "nonzero_coefs": int((abs(res.beta) > 1e-6).sum()),
            "features": study.num_features,
            "total_seconds": res.total_seconds,
        }
        print(json.dumps(out, indent=2))
        return out
    # overflow_check: armed by default on every launch secure path — the
    # fixed-point headroom assert is a fixed ~1-3 ms/round host callback
    # (<= 2% of a production fused round; benchmarks/fault_overhead.py),
    # and a raise beats silently saturating into a plausible reveal
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=args.threshold,
                            num_shares=args.centers,
                            backend="pallas" if args.fused else "reference"),
        overflow_check=True,
    )
    insts = [
        Institution(f"inst{j}", Xj, yj)
        for j, (Xj, yj) in enumerate(study.parts)
    ]
    coord = StudyCoordinator(
        insts, lam=args.lam, protect=args.protect, aggregator=agg,
        deadline=args.deadline, tol=args.tol, seed=args.seed,
        fused=args.fused, rounds=args.rounds,
        rounds_per_sync=args.rounds_per_sync,
    )

    ckpt = None
    if args.checkpoint_dir:
        ckpt = CheckpointManager(args.checkpoint_dir, retain=3)
        if args.resume and ckpt.latest_step() is not None:
            state, step = ckpt.restore(
                {"beta": np.asarray(coord.beta), "obj_prev": np.float64(0)}
            )
            coord.beta = jnp.asarray(state["beta"])
            coord._obj_prev = float(state["obj_prev"])
            coord.iteration = step
            print(f"resumed protocol at iteration {step}")

    t0 = time.perf_counter()
    while not coord.converged and coord.iteration < 50:
        rep = coord.step()
        print(f"iter {rep.iteration:2d} obj={rep.objective:.10f} "
              f"responders={len(rep.responders)} "
              f"stragglers={rep.stragglers}")
        if ckpt and rep.iteration % 1 == 0:
            ckpt.save(rep.iteration, {
                "beta": np.asarray(coord.beta),
                "obj_prev": np.float64(coord._obj_prev),
            })
    total_s = time.perf_counter() - t0

    gold = centralized_fit(*study.pooled(), lam=args.lam, tol=args.tol)
    r2 = float(np.corrcoef(np.asarray(coord.beta), gold.beta)[0, 1] ** 2)
    out = {
        "pipeline": "logreg_paper",
        "study": study.name,
        "samples": study.num_samples,
        "features": study.num_features,
        "iterations": coord.iteration,
        "converged": bool(coord.converged),
        "r2_vs_gold": r2,
        "max_abs_err_vs_gold": float(
            np.max(np.abs(np.asarray(coord.beta) - gold.beta))
        ),
        "total_seconds": total_s,
        "bytes_transmitted": int(
            sum(r.bytes_transmitted for r in coord.reports)
        ),
        "protect": args.protect,
    }
    print(json.dumps(out, indent=2))
    return out


# ------------------------------------------------------------------- LM path
def run_lm(args) -> dict:
    import jax
    import jax.numpy as jnp

    from ..checkpoint import CheckpointManager
    from ..configs import get_config, smoke_config
    from ..core.secure_agg import SecureAggregator
    from ..distributed import MeshRules
    from ..models import transformer as T
    from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
    from ..optim.compression import compressed_psum, init_error_feedback
    from ..runtime import FailureInjector, HeartbeatMonitor, SimClock

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rules = MeshRules(mesh=None)  # single-host run; dry-run covers the pod mesh
    key = jax.random.PRNGKey(args.seed)
    key, kp = jax.random.split(key)
    params = T.init_params(kp, cfg)
    # warmup must fit the run: the config default (100 steps) left short
    # smoke runs training at ~1% of the requested lr, so their loss
    # trajectory was pure batch noise
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=min(100, max(1, args.steps // 2))
    )
    opt_state = adamw_init(params)
    S = max(1, args.institutions)
    agg = SecureAggregator(backend=args.secure_backend,
                           overflow_check=True) \
        if args.secure_agg == "shamir" else None
    err_fb = init_error_feedback(params) if args.compress else None

    B, L = args.batch, args.seq_len
    if B % S:
        raise SystemExit(f"--batch {B} must be divisible by "
                         f"--institutions {S}")

    # The synthetic stream is a small FIXED corpus the loop cycles over
    # (epochs), not a fresh i.i.d. draw per step.  Fresh uniform tokens
    # every step have no learnable structure beyond the marginal, so a
    # short run's first-vs-last loss compared uncorrelated batch noise
    # and the convergence smoke failed stochastically; on a fixed corpus
    # the loss decreases deterministically (and identically under secure
    # aggregation — fixed-point quantization is ~1e-9 per grad element).
    corpus_batches = 4

    def data_batch(step: int, live: np.ndarray):
        """Deterministic synthetic LM batch, per-institution slices."""
        k = jax.random.fold_in(
            jax.random.PRNGKey(args.seed + 1), step % corpus_batches
        )
        tokens = jax.random.randint(k, (B, L + 1), 0, cfg.vocab_size)
        batch = {"labels": tokens[:, 1:].astype(jnp.int32)}
        if cfg.frontend == "embeddings":
            ke = jax.random.fold_in(k, 7)
            batch["embeds"] = jax.random.normal(
                ke, (B, L, cfg.d_model), dtype=jnp.bfloat16
            )
        else:
            batch["tokens"] = tokens[:, :-1].astype(jnp.int32)
        return batch

    def inst_slices(batch):
        return [
            jax.tree_util.tree_map(lambda x: x[j * (B // S):(j + 1) * (B // S)],
                                   batch)
            for j in range(S)
        ]

    grad_fn = jax.jit(
        lambda p, b: jax.value_and_grad(T.loss_fn, has_aux=True)(
            p, b, cfg, rules
        )
    )

    @jax.jit
    def apply_update(grads, opt_state, params):
        return adamw_update(grads, opt_state, params, opt_cfg)

    # --- fault-tolerance wiring
    clock = SimClock()
    monitor = HeartbeatMonitor(clock, timeout=5.0)
    for j in range(S):
        monitor.register(f"inst{j}")
    injector = FailureInjector(
        {args.fail_at: [f"inst{S - 1}"]} if args.fail_at is not None else {}
    )

    ckpt = None
    start = 0
    if args.checkpoint_dir:
        ckpt = CheckpointManager(args.checkpoint_dir, retain=3,
                                 async_writes=False)
        if args.resume and ckpt.latest_step() is not None:
            state, start = ckpt.restore(
                {"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            print(f"resumed LM training at step {start}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        clock.advance(1.0)
        killed = injector.apply(step, monitor)
        if killed:
            print(f"step {step}: institutions failed: {killed}")
        live = [w for w in monitor.alive()]
        live_idx = sorted(int(w[4:]) for w in live)
        if not live_idx:
            raise RuntimeError("no live institutions")
        for w in live:
            monitor.beat(w)

        batch = data_batch(step, live_idx)
        slices = inst_slices(batch)
        # per-institution local computation (paper's distributed phase)
        per_inst = []
        loss_acc = 0.0
        for j in live_idx:
            (loss, metrics), grads = grad_fn(params, slices[j])
            per_inst.append(grads)
            loss_acc += float(loss)
        loss = loss_acc / len(live_idx)

        # cross-institution aggregation (paper's centralized phase)
        if agg is not None:
            key, kk = jax.random.split(key)
            if agg.backend == "pallas":
                # flat-buffer wire: the live cohort's grad trees stack
                # S-leading and the whole round is one batched
                # encode+share launch -> exact uint64 reduction over the
                # institution axis -> one t-subset reveal (the same round
                # helper the fused protocol drivers run); per-institution
                # gradients only ever exist as shares past this point
                stacked = jax.tree_util.tree_map(
                    lambda *gs: jnp.stack(gs, axis=0), *per_inst
                )
                summed = agg.secure_round_batched(
                    kk, stacked, dtype=jnp.float32
                )
            else:
                # per-leaf uint64 oracle loop (debug/audit rung)
                protected = [
                    agg.protect(jax.random.fold_in(kk, j), g)
                    for j, g in zip(live_idx, per_inst)
                ]
                summed = agg.reveal(agg.aggregate(protected),
                                    dtype=jnp.float32)
            grads = jax.tree_util.tree_map(
                lambda x: (x / len(live_idx)).astype(jnp.float32), summed
            )
        else:
            grads = jax.tree_util.tree_map(
                lambda *gs: sum(g.astype(jnp.float32) for g in gs)
                / len(live_idx),
                *per_inst,
            )

        params, opt_state, om = apply_update(grads, opt_state, params)
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:4d} loss={loss:.4f} "
                  f"gnorm={float(om['grad_norm']):.3f} "
                  f"live={len(live_idx)}/{S}")
        if ckpt and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})

    total_s = time.perf_counter() - t0
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.close()
    out = {
        "pipeline": "lm",
        "arch": cfg.name,
        "params": T.count_params(cfg),
        "steps": args.steps - start,
        "secure_agg": args.secure_agg,
        "secure_backend": args.secure_backend
        if args.secure_agg != "none" else None,
        "institutions": S,
        "loss_first": losses[0] if losses else None,
        "loss_last": losses[-1] if losses else None,
        "seconds": total_s,
    }
    print(json.dumps(out, indent=2))
    return out


def main(argv=None):
    args = parse_args(argv)
    if args.arch == "logreg_paper":
        out = run_logreg(args)
    else:
        out = run_lm(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
