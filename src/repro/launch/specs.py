"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

No device allocation anywhere: params/opt-state/caches come from
jax.eval_shape; batches are ShapeDtypeStructs.  Sharding choices degrade
gracefully (an axis is only sharded when its size divides the mesh axis),
so e.g. long_500k's global_batch=1 falls back to batch replication while
its KV window still shards over 'model'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import MeshRules, param_shardings
from ..models import transformer as T
from ..models.config import ModelConfig, ShapeConfig
from ..optim.adamw import adamw_init

__all__ = ["input_specs", "batch_shardings", "cache_pspecs", "train_state_specs"]


def _div(n, size):
    return size > 0 and n % size == 0 and n >= size


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract model inputs for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        batch = {"labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend == "embeddings":
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "decode":
        caches = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
        step = {
            "length": jax.ShapeDtypeStruct((), i32),
            "caches": caches,
        }
        if cfg.frontend == "embeddings":
            step["embeds"] = jax.ShapeDtypeStruct((B, cfg.d_model), bf16)
        else:
            step["tokens"] = jax.ShapeDtypeStruct((B,), i32)
        return step
    raise ValueError(shape.kind)


def batch_shardings(specs, rules: MeshRules):
    """Data-parallel sharding of the token/label/embedding batch."""
    if rules.mesh is None:
        return jax.tree_util.tree_map(lambda _: None, specs)
    dp = rules.dp_axes

    def one(leaf):
        if leaf.ndim == 0:
            return rules.sharding()
        spec = [None] * leaf.ndim
        if _div(leaf.shape[0], rules.dp_size):
            spec[0] = dp
        return rules.sharding(*spec)

    return jax.tree_util.tree_map(one, specs)


def _cache_leaf_pspec(path_str: str, leaf, rules: MeshRules, cfg):
    """Caches carry (L_seg, B, T/window, ...) leaves.

    Batch shards over dp; the time axis of KV-like leaves shards over
    'model' (sequence-sharded cache: this is what makes 32k x 128-batch
    decode fit HBM — see DESIGN.md).
    """
    tp = rules.tp_axis
    spec = [None] * leaf.ndim
    if leaf.ndim >= 2 and _div(leaf.shape[1], rules.dp_size):
        spec[1] = rules.dp_axes
    name = path_str.split("/")[-1]
    if name in ("k", "v", "ckv", "krope") and leaf.ndim >= 3 and _div(
        leaf.shape[2], rules.tp_size
    ):
        spec[2] = tp
    if name == "h" and leaf.ndim == 3 and _div(leaf.shape[2],
                                               rules.tp_size):
        spec[2] = tp  # RG-LRU state shards over lru channels
    if name == "conv" and leaf.ndim == 4 and _div(leaf.shape[3],
                                                  rules.tp_size):
        spec[3] = tp
    return P(*spec)


def cache_pspecs(cache_abstract, rules: MeshRules, cfg):
    def path_str(path):
        return "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )

    def one(path, leaf):
        if rules.mesh is None:
            return None
        return NamedSharding(
            rules.mesh, _cache_leaf_pspec(path_str(path), leaf, rules, cfg)
        )

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def train_state_specs(cfg: ModelConfig, rules: MeshRules):
    """(abstract params, abstract opt state, their shardings)."""
    params_abs = T.abstract_params(cfg)
    p_sh = param_shardings(params_abs, rules, cfg)
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    if rules.mesh is None:
        opt_sh = jax.tree_util.tree_map(lambda _: None, opt_abs)
    else:
        opt_sh = type(opt_abs)(
            step=rules.sharding(),
            mu=param_shardings(opt_abs.mu, rules, cfg),
            nu=param_shardings(opt_abs.nu, rules, cfg),
        )
    return params_abs, p_sh, opt_abs, opt_sh
