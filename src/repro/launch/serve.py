"""Batched serving driver: prefill + token-by-token decode with KV caches.

Serves any registry architecture (reduced ``--smoke`` config on CPU; the
full configs are exercised shape-only by launch/dryrun.py).  Demonstrates
the serving path the decode_32k / long_500k dry-run cells compile:

  prefill(prompt batch) -> caches -> decode_step x new_tokens

Request batching is continuous-lite: a fixed batch of B slots, each slot
carrying an independent prompt; finished slots are refilled from the queue
between decode bursts (slot-level batching is what the serve_step lowering
assumes — the cache layout is slot-major).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_3b --smoke \
      --requests 12 --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    import jax
    import jax.numpy as jnp

    from ..configs import smoke_config
    from ..distributed import MeshRules
    from ..models import transformer as T

    cfg = smoke_config(args.arch)
    rules = MeshRules(mesh=None)
    key = jax.random.PRNGKey(args.seed)
    key, kp = jax.random.split(key)
    params = T.init_params(kp, cfg)
    B, P, N = args.batch, args.prompt_len, args.new_tokens
    cache_len = args.cache_len or (P + N)

    prefill = jax.jit(
        lambda p, toks: T.prefill(p, cfg, rules, tokens=toks,
                                  cache_len=cache_len)
    )
    decode = jax.jit(
        lambda p, c, l, t: T.decode_step(p, c, l, cfg, rules, tokens=t)
    )

    # request queue: each request is an int32 prompt of length P
    key, kq = jax.random.split(key)
    prompts = jax.random.randint(
        kq, (args.requests, P), 0, cfg.vocab_size, dtype=jnp.int32
    )
    queue = list(range(args.requests))
    completed: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    tokens_out = 0
    batches = 0
    while queue:
        slot_ids = [queue.pop(0) for _ in range(min(B, len(queue)))]
        # pad the final partial batch by repeating the last request
        ids = (slot_ids + [slot_ids[-1]] * B)[:B]
        batch_prompts = prompts[np.asarray(ids)]
        logits, caches, length = prefill(params, batch_prompts)
        outs = [[] for _ in range(B)]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for s in range(B):
            outs[s].append(int(tok[s]))
        for _ in range(N - 1):
            logits, caches, length = decode(params, caches, length, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for s in range(B):
                outs[s].append(int(tok[s]))
        for s, rid in enumerate(slot_ids):
            completed[rid] = outs[s]
            tokens_out += len(outs[s])
        batches += 1
    dt = time.perf_counter() - t0
    report = {
        "arch": cfg.name,
        "requests": args.requests,
        "batches": batches,
        "new_tokens_per_request": N,
        "tokens_generated": tokens_out,
        "tokens_per_second": tokens_out / dt,
        "seconds": dt,
        "sample_output": completed[0][:8],
    }
    print(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    main()
