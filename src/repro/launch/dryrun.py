"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script jits the real entry point (train_step /
prefill / serve_step) against ShapeDtypeStruct inputs with the production
shardings, compiles it for the 16x16 (single-pod) or 2x16x16 (multi-pod)
mesh of placeholder CPU devices, and records:

  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — XLA's own (loop-unaware) numbers
  * launch.hlo_analysis         — trip-count-aware flops/bytes/collectives

Results go to results/dryrun/<arch>__<shape>__<mesh>.json; EXPERIMENTS.md
§Dry-run and benchmarks/roofline.py read them.

Usage:
  python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs 2]
"""
import argparse
import json
import os
import subprocess
import sys
import time

from repro.distributed.xla_flags import apply_xla_flags


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) as subprocesses")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--host-devices", type=int, default=512,
                    help="placeholder device count (tests use fewer)")
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. '2,2' or '2,2,2' (tests)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (tests)")
    ap.add_argument("--variant", default="baseline",
                    help="perf variant tag recorded in the result")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches for train "
                         "cells (activations scale with B/n)")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-(arch, shape) §Perf preset "
                         "(configs/perf_presets.py)")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", dest="overrides",
                    help="dataclasses.replace override on the model config "
                         "(int/float/str auto-coerced); repeatable")
    return ap.parse_args(argv)


ARGS = parse_args()
# ONE validated flag write, before the first jax use: apply_xla_flags
# raises if a backend already locked the device count (the old two-write
# shape set a module-level default and then silently overwrote it after
# parse_args, trusting nothing had initialized jax in between).
apply_xla_flags(host_device_count=ARGS.host_devices)

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.core  # noqa: E402  (x64 for the secure-agg variant)
from repro.configs import ARCH_IDS, get_config, smoke_config  # noqa: E402
from repro.distributed import MeshRules  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402
from repro.optim.adamw import AdamWConfig, adamw_update  # noqa: E402

LM_ARCHS = tuple(a for a in ARCH_IDS if a != "logreg_paper")


def make_mesh():
    from repro.distributed.compat import make_mesh as _make_mesh

    if ARGS.mesh_shape:
        dims = tuple(int(x) for x in ARGS.mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        return _make_mesh(dims, axes)
    return make_production_mesh(multi_pod=ARGS.multi_pod)


def lower_cell(cfg, shape, mesh):
    """Returns (lowered, compiled, timings) for one cell."""
    rules = MeshRules(mesh=mesh)
    inputs = SP.input_specs(cfg, shape)
    t0 = time.time()
    if shape.kind == "train":
        params_abs, p_sh, opt_abs, opt_sh = SP.train_state_specs(cfg, rules)
        b_sh = SP.batch_shardings(inputs, rules)
        opt_cfg = AdamWConfig()

        n_micro = max(ARGS.microbatch,
                      getattr(cfg, "train_microbatch", 1))

        def train_step(params, opt_state, batch):
            if n_micro <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    T.loss_fn, has_aux=True
                )(params, batch, cfg, rules)
            else:
                # gradient accumulation: scan over microbatches so the
                # remat-saved activations scale with B/n_micro, not B —
                # what makes the deepest/widest train cells fit HBM.
                def slice_mb(x):
                    B = x.shape[0]
                    return x.reshape((n_micro, B // n_micro) + x.shape[1:])

                mb_batch = jax.tree_util.tree_map(slice_mb, batch)

                def _gconstrain(g):
                    # keep the f32 accumulator sharded like the params —
                    # without this XLA replicates the carry (measured:
                    # +100 GB temp on the 32B/72B fsdp cells)
                    return jax.tree_util.tree_map(
                        lambda z, sh: (
                            jax.lax.with_sharding_constraint(z, sh)
                            if sh is not None else z
                        ), g, p_sh,
                    )

                def mb_step(carry, mb):
                    gacc, lacc = carry
                    (l, m), g = jax.value_and_grad(
                        T.loss_fn, has_aux=True
                    )(params, mb, cfg, rules)
                    gacc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g
                    )
                    # only g0 carries an explicit constraint; the carry
                    # keeps its sharding by propagation (verified: a
                    # per-iteration constraint changes nothing — the
                    # measured microbatch collective overhead is the per-
                    # microbatch gradient reductions themselves).
                    return (gacc, lacc + l), m

                g0 = _gconstrain(jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ))
                (gacc, lsum), ms = jax.lax.scan(
                    mb_step, (g0, jnp.zeros((), jnp.float32)), mb_batch
                )
                grads = jax.tree_util.tree_map(
                    lambda g: g / n_micro, gacc
                )
                loss = lsum / n_micro
                metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
            new_p, new_o, om = adamw_update(grads, opt_state, params,
                                            opt_cfg)
            return new_p, new_o, {**metrics, **om, "loss": loss}

        lowered = jax.jit(
            train_step,
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=(p_sh, opt_sh, None),
        ).lower(params_abs, opt_abs, inputs)
    elif shape.kind == "prefill":
        params_abs, p_sh, _, _ = SP.train_state_specs(cfg, rules)
        b_sh = SP.batch_shardings(inputs, rules)
        cache_abs = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        c_sh = SP.cache_pspecs(cache_abs, rules, cfg)
        logits_sh = rules.sharding(
            rules.dp_axes if shape.global_batch % rules.dp_size == 0
            else None,
            rules.tp_axis if cfg.vocab_size % rules.tp_size == 0 else None,
        )

        def prefill_step(params, batch):
            return T.prefill(params, cfg, rules,
                             tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"))

        lowered = jax.jit(
            prefill_step,
            in_shardings=(p_sh, b_sh),
            out_shardings=(logits_sh, c_sh, None),
        ).lower(params_abs, inputs)
    else:  # decode
        params_abs, p_sh, _, _ = SP.train_state_specs(cfg, rules)
        caches = inputs["caches"]
        c_sh = SP.cache_pspecs(caches, rules, cfg)
        tok_sh = SP.batch_shardings(
            {k: v for k, v in inputs.items()
             if k in ("tokens", "embeds")}, rules
        )
        logits_sh = rules.sharding(
            rules.dp_axes if shape.global_batch % rules.dp_size == 0
            else None,
            rules.tp_axis if cfg.vocab_size % rules.tp_size == 0 else None,
        )

        def serve_step(params, caches, length, batch):
            return T.decode_step(params, caches, length, cfg, rules,
                                 tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"))

        batch = {k: v for k, v in inputs.items()
                 if k in ("tokens", "embeds")}
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, None, tok_sh),
            out_shardings=(logits_sh, c_sh, None),
        ).lower(params_abs, caches, inputs["length"], batch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, {"lower_s": t_lower, "compile_s": t_compile}


def run_cell(arch: str, shape_name: str):
    cfg = smoke_config(arch) if ARGS.smoke else get_config(arch)
    shape = SHAPES[shape_name]
    if ARGS.optimized:
        from repro.configs.perf_presets import apply_preset
        cfg = apply_preset(cfg, shape)
    if ARGS.overrides:
        import dataclasses
        kv = {}
        for item in ARGS.overrides:
            key, val = item.split("=", 1)
            field_t = type(getattr(cfg, key))
            kv[key] = field_t(val) if field_t is not bool else val == "True"
        cfg = dataclasses.replace(cfg, **kv)
    mesh = make_mesh()
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "devices": int(np.prod(mesh.devices.shape)),
        "variant": ARGS.variant,
        "overrides": ARGS.overrides,
        "smoke": ARGS.smoke,
    }
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        result["skipped"] = (
            "pure full-attention arch: 512k dense decode excluded per "
            "DESIGN.md §Arch-applicability"
        )
        return result
    lowered, compiled, times = lower_cell(cfg, shape, mesh)
    result.update(times)
    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    result["xla_cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "note": "loop bodies counted once by XLA (see hlo_analysis)",
    }
    t0 = time.time()
    hlo_text = compiled.as_text()
    if not ARGS.smoke:
        import gzip
        os.makedirs(ARGS.out, exist_ok=True)
        mesh_tag = "multipod" if ARGS.multi_pod else "singlepod"
        if ARGS.variant != "baseline":
            mesh_tag += f"__{ARGS.variant}"
        hlo_path = os.path.join(
            ARGS.out, f"{arch}__{shape_name}__{mesh_tag}.hlo.gz"
        )
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo_text)
    hlo = analyze_hlo(hlo_text)
    result["hlo_analysis"] = {
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes,
        "collective_bytes_per_device": dict(hlo.collective_bytes),
        "collective_counts": dict(hlo.collective_count),
        "bytes_by_kind": dict(hlo.bytes_by_kind),
        "top_byte_buckets": [
            {"bucket": k, "bytes": v} for k, v in hlo.top_buckets()
        ],
        "analysis_s": time.time() - t0,
    }
    result["model"] = {
        "params": T.count_params(cfg),
        "active_params": T.count_params(cfg, active_only=True),
    }
    return result


def main():
    os.makedirs(ARGS.out, exist_ok=True)
    mesh_tag = "multipod" if ARGS.multi_pod else "singlepod"
    if ARGS.all:
        cells = [(a, s) for a in LM_ARCHS for s in SHAPES]
        procs: list = []
        failures = []

        def drain(block_all=False):
            while procs and (block_all or len(procs) >= ARGS.jobs):
                for i, (p, cell) in enumerate(procs):
                    if p.poll() is not None:
                        if p.returncode != 0:
                            failures.append(cell)
                            print(f"FAIL {cell}", flush=True)
                        procs.pop(i)
                        break
                else:
                    time.sleep(1.0)

        for arch, shape in cells:
            tag = mesh_tag if ARGS.variant == "baseline" else (
                f"{mesh_tag}__{ARGS.variant}"
            )
            out_file = os.path.join(
                ARGS.out, f"{arch}__{shape}__{tag}.json"
            )
            if os.path.exists(out_file):
                print(f"skip (exists): {out_file}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", ARGS.out,
                   "--variant", ARGS.variant]
            for item in ARGS.overrides:
                cmd += ["--set", item]
            if ARGS.optimized:
                cmd.append("--optimized")
            if ARGS.multi_pod:
                cmd.append("--multi-pod")
            if ARGS.smoke:
                cmd.append("--smoke")
            drain()
            print(f"launch: {arch} {shape} {mesh_tag}", flush=True)
            procs.append((subprocess.Popen(cmd), (arch, shape)))
        drain(block_all=True)
        print(f"done; {len(failures)} failures: {failures}", flush=True)
        sys.exit(1 if failures else 0)

    assert ARGS.arch and ARGS.shape, "--arch and --shape (or --all)"
    result = run_cell(ARGS.arch, ARGS.shape)
    tag = mesh_tag if ARGS.variant == "baseline" else (
        f"{mesh_tag}__{ARGS.variant}"
    )
    out_file = os.path.join(
        ARGS.out, f"{ARGS.arch}__{ARGS.shape}__{tag}.json"
    )
    with open(out_file, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
