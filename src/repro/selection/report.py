"""PathReport: the selection subsystem's deliverable.

Everything in here is computed from *revealed global aggregates* only —
per-λ per-fold validation deviance/accuracy sums over the whole cohort —
so the report is exactly what the paper's threat model allows the
consortium to learn: the CV curve, the selected λ, and the refit beta.
No per-institution validation score ever exists in the clear.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PathReport", "one_se_rule"]


def one_se_rule(lambdas: np.ndarray, cv_mean: np.ndarray,
                cv_se: np.ndarray) -> tuple[int, int]:
    """(best_index, one_se_index) over a DESCENDING λ grid.

    ``best`` minimizes the CV-mean held-out deviance; the 1-SE pick is the
    largest λ (strongest regularization, i.e. earliest index) whose CV
    mean is within one standard error of the best — the standard
    parsimony rule from glmnet-style CV.
    """
    best = int(np.argmin(cv_mean))
    bar = cv_mean[best] + cv_se[best]
    for i in range(len(lambdas)):  # descending: first hit = largest λ
        if cv_mean[i] <= bar:
            return best, i
    return best, best


@dataclasses.dataclass
class PathReport:
    """Cross-validated regularization-path results (revealed aggregates)."""

    lambdas: np.ndarray  # (L,) descending λ grid
    l1: float
    num_folds: int
    protect: str
    summaries_backend: str
    # per-(λ, fold) revealed CV aggregates
    fold_betas: np.ndarray  # (L, K, d) converged train-fold iterates
    fold_rounds: np.ndarray  # (L, K) secure rounds each config consumed
    fold_converged: np.ndarray  # (L, K) bool
    val_deviance: np.ndarray  # (L, K) held-out -2 log L (cohort sum)
    val_correct: np.ndarray  # (L, K) held-out correct predictions (sum)
    val_count: np.ndarray  # (L, K) held-out rows (sum)
    # CV curve + picks
    cv_mean: np.ndarray  # (L,) mean per-record held-out deviance
    cv_se: np.ndarray  # (L,) standard error over folds
    cv_accuracy: np.ndarray  # (L,) pooled held-out accuracy
    best_index: int
    lambda_best: float
    one_se_index: int
    lambda_1se: float
    # final model: full-data refit at lambda_1se (warm-started in-path)
    beta: np.ndarray | None  # (d,) or None when refit=False
    refit_rounds: int
    # telemetry (static shapes; no per-leaf walks anywhere)
    rounds_total: int  # secure rounds actually executed (skips excluded)
    bytes_per_round: int  # wire bytes of one (chunk x cohort) sweep round
    bytes_total: int
    # deviance traces, one entry per chunk: (rounds, C) objective rows as
    # read back in blocks from the scanned sweep
    traces: list = dataclasses.field(default_factory=list)

    def summary_lines(self) -> list[str]:
        """Human-readable CV curve for examples/CLI output."""
        lines = [
            f"{'lambda':>10}  {'cv deviance/row':>16}  {'+/- se':>10}  "
            f"{'heldout acc':>11}  {'rounds':>6}"
        ]
        for i, lam in enumerate(self.lambdas):
            tag = ""
            if i == self.best_index:
                tag += "  <- min"
            if i == self.one_se_index:
                tag += "  <- 1-SE pick"
            lines.append(
                f"{lam:>10.5g}  {self.cv_mean[i]:>16.6f}  "
                f"{self.cv_se[i]:>10.6f}  {self.cv_accuracy[i]:>11.4f}  "
                f"{int(self.fold_rounds[i].max()):>6d}{tag}"
            )
        return lines
