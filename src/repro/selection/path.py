"""Secure cross-validated regularization paths as batched multi-round graphs.

The sweep advances C = (λ-chunk x K folds) path configs at once through the
existing Shamir pipeline, everything batched and jit-resident:

* **one pass over the data per round** — fold masks compose onto the
  packed batch's ragged row masks (``batched_cv_summaries``), so every
  config's train-fold (H, g, dev) AND held-out deviance/accuracy come out
  of a single streaming launch; no per-fold repacking of X ever happens.
* **one protocol launch per phase per round** — the (C, S)-leading summary
  tree goes through ``SecureCollective.secure_round_multiconfig``: one
  encode+share launch over the C*S flat slices, one exact uint64
  reduction over the institution axis per config, one Lagrange+CRT reveal
  of the C global aggregates.  Held-out metrics ride in the same protected
  buffer — no center ever sees a per-institution validation score.
* **scan-resident rounds** — ``rounds_per_sync`` Newton rounds run as one
  ``lax.scan`` per host sync, with the per-round protect rng folded
  IN-GRAPH from a single path key (``fold_in(key, round_slot)``; no host
  re-splitting, the ROADMAP follow-up this retires for the selection
  path).  Converged configs freeze (their betas stop updating, matching
  the sequential drivers' break-before-update semantics) and once a whole
  chunk has converged the remaining scan slots skip the round body via
  ``lax.cond``, so overshoot costs nothing.  The deviance trace comes
  back in (rounds_per_sync, C) blocks — the only host transfer.
* **warm starts along the path** — the λ grid (sorted descending, the
  glmnet direction) is processed in chunks of ``lam_block`` path points;
  each chunk's fold iterates initialize from the previous chunk's
  converged fold betas, which is what collapses late-path Newton counts
  to 2-3 rounds.  ``lam_block=len(lambdas)`` degenerates to the fully
  amortized single-batch sweep (every path point in every launch);
  ``lam_block=1`` maximizes warm-start reuse.  Both shapes converge to
  the same per-config fixed points — Newton's answer does not depend on
  its starting point — so the precision contract vs the per-(λ, fold)
  sequential oracle is the summaries ladder's converged-beta contract.

The final refit runs through the SAME machinery: a trailing 1-config
chunk with ``fold == -1`` (no held-out rows — the masks reduce to the
plain row masks) at the 1-SE λ, warm-started from that λ's fold betas.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batched_summaries import (
    BACKENDS as SUMMARY_BACKENDS,
    PackedPartitions,
    batched_cv_summaries,
    pack_partitions,
)
from ..core.collective import SecureCollective, declassify_sum
from ..core.newton import (
    newton_step,
    prox_newton_step,
    regularized_objective,
    should_stop,
)
from ..core.scanfit import scan_rounds
from ..obs import metrics as _metrics
from ..obs.trace import traced as _traced
from .folds import assign_folds, pack_fold_ids
from .report import PathReport, one_se_rule

__all__ = ["PathSettings", "PathDriver", "secure_cv_path"]

PROTECT_CHOICES = ("none", "gradient", "hessian", "both")


def _batched_update(betas, H, g, lams, l1: float):
    """vmapped Newton / prox-Newton step, per-config λ."""
    H = jnp.asarray(H, jnp.float64)
    g = jnp.asarray(g, jnp.float64)
    if l1 == 0.0:
        return jax.vmap(newton_step)(betas, H, g, lams)
    return jax.vmap(
        lambda b, Hc, gc, lc: prox_newton_step(b, Hc, gc, lc, l1)
    )(betas, H, g, lams)


@functools.partial(
    jax.jit,
    static_argnames=("agg", "protect", "l1", "tol", "interpret", "points",
                     "summaries_backend", "num_rounds", "num_parts",
                     "max_rounds"),
)
def _cv_sweep_block(betas, obj_prev, converged, iters, vdev, vcorr, vcnt,
                    key, round_base, X, X32, y, counts, fold_ids, fold_of,
                    lams, agg: SecureCollective, protect: str, l1: float,
                    tol: float, interpret: bool,
                    points: tuple[int, ...] | None,
                    summaries_backend: str, num_rounds: int,
                    num_parts: int, max_rounds: int):
    """``num_rounds`` secure sweep rounds as ONE jitted lax.scan.

    Carry: per-config (betas, obj_prev, converged, iters, held-out
    stats) plus the global round slot counter that folds the protect rng
    in-graph.  Emits the (num_rounds, C) objective/active trace blocks —
    the caller's only readback.  The ``max_rounds`` budget is enforced
    IN-GRAPH per config: no config ever executes a round past it
    regardless of the scan block length, and a config spending its last
    budgeted round keeps the beta its revealed metrics were measured at
    (so an unconverged config's reported beta and CV metrics always
    correspond — the same break-before-update shape convergence uses).
    """
    packed = PackedPartitions(X, X32, y, counts)
    scale = agg.codec.scale

    def round_fn(carry):
        betas, obj_prev, converged, iters, vdev, vcorr, vcnt, slot = carry
        kr = agg.round_key(key, slot)
        sm = batched_cv_summaries(
            betas, packed, fold_ids, fold_of,
            backend=summaries_backend, interpret=interpret,
        )
        tree = {}
        if protect in ("gradient", "both"):
            tree["gradient"] = sm.gradient
        if protect in ("hessian", "both"):
            tree["hessian"] = sm.hessian
        if protect != "none":
            tree["deviance"] = sm.deviance
            tree["count"] = sm.count
            tree["val_deviance"] = sm.val_deviance
            tree["val_correct"] = sm.val_correct
            tree["val_count"] = sm.val_count
            revealed = agg.secure_round_multiconfig(kr, tree, points=points)
        else:
            revealed = {}
        # unprotected leaves leave the round ONLY as cross-institution
        # sums (axis 1 of the (C, S, ...) summaries) — the annotated
        # declassification the static gate checks
        H = revealed["hessian"] if protect in ("hessian", "both") \
            else declassify_sum(sm.hessian, axis=1)
        g = revealed["gradient"] if protect in ("gradient", "both") \
            else declassify_sum(sm.gradient, axis=1)
        dev = revealed["deviance"] if protect != "none" \
            else declassify_sum(sm.deviance, axis=1)
        vdev_r = revealed.get("val_deviance",
                              declassify_sum(sm.val_deviance, axis=1))
        vcorr_r = revealed.get("val_correct",
                               declassify_sum(sm.val_correct, axis=1))
        vcnt_r = revealed.get("val_count",
                              declassify_sum(sm.val_count, axis=1))
        obj = regularized_objective(dev, betas, lams, l1)  # (C,)
        active = ~converged & (iters < max_rounds)
        # the one stopping rule, vectorized over the config axis
        stop = should_stop(obj_prev, obj, tol, num_parts, scale)
        conv_new = converged | (active & stop)
        beta_new = _batched_update(betas, H, g, lams, l1)
        # sequential break-before-update semantics: a config that stops
        # this round — or spends its last budgeted round — keeps the
        # beta its objective and held-out metrics were measured at
        exhausting = active & (iters + 1 >= max_rounds)
        freeze = conv_new | exhausting | ~active
        betas = jnp.where(freeze[:, None], betas, beta_new)
        obj_prev = jnp.where(freeze, obj_prev, obj)
        iters = iters + active.astype(jnp.int32)
        # held-out stats freeze at the stopping round's (= reported
        # beta's) values; they keep tracking while the config moves
        vdev = jnp.where(active, vdev_r, vdev)
        vcorr = jnp.where(active, vcorr_r, vcorr)
        vcnt = jnp.where(active, vcnt_r, vcnt)
        return ((betas, obj_prev, conv_new, iters, vdev, vcorr, vcnt,
                 slot + 1), (obj, active))

    def skip_fn(carry):
        # whole chunk converged/out of budget: remaining slots are free
        (betas, obj_prev, converged, iters, vdev, vcorr, vcnt,
         slot) = carry
        return ((betas, obj_prev, converged, iters, vdev, vcorr, vcnt,
                 slot + 1),
                (obj_prev, jnp.zeros_like(converged)))

    def settled(carry):
        return jnp.all(carry[2] | (carry[3] >= max_rounds))

    carry0 = (betas, obj_prev, converged, iters, vdev, vcorr, vcnt,
              round_base)
    carry, (objs, actives) = scan_rounds(
        round_fn, skip_fn, settled, carry0, num_rounds
    )
    return carry, objs, actives


@dataclasses.dataclass(frozen=True)
class PathSettings:
    """Static configuration of one λ-path sweep (hashable; the jit keys)."""

    lambdas: tuple[float, ...]  # DESCENDING
    num_folds: int = 5
    l1: float = 0.0
    protect: str = "gradient"
    tol: float = 1e-10
    summaries_backend: str = "pallas"
    lam_block: int = 1
    rounds_per_sync: int = 8
    max_rounds: int = 50
    warm_start: bool = True
    refit: bool = True
    seed: int = 0
    fold_seed: int = 0

    def __post_init__(self):
        if len(self.lambdas) == 0:
            raise ValueError("need at least one lambda")
        if any(a <= b for a, b in zip(self.lambdas, self.lambdas[1:])):
            raise ValueError(
                "lambdas must be strictly descending (duplicates would "
                "run identical configs through every secure round)"
            )
        if self.protect not in PROTECT_CHOICES:
            raise ValueError(f"protect must be one of {PROTECT_CHOICES}")
        if self.summaries_backend not in SUMMARY_BACKENDS:
            raise ValueError(
                f"summaries_backend must be one of {SUMMARY_BACKENDS}"
            )
        if not (1 <= self.lam_block <= len(self.lambdas)):
            raise ValueError("lam_block must be in 1..len(lambdas)")
        if self.rounds_per_sync < 1:
            raise ValueError("rounds_per_sync must be >= 1")
        if self.max_rounds < 1:
            raise ValueError(
                "max_rounds must be >= 1 (0 would 'run' the sweep without "
                "a single secure round and report all-zero betas)"
            )
        if self.num_folds < 2:
            raise ValueError("need at least 2 folds")


class PathDriver:
    """Chunked execution of a PathSettings sweep over caller-supplied parts.

    The driver is deliberately split from data access: each chunk takes
    the *current* partitions + per-institution fold ids (the
    ``SelectionCoordinator`` re-forms its cohort per chunk; the
    functional ``secure_cv_path`` passes the same parts every time), so
    membership churn between chunks composes with the churn-safe fold
    assignment.  All cross-chunk state lives in a plain dict of numpy
    arrays — that dict IS the mid-path checkpoint.
    """

    def __init__(self, settings: PathSettings, agg: SecureCollective):
        if agg.backend != "pallas":
            raise ValueError(
                "the selection sweep requires the pallas backend (the flat "
                "share buffers ARE the batched multi-config wire format)"
            )
        self.settings = settings
        self.agg = agg
        self.key = jax.random.PRNGKey(settings.seed)

    # -- chunk schedule -------------------------------------------------------
    def chunks(self) -> list[tuple[int, ...]]:
        s = self.settings
        L = len(s.lambdas)
        out = [tuple(range(i, min(i + s.lam_block, L)))
               for i in range(0, L, s.lam_block)]
        return out

    def num_chunks(self) -> int:
        # +1: the trailing full-data refit chunk at the selected λ
        return len(self.chunks()) + (1 if self.settings.refit else 0)

    # -- state ----------------------------------------------------------------
    def fresh_state(self) -> dict:
        s = self.settings
        L, K = len(s.lambdas), s.num_folds
        return {
            "next_chunk": np.asarray(0),
            "warm": np.zeros((0, 0)),  # (K, d) once known
            "fold_betas": np.zeros((0,)),  # (L, K, d) once d known
            "fold_rounds": np.zeros((L, K), np.int32),
            "fold_converged": np.zeros((L, K), bool),
            "val_deviance": np.zeros((L, K)),
            "val_correct": np.zeros((L, K)),
            "val_count": np.zeros((L, K)),
            "round_base": np.asarray(0),
            "rounds_total": np.asarray(0),
            "bytes_total": np.asarray(0, np.int64),
            "bytes_per_round": np.asarray(0, np.int64),
            "beta": np.zeros((0,)),  # refit result
            "refit_rounds": np.asarray(0),
            "refit_converged": np.asarray(False),
        }

    def finished(self, state: dict) -> bool:
        return int(state["next_chunk"]) >= self.num_chunks()

    # -- one chunk ------------------------------------------------------------
    @_traced("selection")
    def run_chunk(self, state: dict, parts: Sequence, fold_parts: Sequence,
                  points: Sequence[int] | None = None,
                  num_live_centers: int | None = None,
                  traces: list | None = None) -> dict:
        """Advance the sweep by one λ chunk (or the final refit chunk).

        ``parts``/``fold_parts`` describe the current cohort;
        ``points``/``num_live_centers`` are the coordinator's live-center
        hooks (None: secure_fit-style defaults).  ``traces`` (optional
        list) receives the per-block objective readbacks.
        """
        s = self.settings
        chunk_idx = int(state["next_chunk"])
        schedule = self.chunks()
        if chunk_idx >= self.num_chunks():
            return state
        is_refit = chunk_idx >= len(schedule)

        packed = pack_partitions(parts)
        fold_ids = pack_fold_ids(fold_parts, packed.X.shape[1])
        d = packed.dim
        K = s.num_folds
        if state["fold_betas"].size == 0:
            state["fold_betas"] = np.zeros((len(s.lambdas), K, d))
        if state["warm"].size == 0:
            state["warm"] = np.zeros((K, d))

        if is_refit:
            lam_idx: tuple[int, ...] = ()
            pick = self._one_se_index(state)
            lams = np.asarray([s.lambdas[pick]])
            fold_of = np.asarray([-1], np.int32)
            # warm-start the full-data fit from that λ's mean fold beta
            betas0 = np.mean(state["fold_betas"][pick], axis=0,
                             keepdims=True)
            cfg_rows = 1
        else:
            lam_idx = schedule[chunk_idx]
            lams = np.repeat(np.asarray(s.lambdas)[list(lam_idx)], K)
            fold_of = np.tile(np.arange(K, dtype=np.int32), len(lam_idx))
            if s.warm_start:
                betas0 = np.tile(state["warm"][None], (len(lam_idx), 1, 1)
                                 ).reshape(-1, d)
            else:
                betas0 = np.zeros((len(lam_idx) * K, d))
            cfg_rows = len(lam_idx) * K

        bytes_per_round = self.agg.round_bytes(
            d, packed.num_institutions, s.protect,
            include_count=True, num_live_centers=num_live_centers,
            num_configs=cfg_rows, extra_scalars=3,
        )
        if not is_refit:
            # the report's representative wire figure: one sweep round of
            # a full (λ-chunk x cohort) batch (the refit chunk is a
            # 1-config tail and accounts into bytes_total only)
            state["bytes_per_round"] = np.asarray(bytes_per_round,
                                                  np.int64)

        carry = (
            jnp.asarray(betas0, jnp.float64),
            jnp.full((cfg_rows,), np.inf, jnp.float64),
            jnp.zeros((cfg_rows,), bool),
            jnp.zeros((cfg_rows,), jnp.int32),
            jnp.zeros((cfg_rows,), jnp.float64),
            jnp.zeros((cfg_rows,), jnp.float64),
            jnp.zeros((cfg_rows,), jnp.float64),
            jnp.asarray(int(state["round_base"]), jnp.int32),
        )
        lams_j = jnp.asarray(lams, jnp.float64)
        fold_of_j = jnp.asarray(fold_of, jnp.int32)
        pts = tuple(points) if points is not None else None
        if s.protect == "none":
            pts = None
        chunk_trace = []
        executed = 0
        while True:
            carry, objs, actives = _cv_sweep_block(
                *carry[:7], self.key, carry[7], packed.X, packed.X32,
                packed.y, packed.counts, fold_ids, fold_of_j, lams_j,
                agg=self.agg, protect=s.protect, l1=float(s.l1),
                tol=float(s.tol), interpret=self.agg.scheme.interpret,
                points=pts, summaries_backend=s.summaries_backend,
                num_rounds=s.rounds_per_sync,
                num_parts=packed.num_institutions,
                max_rounds=s.max_rounds,
            )
            # host-sync: the block's ONE readback — trace + carry in a
            # single transfer (the carry itself stays on device for the
            # next block dispatch)
            (objs, actives, betas_f, conv_f, iters_f, vdev_f, vcorr_f,
             vcnt_f, base_f) = jax.device_get(
                (objs, actives, carry[0], carry[2], carry[3], carry[4],
                 carry[5], carry[6], carry[7])
            )
            chunk_trace.append(objs)
            executed += int(actives.any(axis=1).sum())
            if bool(conv_f.all()) or int(iters_f.max()) >= s.max_rounds:
                break

        state["round_base"] = np.asarray(int(base_f))
        state["rounds_total"] = np.asarray(
            int(state["rounds_total"]) + executed
        )
        state["bytes_total"] = np.asarray(
            int(state["bytes_total"]) + executed * bytes_per_round,
            np.int64,
        )
        if executed:
            _metrics.observe_round("selection_path", bytes_per_round,
                                   rounds=executed)
        if traces is not None:
            traces.append({
                "chunk": chunk_idx,
                "lambdas": lams.copy(),
                "objectives": np.concatenate(chunk_trace, axis=0),
            })
        if is_refit:
            state["beta"] = betas_f[0]
            state["refit_rounds"] = np.asarray(int(iters_f[0]))
            state["refit_converged"] = np.asarray(bool(conv_f[0]))
        else:
            by_lam = betas_f.reshape(len(lam_idx), K, d)
            for row, li in enumerate(lam_idx):
                state["fold_betas"][li] = by_lam[row]
                state["fold_rounds"][li] = iters_f.reshape(-1, K)[row]
                state["fold_converged"][li] = conv_f.reshape(-1, K)[row]
                state["val_deviance"][li] = vdev_f.reshape(-1, K)[row]
                state["val_correct"][li] = vcorr_f.reshape(-1, K)[row]
                state["val_count"][li] = vcnt_f.reshape(-1, K)[row]
            # warm-start source for the next chunk: the LAST (smallest)
            # λ of this chunk, the path neighbour of the next chunk
            state["warm"] = by_lam[-1].copy()
        state["next_chunk"] = np.asarray(chunk_idx + 1)
        return state

    # -- reporting ------------------------------------------------------------
    def _cv_curve(self, state: dict):
        vcnt = np.maximum(state["val_count"], 1.0)
        per_rec = state["val_deviance"] / vcnt  # (L, K)
        cv_mean = per_rec.mean(axis=1)
        cv_se = per_rec.std(axis=1, ddof=1) / np.sqrt(per_rec.shape[1])
        cv_acc = (state["val_correct"].sum(axis=1)
                  / np.maximum(state["val_count"].sum(axis=1), 1.0))
        return cv_mean, cv_se, cv_acc

    def _one_se_index(self, state: dict) -> int:
        cv_mean, cv_se, _ = self._cv_curve(state)
        _, pick = one_se_rule(
            np.asarray(self.settings.lambdas), cv_mean, cv_se
        )
        return pick

    def build_report(self, state: dict, traces: list | None = None
                     ) -> PathReport:
        s = self.settings
        cv_mean, cv_se, cv_acc = self._cv_curve(state)
        best, pick = one_se_rule(np.asarray(s.lambdas), cv_mean, cv_se)
        return PathReport(
            lambdas=np.asarray(s.lambdas),
            l1=s.l1,
            num_folds=s.num_folds,
            protect=s.protect,
            summaries_backend=s.summaries_backend,
            fold_betas=state["fold_betas"].copy(),
            fold_rounds=state["fold_rounds"].copy(),
            fold_converged=state["fold_converged"].copy(),
            val_deviance=state["val_deviance"].copy(),
            val_correct=state["val_correct"].copy(),
            val_count=state["val_count"].copy(),
            cv_mean=cv_mean,
            cv_se=cv_se,
            cv_accuracy=cv_acc,
            best_index=best,
            lambda_best=float(s.lambdas[best]),
            one_se_index=pick,
            lambda_1se=float(s.lambdas[pick]),
            beta=(state["beta"].copy() if state["beta"].size else None),
            refit_rounds=int(state["refit_rounds"]),
            rounds_total=int(state["rounds_total"]),
            bytes_per_round=int(state["bytes_per_round"]),
            bytes_total=int(state["bytes_total"]),
            traces=list(traces) if traces is not None else [],
        )


def secure_cv_path(
    parts: Sequence,
    lambdas: Sequence[float],
    num_folds: int = 5,
    l1: float = 0.0,
    protect: str = "gradient",
    aggregator: SecureCollective | None = None,
    tol: float = 1e-10,
    seed: int = 0,
    fold_seed: int = 0,
    summaries_backend: str = "pallas",
    lam_block: int = 1,
    rounds_per_sync: int = 8,
    max_rounds: int = 50,
    warm_start: bool = True,
    refit: bool = True,
) -> PathReport:
    """Run the whole secure CV λ-path over fixed (X_j, y_j) partitions.

    The in-process mirror of ``SelectionCoordinator.run_path`` (which
    adds fault tolerance, churn, and resume): K-fold cross-validated
    held-out deviance for every λ, all through the Shamir pipeline, plus
    the 1-SE-rule pick and a warm-started full-data refit at the picked
    λ.  Partitions are indexed by position for the churn-safe fold
    assignment, so the same parts always get the same folds.
    """
    settings = PathSettings(
        lambdas=tuple(sorted((float(l) for l in lambdas), reverse=True)),
        num_folds=num_folds, l1=float(l1), protect=protect, tol=tol,
        summaries_backend=summaries_backend, lam_block=lam_block,
        rounds_per_sync=rounds_per_sync, max_rounds=max_rounds,
        warm_start=warm_start, refit=refit, seed=seed, fold_seed=fold_seed,
    )
    agg = aggregator or SecureCollective(backend="pallas")
    driver = PathDriver(settings, agg)
    fold_parts = [
        assign_folds(Xj.shape[0], num_folds, j, fold_seed)
        for j, (Xj, _) in enumerate(parts)
    ]
    state = driver.fresh_state()
    traces: list = []
    while not driver.finished(state):
        state = driver.run_chunk(state, parts, fold_parts, traces=traces)
    return driver.build_report(state, traces)
