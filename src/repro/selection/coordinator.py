"""Deployment-shaped driver for the secure model-selection subsystem.

``SelectionCoordinator`` wraps a ``StudyCoordinator`` — reusing its cohort
formation (stragglers, elastic membership), live-center accounting, churn
hooks, and checkpoint conventions — and drives the chunked λ-path sweep
(``PathDriver``) across whatever cohort is present at each chunk boundary:

* **churn-safe folds** — fold membership is a pure function of the
  institution's *name* (``selection.folds``), so institutions that join,
  leave, or straggle between chunks never perturb anyone else's fold
  assignment; a returning institution resumes its exact folds.
* **mid-path resume** — ``state_dict``/``load_state_dict`` round-trip the
  whole sweep state (chunk cursor, warm-start betas, accumulated CV
  aggregates, rng round counter).  The per-round protect randomness is
  folded in-graph from (seed, round slot), so a resumed sweep replays
  bit-identically to an uninterrupted one.
* **secure CV metrics end to end** — per-institution held-out
  deviance/accuracy travel only as Shamir shares inside the per-round
  multi-config buffer; the coordinator (and every center) learns the
  cross-institution sums per (λ, fold) only.
* **telemetry from static shapes** — bytes/round for the (chunk x
  cohort) sweep from the same size model as the round protocols; no
  per-leaf walks.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..core.protocol import Institution, StudyCoordinator
from ..core.collective import SecureCollective
from ..obs.trace import traced as _traced
from .folds import assign_folds
from .path import PathDriver, PathSettings
from .report import PathReport

__all__ = ["SelectionCoordinator"]


class SelectionCoordinator:
    """Cross-validated λ selection over a fault-tolerant consortium."""

    def __init__(
        self,
        institutions: Sequence[Institution],
        lambdas: Sequence[float],
        num_folds: int = 5,
        l1: float = 0.0,
        protect: str = "gradient",
        aggregator: SecureCollective | None = None,
        num_centers: int | None = None,
        deadline: float | None = None,
        min_responders: int = 1,
        tol: float = 1e-10,
        seed: int = 0,
        fold_seed: int = 0,
        summaries_backend: str = "pallas",
        lam_block: int = 1,
        rounds_per_sync: int = 8,
        max_rounds: int = 50,
        warm_start: bool = True,
        refit: bool = True,
    ):
        agg = aggregator or SecureCollective(backend="pallas")
        self.settings = PathSettings(
            lambdas=tuple(sorted((float(l) for l in lambdas),
                                 reverse=True)),
            num_folds=num_folds, l1=float(l1), protect=protect, tol=tol,
            summaries_backend=summaries_backend, lam_block=lam_block,
            rounds_per_sync=rounds_per_sync, max_rounds=max_rounds,
            warm_start=warm_start, refit=refit, seed=seed,
            fold_seed=fold_seed,
        )
        # the wrapped deployment shape: cohort/straggler/center/churn
        # management all comes from the StudyCoordinator (fused rounds
        # share the pallas aggregator the sweep requires)
        self.study = StudyCoordinator(
            institutions, lam=self.settings.lambdas[0], protect=protect,
            aggregator=agg, num_centers=num_centers, deadline=deadline,
            min_responders=min_responders, tol=tol, seed=seed, fused=True,
            summaries_backend=summaries_backend,
        )
        self.driver = PathDriver(self.settings, self.study.agg)
        self.state = self.driver.fresh_state()
        self.traces: list = []
        self.report: PathReport | None = None

    # -- membership passthrough (fold-safe by construction) -------------------
    def add_institution(self, inst: Institution):
        self.study.add_institution(inst)

    def remove_institution(self, name: str):
        self.study.remove_institution(name)

    def provision_center(self, index: int | None = None):
        return self.study.provision_center(index)

    @property
    def num_chunks(self) -> int:
        return self.driver.num_chunks()

    @property
    def next_chunk(self) -> int:
        return int(self.state["next_chunk"])

    def finished(self) -> bool:
        return self.driver.finished(self.state)

    # -- the sweep ------------------------------------------------------------
    @_traced("selection")
    def step_chunk(self):
        """Advance the path by one λ chunk on the CURRENT cohort.

        Cohort and live centers are re-formed at every chunk boundary —
        the same fault model as ``StudyCoordinator.step``, at chunk
        granularity: stragglers/offline institutions are excluded from
        every round of this chunk (their folds are untouched for when
        they return), and a below-threshold center set raises before any
        computation.  Armed mid-round center-death hooks fire at the same
        boundary (chunk granularity — the sweep's protect..reveal lives
        inside one scan): >= t survivors reveal the whole chunk
        bit-identically, below t the chunk aborts unrun and a retry
        re-shares.
        """
        cohort = self.study.cohort()
        self.study._fire_midround_hooks()
        if self.settings.protect != "none":
            points = tuple(c.index for c in self.study.live_centers())
            num_live = len(points)
        else:
            points, num_live = None, None
        fold_parts = [
            assign_folds(inst.X.shape[0], self.settings.num_folds,
                         inst.name, self.settings.fold_seed)
            for inst in cohort
        ]
        self.state = self.driver.run_chunk(
            self.state, [(i.X, i.y) for i in cohort], fold_parts,
            points=points, num_live_centers=num_live, traces=self.traces,
        )

    def run_path(self) -> PathReport:
        """Run (or resume) the sweep to completion and build the report."""
        while not self.finished():
            self.step_chunk()
        self.report = self.driver.build_report(self.state, self.traces)
        # surface the selected model on the wrapped coordinator so
        # downstream protocol tooling (checkpointing, serving) sees the
        # refit beta as the study's current iterate
        if self.report.beta is not None:
            import jax.numpy as jnp

            self.study.beta = jnp.asarray(self.report.beta)
            self.study.lam = self.report.lambda_1se
        return self.report

    # -- checkpoint/restart ---------------------------------------------------
    def state_dict(self) -> dict:
        # snapshot by copy: run_chunk mutates the sweep arrays in place,
        # so returning live views would let a captured checkpoint drift
        # as the sweep advances
        out = {f"path_{k}": np.array(v) for k, v in self.state.items()}
        out.update(
            {f"study_{k}": v for k, v in self.study.state_dict().items()}
        )
        return out

    def load_state_dict(self, state: dict):
        """Restore a mid-path checkpoint.  The sweep state (betas, CV
        aggregates, rng round counter, byte totals) round-trips exactly;
        the per-block objective ``traces`` are session-local debugging
        readbacks and restart empty — a resumed report's ``traces``
        cover post-resume chunks only, while its totals span the whole
        sweep."""
        self.state = {
            k[len("path_"):]: np.array(v) for k, v in state.items()
            if k.startswith("path_")
        }
        self.study.load_state_dict({
            k[len("study_"):]: v for k, v in state.items()
            if k.startswith("study_")
        })
        self.traces = []
        self.report = None
