"""Secure model selection: cross-validated regularization paths.

The paper fits one fixed λ; a real consortium study must *choose* λ — and
per-fold validation statistics are exactly the per-institution summaries
the threat model says must never be revealed.  This subsystem runs the
full (λ-grid x K-fold) sweep through the existing Shamir pipeline as
batched multi-round secure graphs: fold masks composed onto the packed
row masks (one data pass per round, no per-fold repacking), a leading
config axis over protect -> aggregate -> reveal (one launch per protocol
phase per round regardless of path length), scan-resident Newton rounds
with in-graph rng, warm starts along the descending λ path, and a
1-SE-rule pick with a warm-started full-data refit.

Entry points: ``secure_cv_path`` (in-process, fixed partitions) and
``SelectionCoordinator`` (deployment-shaped: fault tolerance, churn-safe
folds, mid-path resume).
"""
from .coordinator import SelectionCoordinator
from .folds import assign_folds, pack_fold_ids
from .path import PathDriver, PathSettings, secure_cv_path
from .report import PathReport, one_se_rule

__all__ = [
    "SelectionCoordinator",
    "assign_folds", "pack_fold_ids",
    "PathDriver", "PathSettings", "secure_cv_path",
    "PathReport", "one_se_rule",
]
