"""Churn-safe cross-validation fold assignment.

Fold membership is a *deterministic function of the institution's identity*
(its name, hashed salt-free) and the fold seed — never of the cohort
composition.  An institution joining or leaving a consortium study mid-path
therefore cannot reshuffle anyone else's folds: every other institution's
rows keep their assignments bit-for-bit, which is what lets a resumed or
churned λ-path sweep stay comparable round to round (and is the fold-level
analogue of the coordinator's churn-safe pack-cache invalidation).

Within an institution the assignment is balanced (fold sizes differ by at
most one row) and pseudo-random (a permuted ``arange % K`` pattern), which
mirrors the stratification-free random K-fold split the paper's synthetic
evaluation would use.
"""
from __future__ import annotations

import zlib
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["assign_folds", "pack_fold_ids"]


def assign_folds(num_rows: int, num_folds: int, name: str | int,
                 fold_seed: int = 0) -> jnp.ndarray:
    """(num_rows,) int32 fold ids in [0, num_folds) for one institution.

    Depends only on (``name``, ``fold_seed``, ``num_rows``, ``num_folds``)
    — crc32 is salt-free (unlike ``hash``, which PYTHONHASHSEED
    randomizes), so assignments reproduce across processes, resumes, and
    cohort churn.  Balanced: a shuffled repetition of 0..K-1.
    """
    if num_folds < 2:
        raise ValueError("need at least 2 folds")
    if num_rows < num_folds:
        raise ValueError(
            f"institution {name!r} has {num_rows} rows < {num_folds} folds"
        )
    key = jax.random.fold_in(
        jax.random.PRNGKey(fold_seed),
        zlib.crc32(str(name).encode()) & 0x7FFFFFFF,
    )
    pattern = jnp.arange(num_rows, dtype=jnp.int32) % num_folds
    return jax.random.permutation(key, pattern)


def pack_fold_ids(fold_parts: Sequence[jnp.ndarray], n_max: int) -> jnp.ndarray:
    """Stack per-institution fold ids into the packed (S, N_max) layout.

    Padding rows get -1; the value is inert either way because the packed
    batch's ragged row mask already excludes rows >= counts[s] from both
    the train and the held-out mask.
    """
    return jnp.stack([
        jnp.pad(jnp.asarray(f, jnp.int32), (0, n_max - f.shape[0]),
                constant_values=-1)
        for f in fold_parts
    ])
