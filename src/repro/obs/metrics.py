"""Process metrics registry + Prometheus textfile export + the shared
collective byte conventions.

Two halves:

* **Registry** — labeled counters/gauges the drivers update once per
  round (``repro_rounds_total``, ``repro_round_bytes``,
  ``repro_objective`` / ``repro_grad_norm`` / ``repro_step_norm`` from
  the in-graph metric leaves) and the audit folds the privacy ledger
  into (``repro_declass_total{site=...}``).
  :func:`render_prometheus` / :func:`export_textfile` emit the standard
  Prometheus text exposition format, ready for the node-exporter
  textfile collector — the scrape surface ROADMAP direction 1's study
  server schedules on.
* **Byte conventions** — the ONE definition of what a ring collective
  moves, shared by ``launch/hlo_analysis.py`` (HLO walking),
  ``core/newton._iteration_bytes`` consumers and the obs gauges, pinned
  together by ``tests/test_byte_accounting.py``: an all-reduce moves
  2x its result bytes (ring reduce-scatter + all-gather phases), a
  reduce-scatter moves its OPERAND bytes, an all-gather its result
  bytes — so a reduce-scatter + all-gather pair over one logical buffer
  sums to exactly the all-reduce figure.

Stdlib-only on purpose (the obs purity lint enforces it): imported by
core driver modules at load time.
"""
from __future__ import annotations

import threading

__all__ = [
    "ALL_REDUCE_FACTOR",
    "REDUCE_SCATTER_FACTOR",
    "ALL_GATHER_FACTOR",
    "all_reduce_bytes",
    "reduce_scatter_bytes",
    "all_gather_bytes",
    "inc",
    "set_gauge",
    "get",
    "snapshot",
    "reset",
    "observe_round",
    "render_prometheus",
    "export_textfile",
]

# -- collective byte conventions (single source of truth) -------------------

ALL_REDUCE_FACTOR = 2.0      # x result bytes: RS phase + AG phase of a ring
REDUCE_SCATTER_FACTOR = 1.0  # x OPERAND bytes: ring moves the full input
ALL_GATHER_FACTOR = 1.0      # x result bytes: the full gathered buffer


def all_reduce_bytes(result_bytes: float) -> float:
    return ALL_REDUCE_FACTOR * result_bytes


def reduce_scatter_bytes(operand_bytes: float) -> float:
    return REDUCE_SCATTER_FACTOR * operand_bytes


def all_gather_bytes(result_bytes: float) -> float:
    return ALL_GATHER_FACTOR * result_bytes


# -- registry ---------------------------------------------------------------

_lock = threading.Lock()
# (name, ((label, value), ...)) -> float
_counters: dict = {}
_gauges: dict = {}


def _key(name: str, labels: dict):
    return name, tuple(sorted(labels.items()))


def inc(name: str, value: float = 1.0, **labels) -> None:
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0.0) + value


def set_gauge(name: str, value: float, **labels) -> None:
    with _lock:
        _gauges[_key(name, labels)] = float(value)


def get(name: str, **labels):
    """Current value of a counter or gauge (None if never touched)."""
    k = _key(name, labels)
    with _lock:
        if k in _counters:
            return _counters[k]
        return _gauges.get(k)


def snapshot() -> dict:
    with _lock:
        return {"counters": dict(_counters), "gauges": dict(_gauges)}


def reset() -> None:
    with _lock:
        _counters.clear()
        _gauges.clear()


def observe_round(driver: str, nbytes: int, objective: float | None = None,
                  grad_norm: float | None = None,
                  step_norm: float | None = None, rounds: int = 1) -> None:
    """Per-round driver bookkeeping: one call at each round readback.

    Values come off the SAME marked host-sync the driver already does —
    this function only files already-host-side floats; it never touches
    device values (the obs purity lint would flag a materializer here).
    """
    inc("repro_rounds_total", rounds, driver=driver)
    inc("repro_bytes_total", float(nbytes) * rounds, driver=driver)
    set_gauge("repro_round_bytes", nbytes, driver=driver)
    if objective is not None:
        set_gauge("repro_objective", objective, driver=driver)
    if grad_norm is not None:
        set_gauge("repro_grad_norm", grad_norm, driver=driver)
    if step_norm is not None:
        set_gauge("repro_step_norm", step_norm, driver=driver)


# -- Prometheus text exposition ---------------------------------------------


def _escape(v) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def _render(series: dict, mtype: str) -> list[str]:
    lines: list[str] = []
    seen: set = set()
    for (name, labels), value in sorted(series.items()):
        if name not in seen:
            seen.add(name)
            lines.append(f"# TYPE {name} {mtype}")
        if labels:
            lab = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
            lines.append(f"{name}{{{lab}}} {value:g}")
        else:
            lines.append(f"{name} {value:g}")
    return lines


def render_prometheus(extra_counters: dict | None = None) -> str:
    """The registry (plus optional extra counter series) as exposition
    text.  ``extra_counters`` maps (name, ((label, value), ...)) -> n —
    the shape :func:`repro.obs.ledger.counts` folds into."""
    snap = snapshot()
    counters = dict(snap["counters"])
    if extra_counters:
        counters.update(extra_counters)
    lines = _render(counters, "counter") + _render(snap["gauges"], "gauge")
    return "\n".join(lines) + ("\n" if lines else "")


def export_textfile(path, extra_counters: dict | None = None) -> str:
    """Write the exposition text for the node-exporter textfile collector."""
    text = render_prometheus(extra_counters)
    with open(path, "w") as fh:
        fh.write(text)
    return text


def ledger_counter_series(by_site: dict) -> dict:
    """Fold ledger site counts into registry-shaped counter series."""
    return {
        ("repro_declass_total", (("site", site),)): float(n)
        for site, n in by_site.items()
        if site != "_protect_flat"
    } | {
        ("repro_protect_total", ()): float(by_site.get("_protect_flat", 0))
    }
