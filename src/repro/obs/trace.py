"""Host-side span tracer: ring-buffered, ~zero-cost when disabled.

One module-level tracer records :class:`Span` intervals (protect /
aggregate / reveal / newton / round / retry / ...) from every secure
driver.  ``span(kind, ...)`` returns a shared no-op context manager when
tracing is off — the disabled cost is one module-global read and a
branch, which is how the instrumented drivers stay bit- and
perf-invisible (see ``benchmarks/obs_overhead.py``).

Exporters:

* :meth:`SpanTracer.export_jsonl` — one JSON object per line, the run
  ledger ``results/show.py`` renders;
* :meth:`SpanTracer.export_chrome_trace` — the Chrome trace-event JSON
  (``ph: "X"`` duration events, microsecond timestamps) that opens
  directly in ``chrome://tracing`` or https://ui.perfetto.dev;
* :meth:`SpanTracer.summary_lines` — the per-kind wall-time table the
  examples print.

Optional ``jax.profiler`` hook: ``enable(profiler=True)`` additionally
wraps every span in a ``jax.profiler.TraceAnnotation`` so spans land
inside a captured XLA profile.  The import is lazy and failure-tolerant
on purpose — this module must import WITHOUT jax (the jax-free
``runtime.supervisor`` layer uses it), and the obs purity lint
(``repro.analysis.lints.lint_obs_purity``) pins that no module-level jax
import, host callback, or device materialization ever creeps in here.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque

__all__ = [
    "Span",
    "SpanTracer",
    "span",
    "traced",
    "enable",
    "disable",
    "get",
]


class Span:
    """One closed interval: [t0, t1] seconds (perf_counter domain)."""

    __slots__ = ("kind", "name", "t0", "t1", "tid", "attrs")

    def __init__(self, kind, name, t0, t1, tid, attrs):
        self.kind = kind
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "name": self.name,
            "t0": self.t0,
            "dur": self.duration,
            "tid": self.tid,
            **({"attrs": self.attrs} if self.attrs else {}),
        }


class _NoopSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "kind", "name", "attrs", "_t0", "_ann")

    def __init__(self, tracer, kind, name, attrs):
        self._tracer = tracer
        self.kind = kind
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._ann = None

    def set(self, **attrs):
        """Attach attributes mid-span (e.g. results known only at exit)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tr = self._tracer
        if tr.profiler:
            ann = tr._annotation(self.name)
            if ann is not None:
                self._ann = ann
                ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._emit(
            Span(self.kind, self.name, self._t0, t1,
                 threading.get_ident(), self.attrs)
        )
        return False


class SpanTracer:
    """Ring buffer of spans (oldest evicted past ``capacity``)."""

    def __init__(self, capacity: int = 65536, profiler: bool = False):
        self.spans: deque = deque(maxlen=capacity)
        self.profiler = profiler
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def span(self, kind: str, name: str | None = None, **attrs):
        return _LiveSpan(self, kind, name or kind, attrs)

    def _emit(self, s: Span):
        with self._lock:
            self.spans.append(s)

    def record(self, d: dict):
        """Re-ingest one :meth:`Span.to_dict` object (JSONL round-trip)."""
        self._emit(Span(d["kind"], d["name"], d["t0"],
                        d["t0"] + d["dur"], d.get("tid", 0),
                        d.get("attrs", {})))

    def _annotation(self, name: str):
        """A jax.profiler.TraceAnnotation, or None if jax is unavailable."""
        try:  # lazy + tolerant: tracing must work in jax-free processes
            import jax.profiler
            return jax.profiler.TraceAnnotation(name)
        except Exception:
            self.profiler = False
            return None

    def clear(self):
        with self._lock:
            self.spans.clear()

    # -- exporters ---------------------------------------------------------
    def export_jsonl(self, path) -> int:
        """One span per line; returns the number of spans written."""
        with self._lock:
            spans = list(self.spans)
        with open(path, "w") as fh:
            for s in spans:
                fh.write(json.dumps(s.to_dict()) + "\n")
        return len(spans)

    def export_chrome_trace(self, path) -> int:
        """Chrome trace-event JSON (open in chrome://tracing / Perfetto)."""
        with self._lock:
            spans = list(self.spans)
        t_origin = min((s.t0 for s in spans), default=0.0)
        events = [
            {
                "name": s.name,
                "cat": s.kind,
                "ph": "X",
                "ts": (s.t0 - t_origin) * 1e6,
                "dur": s.duration * 1e6,
                "pid": 0,
                "tid": s.tid,
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            }
            for s in spans
        ]
        with open(path, "w") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, fh)
        return len(events)

    # -- summaries ---------------------------------------------------------
    def summary(self) -> dict:
        """Per-kind {count, total_s, mean_s, max_s} aggregates."""
        with self._lock:
            spans = list(self.spans)
        out: dict = {}
        for s in spans:
            rec = out.setdefault(
                s.kind, {"count": 0, "total_s": 0.0, "max_s": 0.0}
            )
            rec["count"] += 1
            rec["total_s"] += s.duration
            rec["max_s"] = max(rec["max_s"], s.duration)
        for rec in out.values():
            rec["mean_s"] = rec["total_s"] / rec["count"]
        return out

    def summary_lines(self) -> list[str]:
        """The per-kind span table examples print after a run."""
        rows = sorted(self.summary().items(),
                      key=lambda kv: -kv[1]["total_s"])
        lines = [f"{'span kind':<20} {'count':>6} {'total ms':>10} "
                 f"{'mean ms':>9} {'max ms':>9}"]
        for kind, rec in rows:
            lines.append(
                f"{kind:<20} {rec['count']:>6d} "
                f"{rec['total_s'] * 1e3:>10.2f} "
                f"{rec['mean_s'] * 1e3:>9.3f} "
                f"{rec['max_s'] * 1e3:>9.3f}"
            )
        return lines


def _jsonable(v):
    return v if isinstance(v, (int, float, str, bool, type(None))) \
        else str(v)


# -- module-level tracer (what the drivers call) ----------------------------

_tracer: SpanTracer | None = None


def enable(capacity: int = 65536, profiler: bool = False) -> SpanTracer:
    """Install (or replace) the process tracer and return it."""
    global _tracer
    _tracer = SpanTracer(capacity=capacity, profiler=profiler)
    return _tracer


def disable() -> SpanTracer | None:
    """Stop tracing; returns the final tracer so callers can export it."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def get() -> SpanTracer | None:
    return _tracer


def span(kind: str, name: str | None = None, **attrs):
    """The instrumentation entry point: a context manager.

    When tracing is disabled this is one global read + branch and a
    shared no-op object — nothing allocates per call beyond the kwargs.
    """
    t = _tracer
    if t is None:
        return _NOOP
    return t.span(kind, name, **attrs)


def traced(kind: str, name: str | None = None):
    """Decorator form of :func:`span` for whole-method instrumentation.

    The wrapper adds one function call + the disabled-span branch when
    tracing is off — the cheapest way to span a method without touching
    its body's indentation.
    """
    import functools

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            t = _tracer
            if t is None:
                return fn(*args, **kwargs)
            with t.span(kind, label):
                return fn(*args, **kwargs)

        return wrapper

    return deco
