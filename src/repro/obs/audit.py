"""Runtime privacy audit: reconcile the ledger against the static gate.

The static gate (:mod:`repro.analysis`) certifies, per driver spec, a
closed jaxpr in which every declassification is a named pjit boundary
(``_reveal_flat`` / ``_distributed_reveal`` / ``declassify_sum``).  The
runtime ledger (:mod:`repro.obs.ledger`) counts every Python-level
invocation of those boundaries.  This module closes the loop:

1. **Expected census** — walk the spec's certified jaxpr (recursing
   through scan/cond/pjit/shard_map bodies) and count every boundary
   equation, keyed ``(site, operand shape)``.  One equation == one
   wrapper invocation during the trace, because the hooks live in the
   host wrappers outside the jitted bodies.
2. **Recorded counts** — ``jax.clear_caches()`` (so the runner's
   enclosing graphs re-trace rather than silently reusing a build-time
   cache entry), then execute the spec's runnable form under
   :func:`repro.obs.ledger.capture`.
3. **Reconcile** — the two multisets must be EQUAL.  Anything extra the
   process did (e.g. a host-level reveal of a per-institution buffer —
   see :func:`extra_reveal_fixture`) fires the wrapper hook regardless
   of jit-cache state and surfaces as a count mismatch: a finding.

Dispatches of an already-certified compiled graph record nothing and
need nothing: they cannot add declassification sites.  What the audit
certifies is therefore exactly: *every declassification this process
performed is an equation of a gate-certified graph (or an expected
host-level call), in the expected multiplicity.*

This module imports jax and must only be loaded behind the CLI
(``python -m repro.obs audit``) or tests — never from the obs core
modules the drivers import.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import jax

from . import ledger

__all__ = ["graph_census", "audit_spec", "extra_reveal_fixture",
           "run_audit", "AuditResult", "SpecAudit"]

# every boundary the census counts: the three declassification sites
# plus the protect direction (same wrapper mechanics, same invariant)
SITES = ledger.DECLASS_SITES + ("_protect_flat",)


def _sub_jaxprs(eqn):
    """Every jaxpr nested in an equation's params (scan/cond/pjit/...)."""
    from jax.core import ClosedJaxpr, Jaxpr

    for v in eqn.params.values():
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, Jaxpr):
                    yield x


def _operand_shape(eqn) -> tuple:
    """The boundary's payload shape: its highest-rank array operand.

    Matches what the host wrapper records (``buf.shape`` — the share
    buffer for protect/reveal, the summed tensor for declassify_sum);
    scalar statics and rng keys rank below the payload buffer.
    """
    shapes = [tuple(v.aval.shape) for v in eqn.invars
              if hasattr(v, "aval") and hasattr(v.aval, "shape")]
    return max(shapes, key=len, default=())


def graph_census(closed) -> dict:
    """Count boundary equations in a certified jaxpr: (site, shape) -> n.

    A ``lax.scan`` body is counted ONCE regardless of trip count and
    both ``lax.cond`` branches are counted — mirroring exactly how often
    the host wrappers fire while the graph is traced.
    """
    counts: Counter = Counter()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pjit" \
                    and eqn.params.get("name") in SITES:
                counts[(eqn.params["name"], _operand_shape(eqn))] += 1
                continue  # the boundary body holds no further boundaries
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    walk(closed.jaxpr)
    return dict(counts)


def _recorded_census(cap: ledger.Capture) -> dict:
    """Fold captured ledger counts to the census key (site, shape)."""
    out: Counter = Counter()
    for (site, _what, shape, _thr), n in cap.counts.items():
        out[(site, tuple(shape))] += n
    return dict(out)


@dataclasses.dataclass
class SpecAudit:
    """One spec's reconciliation result."""

    name: str
    expected: dict  # (site, shape) -> n from the certified graph
    recorded: dict  # (site, shape) -> n from the runtime ledger
    skipped: str = ""  # non-empty: why the runner did not execute

    @property
    def ok(self) -> bool:
        return bool(self.skipped) or self.expected == self.recorded

    def findings(self) -> list[str]:
        if self.skipped:
            return []
        out = []
        keys = sorted(set(self.expected) | set(self.recorded))
        for key in keys:
            e = self.expected.get(key, 0)
            r = self.recorded.get(key, 0)
            if e != r:
                site, shape = key
                out.append(
                    f"{self.name}: {site}{list(shape)} executed {r}x, "
                    f"certified graph has {e} site(s) — "
                    + ("UNCERTIFIED declassification" if r > e
                       else "certified site never executed")
                )
        return out

    def by_site(self, which: dict) -> dict:
        folded: Counter = Counter()
        for (site, _shape), n in which.items():
            folded[site] += n
        return dict(folded)


def audit_spec(spec) -> SpecAudit:
    """Reconcile one DriverSpec: census of its graph vs a captured run."""
    closed, _taints = spec.build()
    expected = graph_census(closed)
    if spec.runner is None:
        return SpecAudit(spec.name, expected, {}, skipped="no runner")
    if jax.device_count() < getattr(spec, "min_devices", 1):
        return SpecAudit(
            spec.name, expected, {},
            skipped=f"needs {spec.min_devices} devices, "
                    f"have {jax.device_count()}",
        )
    # the build's make_jaxpr left this spec's enclosing graphs in the jit
    # cache; clear so the runner re-traces and the wrappers re-fire
    jax.clear_caches()
    with ledger.capture() as cap:
        spec.runner()
    return SpecAudit(spec.name, expected, _recorded_census(cap))


def extra_reveal_fixture(spec) -> SpecAudit:
    """A deliberately-leaky run the audit MUST flag (self-test).

    Executes the spec's certified round, then performs the classic
    coordinator attack: a host-level :func:`_reveal_flat` on a
    protected buffer that never went through Algorithm 2's
    institution-axis aggregation.  The host wrapper fires regardless of
    jit-cache state, so the recorded count exceeds the certified census
    and the audit reports an UNCERTIFIED declassification.
    """
    closed, _taints = spec.build()
    expected = graph_census(closed)
    jax.clear_caches()
    with ledger.capture() as cap:
        spec.runner()
        # ---- the attack: peek at one submission's share stack --------
        import jax.numpy as jnp

        from ..analysis.drivers import _aggregator
        from ..core.collective import _reveal_flat

        agg = _aggregator()
        prot = agg.protect(jax.random.PRNGKey(1),
                           {"gradient": jnp.arange(4.0)})
        t = agg.scheme.threshold
        _reveal_flat(prot.buf[:t], agg.scheme, agg.codec.frac_bits,
                     tuple(range(1, t + 1)))
    audit = SpecAudit(spec.name + "+extra_reveal", expected,
                      _recorded_census(cap))
    return audit


@dataclasses.dataclass
class AuditResult:
    """The whole audit: per-spec reconciliations + the leak self-test."""

    specs: list
    fixture: SpecAudit | None = None

    @property
    def ok(self) -> bool:
        clean = all(s.ok for s in self.specs)
        # the self-test must FAIL reconciliation, or the audit is blind
        armed = self.fixture is None or not self.fixture.ok
        return clean and armed

    def total_by_site(self) -> dict:
        folded: Counter = Counter()
        for s in self.specs:
            for (site, _shape), n in s.recorded.items():
                folded[site] += n
        return dict(folded)

    def lines(self) -> list[str]:
        out = []
        for s in self.specs:
            if s.skipped:
                out.append(f"SKIP  {s.name} ({s.skipped})")
                continue
            summary = " ".join(
                f"{site}={n}" for site, n in
                sorted(s.by_site(s.recorded).items())
            ) or "no boundaries"
            out.append(f"{'OK' if s.ok else 'MISMATCH'}    {s.name}  "
                       f"[{summary}]")
            out.extend(f"  [finding] {f}" for f in s.findings())
        if self.fixture is not None:
            if self.fixture.ok:
                out.append(
                    "BLIND   extra-reveal self-test was NOT flagged — "
                    "the runtime audit cannot see host-level reveals"
                )
            else:
                out.append(f"FLAGGED {self.fixture.name} "
                           "(the deliberate leak was caught)")
                out.extend(f"  [finding] {f}"
                           for f in self.fixture.findings())
        audited = sum(1 for s in self.specs if not s.skipped)
        skipped = len(self.specs) - audited
        out.append(
            f"audit: {'PASS' if self.ok else 'FAIL'} "
            f"({audited} drivers reconciled, {skipped} skipped)"
        )
        return out

    def to_dict(self) -> dict:
        def spec_dict(s):
            return {
                "name": s.name,
                "ok": s.ok,
                "skipped": s.skipped,
                "expected": {f"{site}{list(shape)}": n
                             for (site, shape), n in s.expected.items()},
                "recorded": {f"{site}{list(shape)}": n
                             for (site, shape), n in s.recorded.items()},
                "findings": s.findings(),
            }

        return {
            "ok": self.ok,
            "specs": [spec_dict(s) for s in self.specs],
            "fixture": (spec_dict(self.fixture)
                        if self.fixture is not None else None),
            "total_by_site": self.total_by_site(),
        }


def run_audit(drivers: list[str] | None = None,
              with_fixture: bool = True) -> AuditResult:
    """Audit every (matching) driver spec; arm the leak self-test."""
    from ..analysis.drivers import all_driver_specs

    specs = all_driver_specs()
    if drivers:
        specs = [s for s in specs
                 if any(pat in s.name for pat in drivers)]
    audits = [audit_spec(s) for s in specs]
    fixture = None
    if with_fixture:
        runnable = [s for s in specs
                    if s.runner is not None
                    and jax.device_count() >= getattr(s, "min_devices", 1)]
        if runnable:
            fixture = extra_reveal_fixture(runnable[0])
    return AuditResult(audits, fixture)
