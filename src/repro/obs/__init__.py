"""Protocol observability: span tracing, metrics, runtime privacy audit.

Three stdlib-only building blocks, threaded through every secure driver:

* :mod:`repro.obs.trace`  — ring-buffered host span tracer with JSONL /
  Chrome-trace exporters and an optional ``jax.profiler`` annotation
  hook (``trace.enable()`` / ``trace.span(kind)``);
* :mod:`repro.obs.ledger` — the runtime privacy-audit ledger: typed
  execution counters on every ``_reveal_flat`` / ``_distributed_reveal``
  / ``declassify_sum`` (and ``_protect_flat``) boundary;
* :mod:`repro.obs.metrics` — labeled counters/gauges + Prometheus
  textfile export, and the shared ring-collective byte conventions.

The heavier pieces import jax and live behind the CLI:
``python -m repro.obs audit`` (see :mod:`repro.obs.audit`) reconciles
the runtime ledger against the static privacy gate's expected
declassification set for every certified driver spec.

This package's core modules MUST NOT import jax at module level, call
host callbacks, or materialize device values — ``repro.core`` imports
them on its hot path and the jax-free supervisor layer uses the tracer;
``repro.analysis.lints.lint_obs_purity`` enforces this statically.
"""
from . import ledger, metrics, trace  # noqa: F401

__all__ = ["ledger", "metrics", "trace"]
