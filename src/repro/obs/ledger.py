"""Runtime privacy-audit ledger: typed counters on every declassification.

The static gate (:mod:`repro.analysis`) proves what a driver's *traced
graph* may reveal; this ledger records what the running process actually
*did* reveal.  Every execution of a declassification boundary —
``_reveal_flat`` / ``_distributed_reveal`` / ``declassify_sum`` — and of
the ``_protect_flat`` encode calls :func:`record_site` with the site
name, a short "what" tag, the static buffer shape and the scheme
threshold.  ``python -m repro.obs audit`` reconciles these counts
against the static gate's expected declassification set per driver
spec; a mismatch (e.g. an extra host-level reveal that never appears in
the certified graph) is a finding.

Execution semantics: each boundary is a thin host wrapper around its
jitted impl, and the hook lives in the WRAPPER, so

* a host-level call records once per call — the loop drivers count one
  reveal per round, and a stray host-level reveal is counted even when
  its jitted impl hits the compilation cache;
* a call inside an enclosing ``jit`` records once per call site each
  time the enclosing graph is traced (a scanned body is traced once
  regardless of round count).  Cached dispatches of a certified graph
  record nothing — they cannot add declassification sites, which is
  exactly the invariant the audit reconciles: the recorded counts must
  equal a per-equation census of the certified graph plus the expected
  host-level calls.

This module is deliberately stdlib-only (no jax, no numpy): it is
imported by ``repro.core.secure_agg`` at module load and by the jax-free
``runtime.supervisor`` layer, and the hook must cost one boolean check
when disabled.  Only static metadata (Python ints/strings, ``.shape``
tuples — which abstract tracers provide without materializing) may be
recorded; recording a value would itself be a leak channel.
"""
from __future__ import annotations

import contextlib
import threading
from collections import Counter

__all__ = [
    "DECLASS_SITES",
    "record_site",
    "enabled",
    "enable",
    "disable",
    "reset",
    "counts",
    "by_site",
    "capture",
    "Capture",
]

# the sanctioned declassification boundaries, by pjit name — mirrors
# analysis.taint._PJIT_RULES minus the protect direction
DECLASS_SITES = ("_reveal_flat", "_distributed_reveal", "declassify_sum")

_lock = threading.Lock()
_enabled = False
# (site, what, shape, threshold) -> execution count
_counts: Counter = Counter()


def enabled() -> bool:
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    with _lock:
        _counts.clear()


def record_site(site: str, what: str = "", shape=(), threshold: int = 0
                ) -> None:
    """One execution of a protect/declassify boundary.

    Zero-cost when the ledger is disabled (one attribute read + branch).
    ``shape`` may be a tracer's ``.shape`` — a tuple of Python ints.
    """
    if not _enabled:
        return
    key = (site, str(what), tuple(int(s) for s in shape), int(threshold))
    with _lock:
        _counts[key] += 1


def counts() -> dict:
    """Snapshot of the typed counters: (site, what, shape, threshold) -> n."""
    with _lock:
        return dict(_counts)


def by_site() -> dict:
    """Counts folded to site name -> n (the audit's reconciliation key)."""
    with _lock:
        out: Counter = Counter()
        for (site, _, _, _), n in _counts.items():
            out[site] += n
        return dict(out)


class Capture:
    """Result object of :func:`capture`: the counts recorded inside it."""

    def __init__(self):
        self.counts: dict = {}
        self.by_site: dict = {}


@contextlib.contextmanager
def capture():
    """Enable the ledger for a block and yield the counts recorded in it.

    Restores the previous enabled state on exit; the global counters keep
    accumulating (``capture`` diffs a snapshot, it does not reset).
    """
    global _enabled
    cap = Capture()
    with _lock:
        before = Counter(_counts)
    prev = _enabled
    enable()
    try:
        yield cap
    finally:
        _enabled = prev
        with _lock:
            diff = Counter(_counts)
            diff.subtract(before)
        cap.counts = {k: n for k, n in diff.items() if n > 0}
        folded: Counter = Counter()
        for (site, _, _, _), n in cap.counts.items():
            folded[site] += n
        cap.by_site = dict(folded)
