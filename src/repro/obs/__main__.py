"""Observability CLI: ``python -m repro.obs <subcommand>``.

Subcommands
-----------

``audit``
    Reconcile the runtime privacy-audit ledger against the static
    gate's certified declassification census for every driver spec,
    then arm the extra-reveal self-test (a deliberate host-level leak
    that MUST be flagged).  Exit 0 iff every spec reconciles AND the
    self-test fires.

``summary``
    Render a recorded span JSONL file (``--trace``) as the per-kind
    summary table without re-running anything.

The audit needs the 8-way host-device platform the psum specs shard
over, so XLA flags are applied BEFORE jax is imported — this module
must therefore be the process entrypoint (run it as a subprocess from
tests; see ``tests/conftest.py`` for why in-process flag edits are
banned).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.distributed.xla_flags import apply_xla_flags


def _cmd_audit(args) -> int:
    apply_xla_flags(host_device_count=args.host_devices)
    from repro.obs import audit, ledger, metrics

    result = audit.run_audit(
        drivers=args.drivers or None,
        with_fixture=not args.no_fixture,
    )
    if args.json:
        print(json.dumps(result.to_dict(), indent=2))
    else:
        print("\n".join(result.lines()))
    if args.textfile:
        extra = metrics.ledger_counter_series(result.total_by_site())
        metrics.export_textfile(args.textfile, extra_counters=extra)
        print(f"prometheus textfile written: {args.textfile}",
              file=sys.stderr)
    ledger.disable()
    return 0 if result.ok else 1


def _cmd_summary(args) -> int:
    from repro.obs.trace import SpanTracer

    tracer = SpanTracer(capacity=1 << 20)
    with open(args.trace) as fh:
        for line in fh:
            line = line.strip()
            if line:
                tracer.record(json.loads(line))
    print("\n".join(tracer.summary_lines()))
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="protocol observability: privacy audit + trace tools",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)

    audit_p = sub.add_parser(
        "audit", help="reconcile runtime declassifications vs the "
                      "static gate's certified census")
    audit_p.add_argument("--drivers", nargs="*", default=None,
                         help="substring filter on driver spec names")
    audit_p.add_argument("--json", action="store_true",
                         help="machine-readable output")
    audit_p.add_argument("--no-fixture", action="store_true",
                         help="skip the extra-reveal self-test")
    audit_p.add_argument("--textfile", default=None,
                         help="write Prometheus textfile metrics here")
    audit_p.add_argument("--host-devices", type=int, default=8,
                         help="XLA host platform device count "
                              "(psum specs shard over these)")
    audit_p.set_defaults(fn=_cmd_audit)

    sum_p = sub.add_parser(
        "summary", help="summarize a recorded span JSONL file")
    sum_p.add_argument("--trace", required=True,
                       help="span JSONL written by trace.export_jsonl")
    sum_p.set_defaults(fn=_cmd_summary)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
