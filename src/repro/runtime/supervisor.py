"""Fault-tolerant round supervision for the secure protocol drivers.

The paper's setting is a long-running multi-institution consortium, where
institutions going offline, lagging past a deadline, or a Computation
Center crashing mid-study are the NORMAL case.  The drivers themselves
fail loud and clean — ``cohort()``/``live_centers()`` raise and leave
state untouched — and this module supplies the policy layer that turns
those hard failures into waits, retries, degradation and re-provisioning:
a ``RoundSupervisor`` drives ``StudyCoordinator``, ``SecureFitDriver``
(the stepwise ``secure_fit``) and ``SelectionCoordinator`` rounds through
the existing ``SimClock``/``HeartbeatMonitor``/``StragglerPolicy``
machinery under a declarative ``FaultPolicy``.

Fault model
===========

==============================  ==============================  ==========================================  =========================================
failure class                   detection                       policy                                      guarantee
==============================  ==============================  ==========================================  =========================================
institution straggler burst     round deadline (simulated       excluded from the round (Eqs. 4-6 sum       Newton step on the responding cohort is
                                latency vs deadline)            over responders); below quorum the round    a valid ascent step; the converged fixed
                                                                waits with exponential backoff              point is unchanged by transient exclusion
institution transient flap      missed heartbeats -> monitor    treated as straggler until declared dead,   rounds resume with the returned party;
                                declares dead after timeout     then excluded; retry/backoff below quorum   its folds/summaries re-enter untouched
institution crash (fail-stop)   explicit failure notice         excluded immediately; a ``recover`` event   study completes on the surviving cohort
                                (heartbeat deregister)          re-admits it (or a new member joins)        (>= min_responders/quorum)
center crash (between rounds)   liveness scan before the round  reveal from surviving >= t points;          revealed aggregate bit-identical (any
                                                                re-provision a replacement at a fresh       t-subset reconstructs the same field
                                                                evaluation point after repeated failures    element); replacement learns nothing
                                                                                                            about past rounds (fresh polynomials)
center death protect->reveal    post-protect liveness re-check  >= t survivors: reveal from survivors;      survivor reveal is bit-identical;
                                (mid-round hooks)               below t: abort the round, back off, retry   aborted round leaves fit state untouched
                                                                re-shares with fresh polynomials            and reveals nothing (< t shares are
                                                                                                            information-theoretically void)
coordinator crash               process death (external)        ``state_dict`` checkpoint -> fresh driver   bit-identical replay: same rng stream,
                                                                ``load_state_dict`` resume                  same trace floats, same final beta
unsurvivable (< t centers       retry budget exhausted          the FINAL attempt always calls the driver,  fail loud with the driver's exact
forever, quorum never met)                                      so its exact ``RuntimeError`` propagates    ``RuntimeError``; driver state unmutated
==============================  ==============================  ==========================================  =========================================

The chaos invariant (pinned by ``tests/test_supervisor.py`` across all
three drivers): **any survivable ``FailureInjector`` schedule converges
to the fault-free oracle's beta within fixed-point quantization.**  Two
protocol facts make this hold exactly rather than approximately: the
revealed aggregate is independent of the sharing randomness (so aborted
attempts that consumed rng splits cannot perturb the revealed values),
and reconstruction from ANY >= t evaluation points is the same field
element (so degraded reveals and re-provisioned point sets are
bit-identical to full-strength rounds over the same cohort).  For the
iterative drivers a transiently-shrunk cohort doesn't move the Newton
fixed point, so institution faults that heal before convergence are also
oracle-exact.  The one-pass selection sweep is the qualified case:
center faults are bit-identical as above, but an institution missing
during a λ chunk is *by design* absent from that chunk's CV sums
(responders-only semantics, folds untouched for its return), so
selection oracle-parity is asserted for schedules whose institution
faults heal between chunks.

This module is deliberately jax-free and driver-agnostic: the three
drivers are adapted by duck type (``step_chunk`` -> selection,
``centers`` -> coordinator, ``centers_online`` -> secure-fit driver), so
``runtime`` keeps zero imports from ``core``/``selection``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..obs import metrics as _metrics
from ..obs.trace import span as _span, traced as _traced
from .managers import (
    FailureInjector,
    HeartbeatMonitor,
    SimClock,
    StragglerPolicy,
)

__all__ = ["FaultPolicy", "RoundSupervisor", "SupervisedRound"]


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Declarative knobs for one study's fault handling.

    A round gets ``1 + max_retries`` attempts.  Before each attempt the
    supervisor advances heartbeats and checks quorum/threshold
    preflight; a failed or infeasible attempt backs off
    ``backoff_base * backoff_factor**attempt`` simulated seconds (the
    wait during which flapped parties heal and heartbeats expire).  The
    LAST attempt always calls into the driver so a genuinely
    unsurvivable schedule surfaces the driver's own ``RuntimeError``.
    """

    max_retries: int = 4
    backoff_base: float = 1.0
    backoff_factor: float = 2.0
    # simulated duration of one successful round (clock advance on success)
    round_seconds: float = 1.0
    heartbeat_timeout: float = 5.0
    straggler: StragglerPolicy = StragglerPolicy(
        deadline=2.0, quorum_fraction=0.5
    )
    # replace dead centers with fresh ones after this many failed attempts
    # in a round (0 disables re-provisioning)
    reprovision_after: int = 1

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff must be non-negative, non-shrinking")


@dataclasses.dataclass
class SupervisedRound:
    """Audit record for one supervised round (success or propagated fail)."""

    round_no: int
    attempts: int
    retries: int
    aborted_attempts: int
    backoff_seconds: float
    degraded: bool
    events: list
    suspected_dead: list
    report: object | None


# -- driver adapters ----------------------------------------------------------
#
# One tiny facade per driver so the supervisor loop speaks a single
# interface: institution liveness by NAME, center liveness by evaluation
# POINT, one `attempt()` that either returns a report or raises the
# driver's RuntimeError, and `finished()`/`finalize()`.


class _CoordinatorAdapter:
    """``core.protocol.StudyCoordinator``."""

    def __init__(self, coord):
        self.c = coord

    def _inst(self, name):
        for inst in self.c.institutions:
            if inst.name == name:
                return inst
        raise KeyError(f"unknown institution {name!r}")

    def institution_names(self):
        return [i.name for i in self.c.institutions]

    def set_online(self, name, up):
        self._inst(name).online = bool(up)

    def get_latency(self, name):
        return self._inst(name).latency

    def set_latency(self, name, latency):
        self._inst(name).latency = float(latency)

    def default_deadline(self, deadline):
        if self.c.deadline is None:
            self.c.deadline = deadline

    def num_live(self):
        return sum(1 for i in self.c.institutions if i.online)

    def num_responding(self):
        dl = self.c.deadline
        return sum(
            1 for i in self.c.institutions
            if i.online and (dl is None or i.latency <= dl)
        )

    def needs_centers(self):
        return self.c.protect != "none"

    def threshold(self):
        return self.c.agg.scheme.threshold

    def num_points(self):
        return len(self.c.centers)

    def live_center_count(self):
        return sum(1 for c in self.c.centers if c.online)

    def set_center_online(self, index, up):
        for c in self.c.centers:
            if c.index == index:
                c.online = bool(up)
                return
        raise KeyError(f"no center at evaluation point {index}")

    def dead_center_indices(self):
        return [c.index for c in self.c.centers if not c.online]

    def provision_center(self, index=None):
        return self.c.provision_center(index)

    def arm_midround(self, index):
        self.c._midround_hooks.append(
            lambda: self.set_center_online(index, False)
        )

    def rounds_done(self):
        return self.c.iteration

    def attempt(self):
        return self.c.step()

    def finished(self):
        return bool(self.c.converged)

    def finalize(self):
        import numpy as np

        return np.asarray(self.c.beta)


class _SecureFitAdapter(_CoordinatorAdapter):
    """``core.newton.SecureFitDriver`` (same vocabulary, list storage)."""

    def institution_names(self):
        return list(self.c.names)

    def set_online(self, name, up):
        self.c.set_online(name, up)

    def get_latency(self, name):
        return self.c.get_latency(name)

    def set_latency(self, name, latency):
        self.c.set_latency(name, latency)

    def num_live(self):
        return sum(1 for up in self.c.online if up)

    def num_responding(self):
        dl = self.c.deadline
        return sum(
            1 for up, lat in zip(self.c.online, self.c.latency)
            if up and (dl is None or lat <= dl)
        )

    def num_points(self):
        return len(self.c.centers_online)

    def live_center_count(self):
        return sum(1 for up in self.c.centers_online if up)

    def set_center_online(self, index, up):
        self.c.set_center_online(index, up)

    def dead_center_indices(self):
        return [
            i + 1 for i, up in enumerate(self.c.centers_online) if not up
        ]

    def provision_center(self, index=None):
        # the in-process driver has no center objects to replace: a
        # "replacement" is simply the evaluation point coming back up
        # (next round's shares are cut fresh against it)
        dead = self.dead_center_indices()
        if index is None:
            if not dead:
                raise RuntimeError("no dead center to replace")
            index = dead[0]
        self.c.set_center_online(index, True)
        return index

    def arm_midround(self, index):
        self.c._midround_hooks.append(
            lambda: self.c.set_center_online(index, False)
        )

    def rounds_done(self):
        return self.c.iteration

    def attempt(self):
        return self.c.step()

    def finished(self):
        return bool(self.c.converged)

    def finalize(self):
        return self.c.result()


class _SelectionAdapter(_CoordinatorAdapter):
    """``selection.SelectionCoordinator`` — one "round" = one λ chunk."""

    def __init__(self, sel):
        super().__init__(sel.study)
        self.s = sel

    def arm_midround(self, index):
        self.s.study._midround_hooks.append(
            lambda: self.set_center_online(index, False)
        )

    def rounds_done(self):
        return self.s.next_chunk

    def attempt(self):
        self.s.step_chunk()
        return None

    def finished(self):
        return self.s.finished()

    def finalize(self):
        # builds the PathReport (idempotent when already finished) and
        # surfaces the refit beta on the wrapped study
        return self.s.run_path()


def _adapt(driver):
    if hasattr(driver, "step_chunk"):
        return _SelectionAdapter(driver)
    if hasattr(driver, "centers_online"):
        return _SecureFitAdapter(driver)
    if hasattr(driver, "centers"):
        return _CoordinatorAdapter(driver)
    raise TypeError(
        f"don't know how to supervise {type(driver).__name__}; expected a "
        "StudyCoordinator, SecureFitDriver or SelectionCoordinator"
    )


class RoundSupervisor:
    """Drive a secure protocol driver round by round under a FaultPolicy.

    The supervisor owns the simulated control plane: a ``SimClock``, a
    ``HeartbeatMonitor`` fed by the parties that are currently beating,
    and a deterministic ``FailureInjector`` schedule keyed by ROUND
    number (events fire as the round opens).  Each round:

    1. apply the round's chaos events (crash/flap/straggle/center_*);
    2. up to ``1 + max_retries`` attempts: fire due self-heal timers,
       advance heartbeats, sync institution liveness from the monitor,
       preflight quorum/threshold, and call the driver; an infeasible
       preflight or a driver ``RuntimeError`` backs off exponentially
       (optionally re-provisioning dead centers) and retries — the
       final attempt always calls the driver so unsurvivable schedules
       propagate its exact error;
    3. on success, stamp the retry/backoff/degraded telemetry into the
       driver's ``RoundReport`` and advance the clock by
       ``round_seconds``.

    Determinism: everything is keyed off the SimClock and the schedule —
    no wall-clock, no randomness — so a given (driver seed, schedule,
    policy) triple always produces the same betas, the same retry
    counts, and the same backoff times.

    Scan-resident drivers (``rounds="scan"``): one supervised round is
    one SCAN BLOCK of ``rounds_per_sync`` Newton rounds — the driver's
    ``step()`` dispatches the whole block as a single graph, so chaos
    events land at block boundaries (a ``center_midround`` hook fires at
    the block's dispatch) and ``max_rounds`` caps blocks, not Newton
    rounds.  A failed block mutates no driver state, so the retry
    re-enters at the SAME block; the in-graph rng folds ``(key, round)``
    by absolute round index, which makes the retried block — and any
    post-crash ``state_dict`` resume — bit-identical to an
    uninterrupted run (``tests/test_scan_rounds.py`` pins both).
    """

    def __init__(
        self,
        driver,
        policy: FaultPolicy | None = None,
        injector: FailureInjector | None = None,
        clock: SimClock | None = None,
    ):
        self.policy = policy or FaultPolicy()
        self.driver = _adapt(driver)
        self.clock = clock or SimClock()
        self.injector = injector or FailureInjector()
        self.monitor = HeartbeatMonitor(
            self.clock, timeout=self.policy.heartbeat_timeout
        )
        # give deadline-less drivers the policy's straggler deadline so
        # latency events actually have protocol meaning
        self.driver.default_deadline(self.policy.straggler.deadline)
        self._beating: set[str] = set()
        self._base_latency: dict[str, float] = {}
        self._timers: list[tuple[float, int, Callable[[], None]]] = []
        self._tseq = 0
        # resume support: a reloaded driver continues at its own round
        # count, so schedule keys keep their absolute meaning
        self.round_no = int(self.driver.rounds_done())
        self.rounds: list[SupervisedRound] = []
        self.total_retries = 0
        self.total_backoff = 0.0
        self._admit_new_parties()

    # -- control plane -------------------------------------------------------
    def _admit_new_parties(self):
        """Register parties the supervisor hasn't seen (incl. mid-study
        joins via ``add_institution``) and remember their base latency."""
        for name in self.driver.institution_names():
            if name not in self._base_latency:
                self._base_latency[name] = self.driver.get_latency(name)
                self.monitor.register(name)
                self._beating.add(name)

    def _schedule_timer(self, due: float, fn: Callable[[], None]):
        self._tseq += 1
        self._timers.append((due, self._tseq, fn))
        self._timers.sort()

    def _fire_due_timers(self):
        now = self.clock.now()
        due = [t for t in self._timers if t[0] <= now]
        self._timers = [t for t in self._timers if t[0] > now]
        for _, _, fn in due:
            fn()

    def _heartbeat_sync(self):
        """Beat for live parties; sync driver liveness from the monitor."""
        self._admit_new_parties()
        names = self.driver.institution_names()
        for name in sorted(self._beating):
            self.monitor.beat(name)
        alive = set(self.monitor.alive())
        for name in names:
            self.driver.set_online(name, name in alive)

    def _revive(self, name):
        """Self-heal after a flap: resume beating at base latency."""
        self._beating.add(name)
        self.monitor.register(name)
        self.driver.set_latency(name, self._base_latency.get(name, 0.0))

    def _apply_event(self, ev):
        kind, *args = FailureInjector.normalize(ev)
        if kind == "crash":
            name = ev if isinstance(ev, str) else args[0]
            self._beating.discard(name)
            self.monitor.deregister(name)  # explicit failure notice
            self.driver.set_online(name, False)
            self.driver.set_latency(name, float("inf"))
        elif kind == "recover":
            name = args[0]
            self._revive(name)
            self.driver.set_online(name, True)
        elif kind == "flap":
            name, duration = args[0], float(args[1])
            # transient outage: stops beating (declared dead only after
            # the heartbeat timeout) and misses every deadline meanwhile
            self._beating.discard(name)
            self.driver.set_latency(name, float("inf"))
            self._schedule_timer(
                self.clock.now() + duration,
                lambda n=name: self._revive(n),
            )
        elif kind == "straggle":
            name, latency, duration = args[0], float(args[1]), float(args[2])
            # keeps beating — alive but late; excluded by the deadline rule
            self.driver.set_latency(name, latency)
            self._schedule_timer(
                self.clock.now() + duration,
                lambda n=name: self.driver.set_latency(
                    n, self._base_latency.get(n, 0.0)
                ),
            )
        elif kind == "center_crash":
            self.driver.set_center_online(int(args[0]), False)
        elif kind == "center_recover":
            self.driver.set_center_online(int(args[0]), True)
        elif kind == "center_midround":
            self.driver.arm_midround(int(args[0]))
        elif kind == "provision_center":
            self.driver.provision_center(
                int(args[0]) if args else None
            )

    # -- the supervised round ------------------------------------------------
    def _preflight_ok(self) -> bool:
        live = self.driver.num_live()
        if live == 0:
            return False
        if not self.policy.straggler.quorum_met(
            self.driver.num_responding(), live
        ):
            return False
        if (self.driver.needs_centers()
                and self.driver.live_center_count()
                < self.driver.threshold()):
            return False
        return True

    def _reprovision_dead_centers(self):
        for _ in self.driver.dead_center_indices():
            self.driver.provision_center()

    @_traced("round")
    def step(self) -> SupervisedRound:
        """One supervised round: events -> attempts -> telemetry.

        Raises the driver's own ``RuntimeError`` when the retry budget
        is exhausted on an unsurvivable state (driver state unmutated).
        """
        pol = self.policy
        self.round_no += 1
        events = self.injector.events_at(self.round_no)
        for ev in events:
            self._apply_event(ev)

        retries = 0
        aborted = 0
        backoff = 0.0
        report = None
        attempts = 0
        for attempt in range(pol.max_retries + 1):
            self._fire_due_timers()
            self._heartbeat_sync()
            final = attempt == pol.max_retries
            if final or self._preflight_ok():
                attempts += 1
                try:
                    report = self.driver.attempt()
                    break
                except RuntimeError:
                    aborted += 1
                    if final:
                        raise
            # infeasible or failed: re-provision (if due) and back off
            if (pol.reprovision_after
                    and attempt + 1 >= pol.reprovision_after):
                self._reprovision_dead_centers()
            wait = pol.backoff_base * pol.backoff_factor ** attempt
            with _span("retry", "RoundSupervisor.backoff",
                       round_no=self.round_no, attempt=attempt,
                       backoff_s=wait):
                self.clock.advance(wait)
            retries += 1
            backoff += wait

        degraded = bool(
            retries
            or aborted
            or (report is not None and getattr(report, "stragglers", None))
            or self.driver.dead_center_indices()
        )
        if report is not None and hasattr(report, "retries"):
            report.retries = retries
            report.backoff_seconds = backoff
            report.aborted_attempts = aborted
            report.degraded = degraded
        self.total_retries += retries
        self.total_backoff += backoff
        if retries:
            _metrics.inc("repro_retries_total", retries)
        if aborted:
            _metrics.inc("repro_aborted_attempts_total", aborted)
        record = SupervisedRound(
            round_no=self.round_no,
            attempts=attempts,
            retries=retries,
            aborted_attempts=aborted,
            backoff_seconds=backoff,
            degraded=degraded,
            events=[FailureInjector.normalize(e) for e in events],
            suspected_dead=self.monitor.dead(),
            report=report,
        )
        self.rounds.append(record)
        self.clock.advance(pol.round_seconds)
        return record

    def run(self, max_rounds: int = 100):
        """Supervise rounds until the driver finishes (or the cap).

        Returns the driver's final artifact: the converged beta for a
        ``StudyCoordinator``, a ``FitResult`` for a ``SecureFitDriver``,
        the ``PathReport`` for a ``SelectionCoordinator``.
        """
        while not self.driver.finished() and self.round_no < max_rounds:
            self.step()
        return self.driver.finalize()
