"""Fleet runtime managers: failure, straggler, elasticity — simulated clock.

These are the LM-training-side counterparts of the fault-tolerance already
built into ``core.protocol`` for the paper's Algorithm 1.  Everything is
driven by an injectable ``SimClock`` so behaviour is deterministic and
testable without wall-clock sleeps; `launch/train.py` wires them into the
step loop, and a deployment would replace SimClock with real heartbeats.

Design notes for 1000+ nodes:

* **Heartbeats, not pings.** Workers push heartbeats; the monitor only scans
  its table (O(workers) per check, no network fan-out from the coordinator).
* **Straggler policy = deadline + quorum**, the same rule the paper's
  coordinator applies to institutions: a round proceeds when >= quorum
  workers have reported, stragglers' contributions are dropped for the round
  (grad-accumulation semantics make a dropped microbatch a smaller, still
  unbiased batch).
* **Elasticity by re-meshing from checkpoint**: when membership changes, we
  pick the largest (dp, tp) grid that fits the survivors while preserving
  the TP degree (param shardings stay valid), and the train loop restores from
  the latest checkpoint.  `plan_remesh` is pure and unit-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

__all__ = [
    "SimClock",
    "HeartbeatMonitor",
    "StragglerPolicy",
    "FailureInjector",
    "EVENT_KINDS",
    "plan_remesh",
    "RemeshPlan",
]


class SimClock:
    """Deterministic monotonically-advancing clock."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("time does not go backwards")
        self.t += dt
        return self.t


@dataclasses.dataclass
class HeartbeatMonitor:
    """Declares a worker dead after ``timeout`` without a heartbeat."""

    clock: SimClock
    timeout: float = 60.0
    _last: dict = dataclasses.field(default_factory=dict)

    def register(self, worker: str):
        self._last[worker] = self.clock.now()

    def beat(self, worker: str) -> bool:
        """Record a heartbeat; returns False for an unknown worker.

        A heartbeat racing a ``deregister`` (the packet was in flight when
        the coordinator dropped the worker) is normal fleet behaviour, not
        an error: the stale beat is dropped and the worker stays
        deregistered until it explicitly re-``register``s.
        """
        if worker not in self._last:
            return False
        self._last[worker] = self.clock.now()
        return True

    def deregister(self, worker: str):
        self._last.pop(worker, None)

    def alive(self) -> list[str]:
        # Boundary: a worker whose last beat is *exactly* ``timeout`` old is
        # still alive (<=); ``dead`` is its strict complement (>), so the two
        # lists always partition the registered set.
        now = self.clock.now()
        return sorted(
            w for w, t in self._last.items() if now - t <= self.timeout
        )

    def dead(self) -> list[str]:
        now = self.clock.now()
        return sorted(
            w for w, t in self._last.items() if now - t > self.timeout
        )


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """Deadline + quorum rule for one collective round."""

    deadline: float = 30.0  # seconds from round start
    quorum_fraction: float = 0.75  # fraction of live workers required

    def split(
        self, arrivals: dict[str, float], round_start: float
    ) -> tuple[list[str], list[str]]:
        """-> (responders, stragglers) by arrival time vs deadline."""
        resp = sorted(
            w for w, t in arrivals.items()
            if t - round_start <= self.deadline
        )
        lag = sorted(set(arrivals) - set(resp))
        return resp, lag

    def quorum_met(self, num_responders: int, num_live: int) -> bool:
        import math

        need = max(1, math.ceil(self.quorum_fraction * num_live))
        return num_responders >= need


# Chaos-event vocabulary understood by FailureInjector.normalize (and by
# runtime.supervisor.RoundSupervisor, which interprets the center/latency
# events against the secure-protocol drivers):
#
#   "name"                            legacy shorthand for ("crash", name)
#   ("crash", name)                   institution/worker fail-stop
#   ("recover", name)                 crashed/flapped party rejoins
#   ("flap", name, duration)          transient outage: stops heartbeating
#                                     and misses deadlines for ``duration``
#                                     sim-seconds, then self-heals
#   ("straggle", name, latency, duration)
#                                     straggler burst: responds after
#                                     ``latency`` sim-seconds (keeps
#                                     heartbeating) for ``duration``
#   ("center_crash", index)           computation center fail-stop
#   ("center_recover", index)         crashed center rejoins
#   ("center_midround", index)        center dies BETWEEN protect and reveal
#                                     of the next round (one-shot)
#   ("provision_center", [index])     operator-driven replacement center
EVENT_KINDS = (
    "crash", "recover", "flap", "straggle",
    "center_crash", "center_recover", "center_midround", "provision_center",
)


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for chaos tests.

    ``schedule`` maps step -> iterable of chaos events (see ``EVENT_KINDS``
    above).  The legacy forms — a bare worker name meaning "kill", and
    ``("recover", name)`` — are still accepted everywhere.
    """

    schedule: dict = dataclasses.field(default_factory=dict)

    def events_at(self, step: int) -> list:
        return list(self.schedule.get(step, ()))

    @staticmethod
    def normalize(ev) -> tuple:
        """Canonicalize one schedule entry to a ``(kind, *args)`` tuple."""
        if isinstance(ev, str):
            return ("crash", ev)
        ev = tuple(ev)
        if not ev or ev[0] not in EVENT_KINDS:
            raise ValueError(f"unknown chaos event {ev!r}")
        return ev

    def apply(self, step: int, monitor: HeartbeatMonitor) -> list[str]:
        """Kill/recover per schedule against a bare heartbeat monitor;
        returns the names affected.

        This is the LM-loop entry point and only interprets worker-liveness
        events: ``crash``/``flap`` deregister (a flap degrades to a crash
        until its ``recover``), ``recover`` (re-)registers — including a
        worker never seen before, which is how a replacement node joins the
        fleet.  Center and latency events are no-ops here; the
        ``RoundSupervisor`` gives them meaning against protocol drivers.
        """
        hit = []
        for ev in self.events_at(step):
            kind, *args = self.normalize(ev)
            if kind == "recover":
                monitor.register(args[0])
                hit.append(args[0])
            elif kind in ("crash", "flap"):
                monitor.deregister(args[0])
                hit.append(args[0])
        return hit


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    dp: int
    tp: int
    dropped_workers: int

    @property
    def devices(self) -> int:
        return self.dp * self.tp


def plan_remesh(
    available_devices: int, tp: int, *, max_dp: int | None = None
) -> RemeshPlan:
    """Largest (dp, tp) grid fitting the survivors, preserving TP degree.

    TP degree is preserved because parameter shardings (and the collectives
    compiled against them) assume it; only the data-parallel extent shrinks.
    Raises when not even one TP group survives.
    """
    if tp <= 0:
        raise ValueError("tp must be positive")
    dp = available_devices // tp
    if dp < 1:
        raise RuntimeError(
            f"{available_devices} devices cannot host one tp={tp} group"
        )
    if max_dp is not None:
        dp = min(dp, max_dp)
    return RemeshPlan(dp=dp, tp=tp,
                      dropped_workers=available_devices - dp * tp)
