from .managers import (
    EVENT_KINDS,
    FailureInjector,
    HeartbeatMonitor,
    RemeshPlan,
    SimClock,
    StragglerPolicy,
    plan_remesh,
)
from .supervisor import FaultPolicy, RoundSupervisor, SupervisedRound

__all__ = [
    "EVENT_KINDS",
    "FailureInjector",
    "FaultPolicy",
    "HeartbeatMonitor",
    "RemeshPlan",
    "RoundSupervisor",
    "SimClock",
    "StragglerPolicy",
    "SupervisedRound",
    "plan_remesh",
]
