from .managers import (
    FailureInjector,
    HeartbeatMonitor,
    RemeshPlan,
    SimClock,
    StragglerPolicy,
    plan_remesh,
)

__all__ = [
    "FailureInjector",
    "HeartbeatMonitor",
    "RemeshPlan",
    "SimClock",
    "StragglerPolicy",
    "plan_remesh",
]
