"""Gradient compression for cross-pod reduction (distributed-opt trick).

int8 quantized all-reduce with error feedback: each pod quantizes its local
gradient to int8 (per-leaf absmax scaling), psums the int8 payload (in int32
accumulators), dequantizes, and carries the quantization residual into the
next step (error feedback keeps the scheme unbiased over time).  4x less
cross-pod traffic than bf16, 8x less than f32.

Composable with the Shamir path: `secure-agg shamir` already moves uint64
shares; compression applies to the *plain* mode only (compressing shares
would break the field homomorphism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "init_error_feedback"]


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quantize(g):
    absmax = jnp.max(jnp.abs(g)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale, g - q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, error_fb):
    """Quantized all-reduce over ``axis_name`` with error feedback.

    Returns (mean_grads, new_error_fb).  Scales are psummed alongside (one
    f32 per leaf) so dequantization uses the max scale across pods.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale, resid = _quantize(g32)
        # common scale across pods keeps the sum linear
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        resid = g32 - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(1, axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), resid

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return means, new_e
