"""AdamW in plain JAX (pytree-native, shardable, checkpointable)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics).  f32 moments; params keep
    their dtype (bf16 weights with f32 moments = mixed-precision train)."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (
            delta + cfg.weight_decay * p.astype(jnp.float32)
        )
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm,
                                                   "lr": lr}
