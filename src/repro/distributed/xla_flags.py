"""XLA_FLAGS management that is safe against jax's one-shot flag read.

XLA reads ``XLA_FLAGS`` exactly once, when the first backend client is
created (the first ``jax.devices()`` / first trace / first array op) — a
later assignment to ``os.environ`` silently does nothing.  Before this
helper existed, ``launch/dryrun.py`` set the variable twice (a module-level
default on line 2 and an arg-driven overwrite after ``parse_args``), which
worked only by the accident that nothing between the two had touched a
backend.  Every flag writer now routes through :func:`apply_xla_flags`,
which *verifies* no jax backend exists yet and raises instead of silently
losing the flag.

This module must therefore import WITHOUT importing jax (merely importing
jax is fine — flags are read at backend init, not at import — but pulling
in ``distributed.sharding`` would create arrays).  ``repro.distributed``'s
``__init__`` is lazy for exactly this reason.

Typical uses::

    from repro.distributed.xla_flags import apply_xla_flags
    apply_xla_flags(host_device_count=8)        # before first jax use
    import jax                                   # sees 8 CPU devices

    # subprocess workers (CPU-mesh CI): build the child env instead;
    # latency_hiding=True only when the child targets a GPU backend
    env = mesh_env(host_device_count=256)
    subprocess.run([...], env=env)
"""
from __future__ import annotations

import os
import sys

__all__ = [
    "LATENCY_HIDING_FLAGS",
    "apply_xla_flags",
    "jax_backend_initialized",
    "mesh_env",
]

# The collective-overlap knobs from the olmax run scripts (SNIPPETS.md):
# async collectives let a reduction proceed while independent work (the
# next chunk's encode+share) issues; the latency-hiding scheduler orders
# the HLO so that independent work actually lands between a collective's
# start and done.  GPU-ONLY: XLA's flag parser hard-aborts the process on
# flags the build does not know (``parse_flags_from_env.cc``), and
# CPU-only builds do not register the ``--xla_gpu_*`` family — so these
# are requested explicitly by GPU launch paths (``latency_hiding=True``)
# and must stay OFF for the forced-host-device CPU meshes CI runs.
LATENCY_HIDING_FLAGS: tuple[str, ...] = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def jax_backend_initialized() -> bool:
    """True once any XLA backend client exists (flags are locked in).

    Checks the live interpreter state rather than "is jax imported":
    importing jax does not read XLA_FLAGS; creating the first backend
    does.  Probes the private backend cache without triggering backend
    creation (calling any public device API would itself lock the flags).
    """
    if "jax" not in sys.modules:
        return False
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is None:
        return False
    return bool(getattr(xb, "_backends", None))


def _merge_flags(existing: str, updates: list[str]) -> str:
    """Merge flag strings, last-writer-wins per flag name."""
    out: dict[str, str] = {}
    order: list[str] = []
    for tok in existing.split() + updates:
        name = tok.split("=", 1)[0]
        if name not in out:
            order.append(name)
        out[name] = tok
    return " ".join(out[name] for name in order)


def _build(host_device_count: int | None, latency_hiding: bool,
           extra: tuple[str, ...] | list[str], existing: str) -> str:
    updates: list[str] = []
    if host_device_count is not None:
        if host_device_count < 1:
            raise ValueError("host_device_count must be >= 1")
        updates.append(
            f"--xla_force_host_platform_device_count={host_device_count}"
        )
    if latency_hiding:
        updates.extend(LATENCY_HIDING_FLAGS)
    updates.extend(extra)
    return _merge_flags(existing, updates)


def apply_xla_flags(
    host_device_count: int | None = None,
    latency_hiding: bool = False,
    extra: tuple[str, ...] | list[str] = (),
) -> str:
    """Set ``os.environ["XLA_FLAGS"]`` — verified to land before jax init.

    Merges into any flags already present (per-flag, last writer wins, so
    re-applying the same value is idempotent).  Raises ``RuntimeError``
    if a jax backend already exists and the merge would CHANGE the flag
    string — the change could never take effect, and the silent version
    of that bug is exactly what this helper retires.  Returns the final
    flag string.
    """
    existing = os.environ.get("XLA_FLAGS", "")
    merged = _build(host_device_count, latency_hiding, extra, existing)
    if merged != existing and jax_backend_initialized():
        raise RuntimeError(
            "XLA backend already initialized; XLA_FLAGS changes can no "
            f"longer take effect (wanted {merged!r}, locked at "
            f"{existing!r}).  Apply flags before the first jax device/"
            "array operation — e.g. at process start, or spawn a "
            "subprocess with mesh_env()."
        )
    os.environ["XLA_FLAGS"] = merged
    return merged


def mesh_env(
    host_device_count: int | None = None,
    latency_hiding: bool = False,
    extra: tuple[str, ...] | list[str] = (),
    base: dict | None = None,
) -> dict:
    """A child-process environment with the merged XLA_FLAGS.

    The subprocess-launch twin of :func:`apply_xla_flags`: never touches
    this process's environment (so the parent's already-initialized jax
    is irrelevant), which is how the CPU-mesh CI jobs and the multihost
    benchmark give each worker its own forced device count.
    """
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = _build(
        host_device_count, latency_hiding, extra, env.get("XLA_FLAGS", "")
    )
    return env
