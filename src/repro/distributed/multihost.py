"""Multi-host SPMD secure rounds: pod x share meshes, pipelined scans.

The single-process drivers (``SecureFitDriver``, ``StudyCoordinator``)
simulate every institution on one host.  This module is the launcher for
the real layout the paper describes: institutions laid along the
``POD_AXIS`` of a device mesh (one party per pod, ``secure_psum`` as the
wire) and — new here — the Computation Centers laid along a second
``SHARE_AXIS``, so each center-device only ever *holds* its own share
slice and the reveal itself is distributed.  Both wires — and the
``_distributed_reveal`` boundary itself — live on
:class:`repro.core.collective.SecureCollective` (``psum`` /
``psum_2d``); this module is the mesh/launcher layer around them:

* **1D (pod) mesh** — every device runs the full t-slice wire of
  :func:`repro.core.collective.secure_psum`; the scan-resident round
  chain (:func:`scan_secure_rounds`) keeps a whole block of rounds
  in-graph with the next round's sharing randomness generated while the
  current round's collective is in flight (double buffering: on a
  backend with async collectives + the latency-hiding scheduler the two
  overlap; on the CPU CI mesh it is the same math, just scheduled
  serially).
* **2D (pod, share) mesh** — :func:`secure_psum_2d`: each (pod i,
  share j) device evaluates institution i's sharing polynomial, keeps
  ONLY slice j, field-psums it over the pod axis (Algorithm 2, executed
  by center j), then the *reveal is a collective too*: each center
  scales its aggregated slice by its public Lagrange weight
  ``L_j(0) mod p_r`` and one exact uint64 psum over the share axis +
  trailing mod reconstructs the aggregate residues everywhere (CRT
  decode is local).  No device ever assembles another center's share —
  the wire moves exactly one slice per hop, matching the paper's trust
  model where centers jointly reveal only aggregates.

CI runs all of this on a forced-host-device CPU mesh
(``--xla_force_host_platform_device_count``, via
:mod:`repro.distributed.xla_flags` so the flag provably lands before jax
initializes); real multi-process runs call
:func:`initialize_distributed` first.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from ..obs import trace as _trace
from .compat import axis_size, make_mesh, shard_map
from .sharding import POD_AXIS, SHARE_AXIS

__all__ = [
    "SHARE_AXIS",
    "initialize_distributed",
    "pod_mesh",
    "pod_share_mesh",
    "secure_psum_2d",
    "scan_secure_rounds",
    "run_scanned_rounds",
]


def initialize_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> bool:
    """Gated ``jax.distributed.initialize``; no-op for single-process CI.

    Args default from the standard env vars (``JAX_COORDINATOR_ADDRESS``
    / ``JAX_NUM_PROCESSES`` / ``JAX_PROCESS_ID``).  Returns True iff the
    distributed runtime was started — a forced-host-device CPU mesh in
    one process (the CI configuration) needs no runtime, so a plain
    ``num_processes in (None, 1)`` environment falls straight through.
    """
    if num_processes is None:
        num_processes = int(os.environ.get("JAX_NUM_PROCESSES", "1") or 1)
    if num_processes <= 1:
        return False
    jax.distributed.initialize(
        coordinator_address=coordinator_address
        or os.environ.get("JAX_COORDINATOR_ADDRESS"),
        num_processes=num_processes,
        process_id=process_id
        if process_id is not None
        else int(os.environ.get("JAX_PROCESS_ID", "0")),
    )
    return True


def pod_mesh(num_pods: int):
    """1D institution mesh: one party per device along ``POD_AXIS``."""
    return make_mesh((num_pods,), (POD_AXIS,))


def pod_share_mesh(num_pods: int, num_centers: int):
    """2D (pod, share) mesh: institutions x Computation Centers.

    ``num_centers`` is the reveal-subset size — normally the scheme
    threshold t, one device column per center that participates in the
    distributed reveal.
    """
    return make_mesh((num_pods, num_centers), (POD_AXIS, SHARE_AXIS))


def secure_psum_2d(tree, key, aggregator=None, dtype=jnp.float32,
                   pod_axis: str = POD_AXIS, share_axis: str = SHARE_AXIS,
                   points=None):
    """Secret-shared all-reduce on a 2D (pod, share) mesh.

    Call from inside ``shard_map`` over :func:`pod_share_mesh`.  The
    share-axis size must equal the reveal subset (default: the scheme
    threshold t).  Every (pod, share) device derives the SAME sharing
    polynomial for its pod (the rng folds only the pod index), keeps
    only its own slice, and the two collectives are

    1. uint64 psum over ``pod_axis``  — Algorithm 2 at center j;
    2. weighted uint64 psum over ``share_axis`` — the distributed
       Lagrange reveal (the ``_distributed_reveal`` boundary).

    Bit-equal to the 1D ``secure_psum`` wire: both reveal the exact
    field encoding of the global sum.  The chain itself is
    :meth:`repro.core.collective.SecureCollective.psum_2d`; this is the
    historical entry point.
    """
    from ..core.collective import SecureCollective

    agg = aggregator or SecureCollective(backend="pallas")
    return agg.psum_2d(tree, key, dtype=dtype, pod_axis=pod_axis,
                       share_axis=share_axis, points=points)


def scan_secure_rounds(tree, key, num_rounds: int, aggregator=None,
                       axis_name: str = POD_AXIS,
                       reveal: str = "replicated",
                       dtype=jnp.float32):
    """``num_rounds`` secure rounds as ONE in-graph ``lax.scan``.

    Call from inside ``shard_map`` over a 1D pod mesh.  Each round
    protects the current tree, field-all-reduces the t-slice share
    buffer over ``axis_name`` and reveals the aggregate; the revealed
    *mean* feeds the next round (a stand-in for the Newton update that
    keeps the round-to-round data dependency of the real fit).

    Double buffering: the sharing coefficients for round r+1 are drawn
    in the same scan step that reduces round r's shares — the two are
    data-independent, so a backend with async collectives and the
    latency-hiding scheduler (``LATENCY_HIDING_FLAGS``) overlaps the
    rng/encode work with the in-flight collective (request those flags
    via ``xla_flags.apply_xla_flags(latency_hiding=True)`` on GPU
    launches only — CPU builds abort on unknown ``--xla_gpu_*`` flags).
    Rounds use ``fold_in(key, slot)`` so the chain is bit-reproducible
    regardless of how many rounds one scan covers.
    """
    from ..core.collective import (
        REVEAL_MODES,
        SecureCollective,
        check_aggregation_headroom,
    )
    from ..core.field import random_elements_fast
    from ..core.flatbuf import LANES, pack_pytree, unpack_pytree
    from ..kernels import ops

    agg = aggregator or SecureCollective(backend="pallas")
    if agg.backend != "pallas":
        raise ValueError("scan_secure_rounds needs the flat-buffer wire")
    if reveal not in REVEAL_MODES:
        raise ValueError(f"reveal must be one of {REVEAL_MODES}")
    pts = agg._validated_points(None)
    scheme, field = agg.scheme, agg.scheme.field
    num_devices = axis_size(axis_name)
    check_aggregation_headroom(num_devices, field)
    key = agg.round_key(key, jax.lax.axis_index(axis_name))

    row_align = 8 if reveal == "replicated" else math.lcm(8, num_devices)
    buf0, layout = pack_pytree(tree, row_align=row_align)
    buf0 = buf0.astype(jnp.float64)

    def draw_coeffs(slot):
        return random_elements_fast(
            agg.round_key(key, slot),
            (scheme.threshold - 1, layout.rows, LANES), field,
        ).astype(jnp.uint32)

    def body(carry, _):
        buf, coeffs, slot = carry
        shares = ops.shamir_protect_flat(
            buf, coeffs, scheme.num_shares, field.moduli,
            agg.codec.frac_bits, interpret=scheme.interpret, points=pts,
        )
        if reveal == "replicated":
            summed = agg.allreduce(shares, axis_name)
            flat = agg.reveal_wire(summed, pts)
        else:
            tile = agg.allreduce(shares, axis_name, scatter_axis=2)
            flat_tile = agg.reveal_wire(tile, pts)
            flat = jax.lax.all_gather(
                flat_tile, axis_name, axis=0, tiled=True
            )
        # round r+1's sharing randomness: independent of the collective
        # above, so the latency-hiding scheduler may overlap them
        coeffs_next = draw_coeffs(slot)
        buf_next = flat / num_devices  # revealed mean -> next round input
        return (buf_next, coeffs_next, slot + 1), flat[0, 0]

    carry0 = (buf0, draw_coeffs(jnp.zeros((), jnp.int32)),
              jnp.ones((), jnp.int32))  # round 0's coeffs pre-drawn; the
    # in-scan draw at carry slot r produces round r's coeffs for the
    # next step, so executed round r always folds (key, r)
    (buf, _, _), trace = jax.lax.scan(body, carry0, None,
                                      length=num_rounds)
    return unpack_pytree(buf, layout, dtype=dtype), trace


def run_scanned_rounds(num_pods: int, tree, key, num_rounds: int,
                       aggregator=None, reveal: str = "replicated",
                       dtype=jnp.float32):
    """Host-level convenience: shard_map + jit around scan_secure_rounds.

    The input tree is replicated to every pod (each institution submits
    the same values, so round 1 reveals ``num_pods * tree`` and every
    later round preserves the mean — an easy invariant for tests and the
    round-latency benchmark).  Returns ``(final_tree, trace)``.
    """
    from jax.sharding import PartitionSpec as P

    mesh = pod_mesh(num_pods)
    fn = jax.jit(shard_map(
        lambda: scan_secure_rounds(
            tree, key, num_rounds, aggregator=aggregator, reveal=reveal,
            dtype=dtype,
        ),
        mesh=mesh, in_specs=(), out_specs=P(), check_vma=False,
    ))
    with _trace.span("scan_block", "run_scanned_rounds",
                     num_pods=num_pods, num_rounds=num_rounds):
        return fn()
