from .compat import make_mesh, shard_map
from .sharding import MeshRules, param_pspec, param_shardings

__all__ = ["MeshRules", "make_mesh", "param_pspec", "param_shardings",
           "shard_map"]
