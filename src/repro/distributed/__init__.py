from .sharding import MeshRules, param_pspec, param_shardings

__all__ = ["MeshRules", "param_pspec", "param_shardings"]
