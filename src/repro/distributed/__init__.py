"""Distributed execution: meshes, sharding rules, multi-host launch.

Lazy re-exports (PEP 562): ``xla_flags`` must be importable WITHOUT
importing jax — it has to run before the first backend init to do its
job — but ``compat``/``sharding`` import jax at module level.  Attribute
access resolves the submodule on first use, so
``from repro.distributed.xla_flags import apply_xla_flags`` stays
jax-free while ``from repro.distributed import MeshRules`` keeps
working unchanged.
"""
from __future__ import annotations

__all__ = ["MeshRules", "POD_AXIS", "SHARE_AXIS", "axis_size",
           "initialize_distributed", "make_mesh", "param_pspec",
           "param_shardings", "pod_mesh", "pod_share_mesh",
           "run_scanned_rounds", "scan_secure_rounds", "secure_psum_2d",
           "shard_map"]

_COMPAT = ("axis_size", "make_mesh", "shard_map")
_SHARDING = ("MeshRules", "POD_AXIS", "param_pspec", "param_shardings")
_MULTIHOST = ("SHARE_AXIS", "initialize_distributed", "pod_mesh",
              "pod_share_mesh", "run_scanned_rounds", "scan_secure_rounds",
              "secure_psum_2d")


def __getattr__(name: str):
    if name in _COMPAT:
        from . import compat
        return getattr(compat, name)
    if name in _SHARDING:
        from . import sharding
        return getattr(sharding, name)
    if name in _MULTIHOST:
        from . import multihost
        return getattr(multihost, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
