from .compat import axis_size, make_mesh, shard_map
from .sharding import MeshRules, POD_AXIS, param_pspec, param_shardings

__all__ = ["MeshRules", "POD_AXIS", "axis_size", "make_mesh", "param_pspec",
           "param_shardings", "shard_map"]
