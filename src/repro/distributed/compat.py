"""jax API compatibility shims (0.4.x <-> 0.6+ drift).

The codebase targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); this module makes
them work on the pinned jax 0.4.37, where ``shard_map`` still lives in
``jax.experimental.shard_map`` (with the ``check_rep`` spelling of the
replication check) and meshes carry no axis types.  Every call site routes
through here instead of feature-testing jax inline.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "axis_size"]

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _EXP_SHARD_MAP
else:
    _EXP_SHARD_MAP = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` under both the 0.4.x and 0.6+ APIs.

    ``check_vma`` maps onto the 0.4.x ``check_rep`` flag (same semantics:
    verify per-output replication/varying-mesh-axes claims).
    """
    if _NEW_SHARD_MAP is not None:
        return _NEW_SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    return _EXP_SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name: str) -> int:
    """Static size of a mapped mesh axis, from inside shard_map/pmap.

    Modern jax spells this ``jax.lax.axis_size``; on 0.4.x the equivalent
    is ``lax.psum(1, axis)``, which is evaluated eagerly for non-tracer
    operands and returns a Python int.  Callers rely on the result being
    static (it sizes reduce-scatter tiles, exact-sum overflow guards, and
    the 2D-mesh share-axis checks in ``distributed.multihost``), so both
    spellings resolve at trace time.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return int(fn(axis_name))
    return int(jax.lax.psum(1, axis_name))


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    Newer jax lets collectives distinguish Auto vs Explicit axes; 0.4.x
    meshes are implicitly all-Auto, so dropping the argument is exact.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(axis_type.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)
