"""Logical-axis sharding rules -> NamedSharding, with fallback chains.

The production mesh is fixed at (16, 16) ["data", "model"] per pod (plus a
leading "pod" axis multi-pod), but the assigned architectures have head
counts like 40, 24 and 56 that do not divide 16.  Rather than per-arch
meshes, each parameter kind carries a *fallback chain*: e.g. attention QKV
projections are column-parallel over heads when ``H % tp == 0`` and fall
back to row-parallel over d_model (XLA inserts the psum) otherwise.  The
rules are name-based over the parameter pytree paths, MaxText-style.

Institutions (the paper's parties) map to the ``POD_AXIS`` ("pod") axis;
all data-parallel batch axes are ("pod", "data") in multi-pod meshes.
``secure_psum`` runs over ``POD_AXIS`` — it is the axis whose all-reduce
the secret-shared wire replaces — so mesh builders, the secure-psum
benchmark and the SPMD tests all take the name from here.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshRules", "POD_AXIS", "SHARE_AXIS", "param_pspec",
           "param_shardings"]

# The institution axis: one paper party per pod.  secure_psum's share
# reductions (and the sharded reveal's reduce-scatter) run over this axis.
POD_AXIS = "pod"

# The computation-center axis of the 2D (pod, share) mesh
# (``distributed.multihost``): reveal point j lives on mesh column j, so a
# center-device only ever holds its own share slice and reconstruction is
# a psum of Lagrange-weighted slices over this axis.  Orthogonal to
# POD_AXIS.
SHARE_AXIS = "share"


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Carries the mesh + axis naming; no-ops cleanly when mesh is None."""

    mesh: Mesh | None = None
    tp_axis: str = "model"
    fsdp: bool = True
    pod_axis: str = POD_AXIS

    @property
    def dp_axes(self):
        if self.mesh is None:
            return ("data",)
        return tuple(n for n in self.mesh.axis_names if n != self.tp_axis)

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        s = 1
        for a in self.dp_axes:
            s *= self.mesh.shape[a]
        return s

    def fsdp_axes(self):
        return self.dp_axes if self.fsdp else None

    # activation / intermediate constraints ---------------------------------
    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )

    def batch_spec(self):
        """Leading-axis data parallelism for activations."""
        return self.dp_axes

    def sharding(self, *spec) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))


def _divisible(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def param_pspec(path: str, shape: tuple[int, ...], rules: MeshRules,
                cfg) -> P:
    """Name-based parameter partition spec with divisibility fallbacks.

    ``path`` is a '/'-joined pytree path; cfg is the ModelConfig (for head
    counts).  Returned specs only ever shard axes that divide evenly.
    """
    tp, fsdp = rules.tp_axis, rules.fsdp_axes()
    tpn = rules.tp_size

    def fs(dim: int):
        """fsdp axes if they divide dim, else None."""
        if fsdp is None:
            return None
        return fsdp if _divisible(dim, rules.dp_size) else None

    name = path.split("/")[-1]
    # ---- FSDP-only (ZeRO-3) mode: block weights row-sharded over the
    # full mesh, no TP.  Activations are batch-sharded over every axis
    # (transformer._block_batch_spec); embed/lm_head keep their usual
    # specs — the head runs in the staged dp-only region.
    if (
        (getattr(cfg, "fsdp_only", False)
         or getattr(cfg, "seq_parallel_prefill", False))
        and len(shape) >= 2
        and name in ("wq", "wk", "wv", "wo", "w1", "w2", "w3",
                     "wq_mla", "wkv_a", "wk_up", "wv_up")
    ):
        full = rules.dp_axes + (tp,) if rules.mesh is not None else None
        sz = rules.dp_size * rules.tp_size
        if full:
            for dim in range(len(shape)):
                if _divisible(shape[dim], sz):
                    spec = [None] * len(shape)
                    spec[dim] = full
                    return P(*spec)
        return P(fs(shape[0]), None)
    # ---- embeddings / unembedding
    if name == "embed":  # (V, d)
        return P(tp if _divisible(shape[0], tpn) else None, fs(shape[1]))
    if name == "lm_head":  # (d, V)
        return P(fs(shape[0]), tp if _divisible(shape[1], tpn) else None)
    # ---- norms / scalars / biases over d
    if name.startswith(("ln", "norm")) or len(shape) <= 1:
        return P(*([None] * len(shape)))
    # ---- attention projections
    if name in ("wq", "wk", "wv", "wkv_b"):  # (d, H*Dh) fused out axis
        heads = {"wq": cfg.num_heads, "wk": cfg.num_kv_heads,
                 "wv": cfg.num_kv_heads, "wkv_b": cfg.num_heads}[name]
        if _divisible(heads, tpn):
            return P(fs(shape[0]), tp)  # column-parallel over heads
        if _divisible(shape[0], tpn):
            return P(tp, None)  # row-parallel fallback (psum after)
        return P(None, None)
    if name == "wo":  # (H*Dh, d)
        if _divisible(cfg.num_heads, tpn):
            return P(tp, fs(shape[1]))  # row-parallel (Megatron pair)
        if _divisible(shape[1], tpn):
            return P(None, tp)
        return P(None, None)
    # ---- MLA projections
    if name in ("wkv_a", "wq_mla"):  # (d, small) down-projections
        return P(fs(shape[0]) if name == "wkv_a" else None, None) \
            if not _divisible(cfg.num_heads, tpn) else P(fs(shape[0]),
                                                         None)
    if name in ("wk_up", "wv_up"):  # (lora, H*dim)
        return P(None, tp if _divisible(cfg.num_heads, tpn) else None)
    # ---- dense MLP
    if name in ("w1", "w3"):  # (d, ff)
        if _divisible(shape[1], tpn):
            return P(fs(shape[0]), tp)
        return P(fs(shape[0]), None)
    if name == "w2":  # (ff, d)
        if _divisible(shape[0], tpn):
            return P(tp, fs(shape[1]))
        return P(None, fs(shape[1]))
    # ---- MoE
    if name == "router":  # (d, E)
        return P(None, None)
    if name.startswith("experts_"):  # (E, d, h) / (E, h, d)
        return P(tp if _divisible(shape[0], tpn) else None, None, None)
    if name.startswith("shared_"):  # shared expert, shard like dense mlp
        if name.endswith(("w1", "w3")):
            return P(fs(shape[0]),
                     tp if _divisible(shape[1], tpn) else None)
        return P(tp if _divisible(shape[0], tpn) else None, fs(shape[1]))
    # ---- RWKV6 (heads rarely divide tp)
    if name.startswith("rwkv_w_"):  # (d, d) / channel-mix projections
        if getattr(cfg, "rwkv_batch_parallel", False):
            # batch-parallel mode: weights FSDP-sharded over the FULL mesh,
            # no TP — activations are batch-sharded over (data x model)
            # instead (see transformer._apply_block), so no per-projection
            # psums; full-mesh sharding keeps the backward's gradient
            # accumulators sharded too (they follow the param spec).
            full = rules.dp_axes + (tp,) if rules.mesh is not None else None
            sz = rules.dp_size * rules.tp_size
            if full and _divisible(shape[0], sz):
                return P(full, None)
            return P(fs(shape[0]), None)
        if _divisible(shape[0], tpn):
            return P(tp, None)  # row-parallel (psum after)
        return P(None, None)
    # ---- RG-LRU / Griffin
    if name in ("lru_in", "lru_gate"):  # (d, lru)
        return P(fs(shape[0]), tp if _divisible(shape[1], tpn) else None)
    if name == "lru_out":  # (lru, d)
        return P(tp if _divisible(shape[0], tpn) else None, fs(shape[1]))
    if name.startswith("lru_"):  # per-channel vectors (lru,)
        return P(*([None] * len(shape)))
    # default: replicate
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape, rules: MeshRules, cfg):
    """Map a (possibly abstract) param pytree to NamedShardings."""
    def one(path, leaf):
        pstr = _path_str(path)
        if "segments" in pstr and leaf.ndim >= 1:
            # stacked-over-layers leaf: (L_seg, *unstacked); the scan axis
            # stays unsharded, rules apply to the per-layer shape.
            spec = param_pspec(pstr, leaf.shape[1:], rules, cfg)
            return rules.sharding(None, *spec)
        spec = param_pspec(pstr, leaf.shape, rules, cfg)
        return rules.sharding(*spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)
