"""Evaluation datasets shaped like the paper's four studies.

The paper evaluates on Insurance (COIL 2000; 9,822 x 84, 5 institutions),
Parkinsons.Motor / Parkinsons.Total (5,875 x 20, 5 institutions) and a
1M x 6 Synthetic study (6 institutions).  The real UCI files are not
available in this offline container, so we generate *deterministic
stand-ins with identical shapes and institution splits* — logistic
responses over correlated Gaussian covariates, binarized UPDRS-style
targets for the Parkinsons pair (same covariates, different responses,
matching the paper's sub-study construction).  All benchmark claims keyed
to these datasets (iterations-to-converge, central-vs-total runtime
shares, bytes transmitted) are structural and carry over; coefficient
values obviously differ from the real data and are never compared to the
paper's.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .partition import partition_rows
from .synthetic import generate_synthetic

__all__ = ["Study", "load_study", "STUDIES"]


@dataclasses.dataclass
class Study:
    name: str
    parts: list  # [(X_j, y_j)] per institution
    lam: float = 1.0

    @property
    def num_samples(self) -> int:
        return sum(int(p[0].shape[0]) for p in self.parts)

    @property
    def num_features(self) -> int:
        return int(self.parts[0][0].shape[1])

    def pooled(self):
        X = jnp.concatenate([p[0] for p in self.parts], axis=0)
        y = jnp.concatenate([p[1] for p in self.parts], axis=0)
        return X, y


def _logistic_table(key, n, d, num_inst, rho=0.3, dtype=jnp.float64):
    """Correlated-covariate logistic data, horizontally partitioned."""
    kb, kz, ke, ky = jax.random.split(key, 4)
    beta = jax.random.uniform(kb, (d,), minval=-0.8, maxval=0.8, dtype=dtype)
    common = jax.random.normal(kz, (n, 1), dtype=dtype)
    eps = jax.random.normal(ke, (n, d - 1), dtype=dtype)
    cov = jnp.sqrt(rho) * common + jnp.sqrt(1 - rho) * eps
    X = jnp.concatenate([jnp.ones((n, 1), dtype=dtype), cov], axis=1)
    y = jax.random.bernoulli(ky, jax.nn.sigmoid(X @ beta)).astype(dtype)
    return partition_rows(X, y, num_inst)


def load_study(name: str, seed: int = 0, scale: float = 1.0) -> Study:
    """``scale`` shrinks row counts for CI-speed runs (1.0 = paper size)."""
    key = jax.random.PRNGKey(hash(name) % (2**31) + seed)
    def rows(n):
        return max(64, int(n * scale))

    if name == "insurance":
        parts = _logistic_table(key, rows(9_822), 84, 5)
        return Study("insurance", parts, lam=1.0)
    if name in ("parkinsons.motor", "parkinsons.total"):
        # same covariates, different response (paper's two sub-studies)
        base = jax.random.PRNGKey(424242 + seed)
        kb, ky1, ky2 = jax.random.split(base, 3)
        n, d = rows(5_875), 20
        parts_x = _logistic_table(kb, n, d, 5)
        X = jnp.concatenate([p[0] for p in parts_x], axis=0)
        kk = ky1 if name.endswith("motor") else ky2
        kbeta, kber = jax.random.split(kk)
        beta = jax.random.uniform(kbeta, (d,), minval=-0.6, maxval=0.6,
                                  dtype=jnp.float64)
        y = jax.random.bernoulli(kber, jax.nn.sigmoid(X @ beta))
        return Study(name, partition_rows(X, y.astype(jnp.float64), 5), lam=1.0)
    if name == "synthetic":
        study = generate_synthetic(
            key,
            num_institutions=6,
            records_per_institution=rows(1_000_000 // 6),
            dim=6,
        )
        return Study("synthetic", list(study.parts), lam=1.0)
    raise KeyError(f"unknown study {name!r}")


STUDIES = ("insurance", "parkinsons.motor", "parkinsons.total", "synthetic")
