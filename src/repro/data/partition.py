"""Horizontal (per-institution) partitioning of pooled datasets."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["partition_rows"]


def partition_rows(X, y, num_institutions: int):
    """Split rows round-robin-contiguously into S institution-local parts.

    Mirrors the paper's "randomly partitioned the dataset horizontally";
    rows are assumed pre-shuffled (our generators draw i.i.d. rows).
    """
    n = X.shape[0]
    sizes = [n // num_institutions] * num_institutions
    for i in range(n % num_institutions):
        sizes[i] += 1
    parts, off = [], 0
    for s in sizes:
        parts.append((jnp.asarray(X[off : off + s]), jnp.asarray(y[off : off + s])))
        off += s
    return parts
