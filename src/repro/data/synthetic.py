"""Algorithm 3: synthetic dataset generation, partitioned per institution."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["generate_synthetic", "SyntheticStudy"]


class SyntheticStudy(tuple):
    """(beta_true, parts) where parts = [(X_j, y_j)] per institution."""

    @property
    def beta_true(self):
        return self[0]

    @property
    def parts(self):
        return self[1]

    def pooled(self):
        X = jnp.concatenate([p[0] for p in self[1]], axis=0)
        y = jnp.concatenate([p[1] for p in self[1]], axis=0)
        return X, y


def generate_synthetic(
    key: jax.Array,
    num_institutions: int = 6,
    records_per_institution: int = 10_000,
    dim: int = 6,
    mu: float = 0.0,
    sigma: float = 1.0,
    beta_scale: float = 1.0,
    dtype=jnp.float64,
) -> SyntheticStudy:
    """Paper Algorithm 3.

    1. beta ~ U(-beta_scale, beta_scale), d-dimensional (incl. intercept).
    2. Per institution j: cov_j ~ N(mu, sigma^2) of shape (N_j, d-1);
       X_j = [1 | cov_j]; p_j = sigmoid(X_j beta); y_j ~ Bernoulli(p_j).
    """
    k_beta, k_data = jax.random.split(key)
    beta = jax.random.uniform(
        k_beta, (dim,), minval=-beta_scale, maxval=beta_scale, dtype=dtype
    )
    parts = []
    for j in range(num_institutions):
        k_data, k_cov, k_y = jax.random.split(k_data, 3)
        cov = mu + sigma * jax.random.normal(
            k_cov, (records_per_institution, dim - 1), dtype=dtype
        )
        Xj = jnp.concatenate(
            [jnp.ones((records_per_institution, 1), dtype=dtype), cov], axis=1
        )
        pj = jax.nn.sigmoid(Xj @ beta)
        yj = jax.random.bernoulli(k_y, pj).astype(dtype)
        parts.append((Xj, yj))
    return SyntheticStudy((beta, parts))
