from .synthetic import SyntheticStudy, generate_synthetic
from .datasets import STUDIES, Study, load_study
from .partition import partition_rows

__all__ = ["SyntheticStudy", "generate_synthetic", "STUDIES", "Study",
           "load_study", "partition_rows"]
