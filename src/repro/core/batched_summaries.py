"""Batched per-institution summaries: the local phase without the S loop.

``newton.secure_fit`` originally looped Python-side over the S institutions,
dispatching one ``local_summaries`` per partition per Newton iteration.
This module packs the ragged partitions ONCE per fit into a stacked
(S, N_max, d) layout with row masks and computes every institution's
(H_j, g_j, dev_j) in a single batched launch per iteration:

* ``backend="pallas"`` — one ``kernels.fused_irls`` launch for all S
  institutions (X streamed through VMEM once; IRLS weights never touch
  HBM; Gram accumulation in f32 as on the MXU).
* ``backend="reference"`` — the masked jnp oracle (f64 end to end), used
  by tests and as the legacy-comparable gold path.
* ``backend="mixed"`` — f64 gradient/deviance with a split-accumulation
  f32 Gram (chunked f32 gemms merged in f64): ~4x the Hessian accuracy
  of the single-pass f32 Gram at f32-gemm speed, the natural two-pass
  variant for the TPU kernel at production N.

Padding contract: rows >= counts[s] are zero AND masked in-kernel, so the
stacked layout is exact for arbitrarily uneven partitions (including an
institution smaller than one kernel block).  The packed arrays are the
per-fit constants; only beta changes across iterations, which is what
lets the whole Newton step stay jit-resident.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from typing import NamedTuple

from .logreg import LocalSummaries

__all__ = ["PackedPartitions", "pack_partitions", "batched_local_summaries",
           "CVSummaries", "batched_cv_summaries",
           "pack_cache_clear", "pack_cache_evict", "pack_cache_len"]

BACKENDS = ("reference", "pallas", "mixed")


@dataclasses.dataclass(frozen=True)
class PackedPartitions:
    """Stacked ragged partitions + the static facts the kernels need.

    ``X``/``y`` are zero-padded to (S, N_max, d); ``X32`` is the pre-cast
    f32 MXU operand for the Gram matmul (cast once per fit, not per
    iteration).  With a float32 payload — the TPU storage dtype, and what
    the fused ``secure_fit`` packs — ``X`` and ``X32`` are the SAME
    array; with float64 (the oracle/test payload) both live side by
    side.  ``y`` stays f64 either way: labels are 0/1 (exact in any
    float) and the gradient/deviance accumulate in f64.
    """

    X: jnp.ndarray  # (S, N_max, d) payload (f32 or f64)
    X32: jnp.ndarray  # (S, N_max, d) float32 MXU operand
    y: jnp.ndarray  # (S, N_max) float64
    counts: jnp.ndarray  # (S,) int32 true row counts

    @property
    def num_institutions(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[2]

    @property
    def total_records(self) -> int:
        return int(np.sum(np.asarray(self.counts)))


@functools.partial(jax.jit, static_argnames=("n_max", "dtype"))
def _stack_pad(xs, ys, n_max: int, dtype):
    """One fused graph for pad + stack + the f32 MXU-operand cast."""
    Xs = jnp.stack([
        jnp.pad(jnp.asarray(X, dtype), ((0, n_max - X.shape[0]), (0, 0)))
        for X in xs
    ])
    ys_ = jnp.stack([
        jnp.pad(jnp.asarray(y, jnp.float64), (0, n_max - y.shape[0]))
        for y in ys
    ])
    X32 = Xs if Xs.dtype == jnp.float32 else Xs.astype(jnp.float32)
    return Xs, X32, ys_


# LRU pack cache for pack_partitions.  jax arrays are immutable, so the
# identity of every part buffer is a sound cache key as long as no id is
# recycled behind the cache's back.  Each entry therefore holds a weakref
# to every part buffer whose finalizer evicts the entry the moment any
# referent is collected — a recycled id can never alias a dead buffer, and
# the cache pins no input arrays (only the packed outputs, bounded by
# ``_PACK_CACHE_SIZE`` entries).  Multiple slots serve alternating
# multi-study workloads (coordinator cohorts that churn and churn back,
# lambda sweeps over several studies) without thrashing repacks, the same
# way the jit cache serves multiple traced shapes.
_PACK_CACHE: "collections.OrderedDict[tuple, tuple[list, PackedPartitions]]" \
    = collections.OrderedDict()
# Entry bound, not a byte bound: each entry pins one packed study (f64
# payload + f32 MXU copy — hundreds of MB at benchmark scale), so the
# bound IS the residency ceiling.  4 covers the alternation patterns
# that motivated the LRU (two studies ping-ponging, a churned cohort
# plus its churn-back, a lambda sweep over a pair) at 4x the old
# single-slot ceiling; entries also die early via the weakref
# finalizers when their study's buffers are released.
_PACK_CACHE_SIZE = 4


def _pack_cache_key(parts, dtype) -> tuple:
    return (
        tuple((id(Xj), id(yj)) for Xj, yj in parts), jnp.dtype(dtype).name
    )


def pack_cache_clear():
    """Drop every cached pack (packed buffers become collectable)."""
    _PACK_CACHE.clear()


def pack_cache_evict(parts, dtype=None):
    """Evict any cached pack that includes one of ``parts``' buffers.

    Institution-churn hook: a coordinator that adds/removes an institution
    calls this with the churned partition so no later cohort can resurrect
    a stale padded batch through a recycled buffer id (the weakref
    finalizers already cover collected buffers; this covers live ones
    leaving a cohort).  ``dtype=None`` evicts across payload dtypes.
    """
    ids = {id(b) for part in parts for b in part}
    for key in list(_PACK_CACHE):
        part_ids, dt_name = key
        if dtype is not None and dt_name != jnp.dtype(dtype).name:
            continue
        if any(i in ids or j in ids for i, j in part_ids):
            _PACK_CACHE.pop(key, None)


def pack_cache_len() -> int:
    return len(_PACK_CACHE)


def pack_partitions(
    parts: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    dtype=jnp.float64,
) -> PackedPartitions:
    """Stack S ragged (X_j, y_j) partitions into one masked batch.

    Once per *study* — repeated calls with the same part arrays return
    the cached pack (a small LRU, so alternating studies or churned
    cohorts each keep their pack resident).  The padded copies (plus the
    f32 MXU operand) replace S live partition references, traded for a
    loop-free iteration.  Pad/stack/cast run as one jitted graph (a few
    hundred MB of pure memory movement at benchmark scale; doing it
    eagerly per part costs 2-3x that).  ``dtype`` is the X payload:
    float64 keeps the exact oracle payload (plus a separate f32 MXU
    operand); float32 stores one f32 buffer total — the TPU layout.
    """
    if not parts:
        raise ValueError("need at least one partition")
    d = parts[0][0].shape[1]
    if any(Xj.shape[1] != d for Xj, _ in parts):
        raise ValueError("all partitions must share the feature dimension")
    # identity-keyed caching is only sound for immutable buffers: numpy
    # (or other mutable) inputs bypass the cache entirely
    cacheable = all(
        isinstance(Xj, jax.Array) and isinstance(yj, jax.Array)
        for Xj, yj in parts
    )
    key = _pack_cache_key(parts, dtype)
    if cacheable:
        hit = _PACK_CACHE.get(key)
        if hit is not None:
            _PACK_CACHE.move_to_end(key)
            return hit[1]
    counts = np.asarray([Xj.shape[0] for Xj in (p[0] for p in parts)],
                        np.int32)
    n_max = int(counts.max())
    Xs, X32, ys = _stack_pad(
        [p[0] for p in parts], [p[1] for p in parts], n_max,
        jnp.dtype(dtype).name,
    )
    packed = PackedPartitions(Xs, X32, ys, jnp.asarray(counts))
    if cacheable:
        # evict-on-collect: if ANY part buffer dies, the ids in `key` may
        # be recycled, so the entry must go before a lookup can alias it
        evict = lambda _ref, key=key: _PACK_CACHE.pop(key, None)
        refs = [weakref.ref(b, evict) for part in parts for b in part]
        _PACK_CACHE[key] = (refs, packed)
        while len(_PACK_CACHE) > _PACK_CACHE_SIZE:
            _PACK_CACHE.popitem(last=False)
    return packed


def _masked_irls_terms(beta, X, y, counts):
    """Shared payload-dtype IRLS terms: row mask, weights, gradient,
    deviance.  Single source of truth for every non-kernel backend —
    the "g/dev identical to the reference oracle" contract of the mixed
    backend holds by construction, not by keeping copies in sync."""
    n = X.shape[1]
    mask = (jnp.arange(n)[None, :] < counts[:, None]).astype(X.dtype)
    z = jnp.einsum("snd,d->sn", X, beta.astype(X.dtype))
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p) * mask
    g = jnp.einsum("snd,sn->sd", X, (y - p) * mask)
    dev = -2.0 * jnp.sum((y * z - jnp.logaddexp(0.0, z)) * mask, axis=1)
    return w, g, dev


def _reference_summaries(beta, X, y, counts):
    """Masked batched oracle in the payload dtype (f64)."""
    w, g, dev = _masked_irls_terms(beta, X, y, counts)
    H = jnp.einsum("sni,snj->sij", X * w[..., None], X)
    return H, g, dev


# Gram chunk length for the mixed backend: long enough that the f32 gemms
# stay MXU/SIMD-efficient, short enough that in-chunk f32 accumulation
# error stays below the f32 *operand* rounding floor (which chunking
# cannot remove).
MIXED_GRAM_CHUNK = 1024


def _mixed_summaries(beta, X, X32, y, counts, chunk: int = MIXED_GRAM_CHUNK):
    """f64 gradient/deviance + split-accumulation f32 Gram.

    The middle rung of the summaries precision ladder, between the f64
    reference (exact, but the f64 Gram IS the round's flop wall) and the
    f32-Gram kernel (fastest, largest H error):

    * z, p, w, g, dev — f64, identical to the reference oracle (the
      gradient fixes the Newton fixed point, so it must stay exact).
    * H — the two-pass "split" accumulation the TPU kernel would use at
      large N: f32 gemms over ``chunk``-row slabs of the weighted
      operand, merged across slabs in f64.  The f32 accumulation chain
      shrinks from N to ``chunk``, cutting the measured H error ~4.4x
      under the single-pass f32 Gram at N=2e5 (down to the f32 operand-
      rounding floor, ~1e-7 relative) at f32-gemm speed.

    Contract note: like the pallas backend, this holds CONVERGED-beta
    parity with the f64 oracle inside fixed-point quantization; it does
    NOT hold per-ROUND parity at production N (the mid-run Newton
    transient amplifies even the operand-floor H perturbation past the
    quantization tolerance) — use the reference backend for that.
    """
    n, d = X.shape[1], X.shape[2]
    w, g, dev = _masked_irls_terms(beta, X, y, counts)
    num_chunks = -(-n // chunk)
    pad = num_chunks * chunk - n
    Xw32 = (X * w[..., None]).astype(jnp.float32)

    def slabs(a):
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        return a.reshape(a.shape[0], num_chunks, chunk, d)

    # (S, nc, d, d) f32 partial Grams, merged across slabs in f64
    Hc = jax.lax.dot_general(
        slabs(Xw32), slabs(X32), (((2,), (2,)), ((0, 1), (0, 1)))
    )
    H = jnp.sum(Hc.astype(jnp.float64), axis=1)
    return H, g, dev


# -- cross-validated summaries: fold masks over the SAME packed batch --------

class CVSummaries(NamedTuple):
    """Per-(config, institution) train summaries + held-out metrics.

    The selection subsystem's batched mirror of ``LocalSummaries``: every
    field carries leading (C, S) axes — C path configs (lambda x fold
    pairs, plus optional full-data fits with ``fold == -1``) over S
    institutions — all emitted by ONE pass over the packed batch.  The
    validation fields are per-institution secrets exactly like H/g/dev:
    they only ever leave an institution secret-shared.
    """

    hessian: jnp.ndarray  # (C, S, d, d) train-fold Gram
    gradient: jnp.ndarray  # (C, S, d) train-fold score
    deviance: jnp.ndarray  # (C, S) train-fold -2 log L
    count: jnp.ndarray  # (C, S) train-fold row count
    val_deviance: jnp.ndarray  # (C, S) held-out -2 log L
    val_correct: jnp.ndarray  # (C, S) held-out correct predictions
    val_count: jnp.ndarray  # (C, S) held-out row count


def _cv_masks(X, counts, fold_ids, fold_of):
    """(tmask, vmask) float64 (C, S, N): fold masks composed onto the
    ragged row mask.  ``fold_of == -1`` selects no validation rows, so a
    full-data fit shares the batch with the fold fits."""
    n = X.shape[1]
    row_ok = jnp.arange(n, dtype=jnp.int32)[None, :] < counts[:, None]
    on_fold = fold_ids[None] == fold_of[:, None, None]
    tmask = (row_ok[None] & ~on_fold).astype(jnp.float64)
    vmask = (row_ok[None] & on_fold).astype(jnp.float64)
    return tmask, vmask


def _cv_common_terms(betas, X, y, tmask, vmask):
    """f64 z/g/dev/val terms shared by the reference and mixed rungs (and
    matching the sim's f64-accumulation contract).  Returns everything
    except the Gram, which is what the rungs differ on."""
    s_dim = X.shape[0]
    z = jnp.einsum("snd,cd->csn", X, betas.astype(X.dtype))
    z = z.astype(jnp.float64)
    p = jax.nn.sigmoid(z)
    ll = y[None] * z - jnp.logaddexp(0.0, z)
    dev_tr = -2.0 * jnp.sum(ll * tmask, axis=2)
    dev_va = -2.0 * jnp.sum(ll * vmask, axis=2)
    acc_va = jnp.sum(
        jnp.where((z > 0.0) == (y[None] > 0.5), vmask, 0.0), axis=2
    )
    w = (p * (1.0 - p)) * tmask  # (C, S, N) train-fold IRLS weights
    resid = (y[None] - p) * tmask
    g = jnp.stack([
        jax.lax.dot_general(
            resid[:, s], X[s], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float64,
        )
        for s in range(s_dim)
    ], axis=1)  # (C, S, d)
    return w, g, dev_tr, dev_va, acc_va


def batched_cv_summaries(
    betas: jnp.ndarray,
    packed: PackedPartitions,
    fold_ids: jnp.ndarray,
    fold_of: jnp.ndarray,
    backend: str = "pallas",
    interpret: bool = True,
    block_n: int = 512,
) -> CVSummaries:
    """All (config, institution) train summaries + held-out metrics in one
    launch over the packed batch — no per-fold repacking, ever.

    ``betas`` (C, d) holds one Newton iterate per path config;
    ``fold_ids`` (S, N_max) the per-row fold assignment (padding rows may
    hold anything — the row mask already excludes them); ``fold_of`` (C,)
    names each config's held-out fold (-1: none).  ``backend`` selects
    the same precision ladder as ``batched_local_summaries``:

    * "reference" — f64 end to end (per-round-parity rung),
    * "pallas"    — the kernel layout: f32 Gram, f64 g/dev
      (``interpret=True`` runs the XLA simulation, exactly like the
      non-CV path),
    * "mixed"     — f64 g/dev + chunked split-accumulation f32 Gram.

    The Gram on every rung runs as a ``lax.map`` over the config axis so
    the traced graph size is independent of path length.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    fold_ids = fold_ids.astype(jnp.int32)
    fold_of = fold_of.astype(jnp.int32)
    if backend == "pallas":
        from ..kernels import ops

        H, g, dev_tr, dev_va, acc_va, n_va = ops.fused_irls_cv(
            betas, packed.X, packed.y, fold_ids, fold_of,
            counts=packed.counts, block_n=block_n, interpret=interpret,
            mxu_operand=packed.X32,
        )
        # train + held-out rows partition the valid rows exactly (also
        # for fold_of == -1, where n_va == 0), so n_tr needs no dense
        # (C, S, N) mask materialization inside the sweep scan
        n_va = n_va.astype(jnp.float64)
        n_tr = packed.counts[None, :].astype(jnp.float64) - n_va
        return CVSummaries(
            H.astype(jnp.float64), g.astype(jnp.float64),
            dev_tr.astype(jnp.float64), n_tr,
            dev_va.astype(jnp.float64), acc_va.astype(jnp.float64),
            n_va,
        )
    X, y = packed.X, packed.y
    tmask, vmask = _cv_masks(X, packed.counts, fold_ids, fold_of)
    w, g, dev_tr, dev_va, acc_va = _cv_common_terms(
        betas, X, y, tmask, vmask
    )
    s_dim, d = X.shape[0], X.shape[2]
    if backend == "reference":
        def gram_one(w_c):  # (S, N) f64 -> (S, d, d) f64
            return jnp.stack([
                (X[s] * w_c[s][:, None]).T @ X[s] for s in range(s_dim)
            ])

        H = jax.lax.map(gram_one, w)
    else:  # mixed: chunked f32 gemms merged in f64, per config
        X32 = packed.X32
        n = X.shape[1]
        chunk = MIXED_GRAM_CHUNK
        num_chunks = -(-n // chunk)
        pad = num_chunks * chunk - n

        def slabs(a):
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
            return a.reshape(s_dim, num_chunks, chunk, d)

        X32s = slabs(X32)

        def gram_one(w_c):  # (S, N) -> (S, d, d): split accumulation
            Xw32 = slabs((X * w_c[..., None]).astype(jnp.float32))
            Hc = jax.lax.dot_general(
                Xw32, X32s, (((2,), (2,)), ((0, 1), (0, 1)))
            )  # (S, nc, d, d) f32 partial Grams
            return jnp.sum(Hc.astype(jnp.float64), axis=1)

        H = jax.lax.map(gram_one, w)
    n_tr = jnp.sum(tmask, axis=2)
    n_va = jnp.sum(vmask, axis=2)
    return CVSummaries(H, g, dev_tr, n_tr, dev_va, acc_va, n_va)


def batched_local_summaries(
    beta: jnp.ndarray,
    packed: PackedPartitions,
    backend: str = "pallas",
    interpret: bool = True,
    block_n: int = 512,
) -> LocalSummaries:
    """All S institutions' summaries in one launch.

    Returns a ``LocalSummaries`` whose fields carry a leading S axis:
    hessian (S, d, d), gradient (S, d), deviance (S,), count (S,) — the
    batched mirror of ``local_summaries`` (which remains the
    per-institution oracle).  Everything is traceable, so this composes
    into the jit-resident secure iteration.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if backend == "mixed":
        H, g, dev = _mixed_summaries(
            beta, packed.X, packed.X32, packed.y, packed.counts
        )
        return LocalSummaries(H, g, dev, packed.counts)
    if backend == "pallas":
        from ..kernels import ops

        # interpret=True routes to the kernel's XLA simulation inside
        # ops.fused_irls (block_n then has no effect); interpret=False
        # compiles the blocked TPU kernel with VMEM-sized N tiles.
        H, g, dev = ops.fused_irls(
            beta, packed.X, packed.y, packed.counts,
            block_n=block_n, interpret=interpret, mxu_operand=packed.X32,
        )
        # protocol dtype: the fixed-point encode needs f64 past 2**24
        H = H.astype(jnp.float64)
        g = g.astype(jnp.float64)
        dev = dev.astype(jnp.float64)
    else:
        H, g, dev = _reference_summaries(
            beta, packed.X, packed.y, packed.counts
        )
    return LocalSummaries(H, g, dev, packed.counts)
