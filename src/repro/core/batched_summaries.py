"""Batched per-institution summaries: the local phase without the S loop.

``newton.secure_fit`` originally looped Python-side over the S institutions,
dispatching one ``local_summaries`` per partition per Newton iteration.
This module packs the ragged partitions ONCE per fit into a stacked
(S, N_max, d) layout with row masks and computes every institution's
(H_j, g_j, dev_j) in a single batched launch per iteration:

* ``backend="pallas"`` — one ``kernels.fused_irls`` launch for all S
  institutions (X streamed through VMEM once; IRLS weights never touch
  HBM; Gram accumulation in f32 as on the MXU).
* ``backend="reference"`` — the masked jnp oracle (f64 end to end), used
  by tests and as the legacy-comparable gold path.

Padding contract: rows >= counts[s] are zero AND masked in-kernel, so the
stacked layout is exact for arbitrarily uneven partitions (including an
institution smaller than one kernel block).  The packed arrays are the
per-fit constants; only beta changes across iterations, which is what
lets the whole Newton step stay jit-resident.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .logreg import LocalSummaries

__all__ = ["PackedPartitions", "pack_partitions", "batched_local_summaries"]

BACKENDS = ("reference", "pallas")


@dataclasses.dataclass(frozen=True)
class PackedPartitions:
    """Stacked ragged partitions + the static facts the kernels need.

    ``X``/``y`` are zero-padded to (S, N_max, d); ``X32`` is the pre-cast
    f32 MXU operand for the Gram matmul (cast once per fit, not per
    iteration).  With a float32 payload — the TPU storage dtype, and what
    the fused ``secure_fit`` packs — ``X`` and ``X32`` are the SAME
    array; with float64 (the oracle/test payload) both live side by
    side.  ``y`` stays f64 either way: labels are 0/1 (exact in any
    float) and the gradient/deviance accumulate in f64.
    """

    X: jnp.ndarray  # (S, N_max, d) payload (f32 or f64)
    X32: jnp.ndarray  # (S, N_max, d) float32 MXU operand
    y: jnp.ndarray  # (S, N_max) float64
    counts: jnp.ndarray  # (S,) int32 true row counts

    @property
    def num_institutions(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[2]

    @property
    def total_records(self) -> int:
        return int(np.sum(np.asarray(self.counts)))


@functools.partial(jax.jit, static_argnames=("n_max", "dtype"))
def _stack_pad(xs, ys, n_max: int, dtype):
    """One fused graph for pad + stack + the f32 MXU-operand cast."""
    Xs = jnp.stack([
        jnp.pad(jnp.asarray(X, dtype), ((0, n_max - X.shape[0]), (0, 0)))
        for X in xs
    ])
    ys_ = jnp.stack([
        jnp.pad(jnp.asarray(y, jnp.float64), (0, n_max - y.shape[0]))
        for y in ys
    ])
    X32 = Xs if Xs.dtype == jnp.float32 else Xs.astype(jnp.float32)
    return Xs, X32, ys_


# Single-slot memo for pack_partitions.  jax arrays are immutable, so the
# identity of every part buffer is a sound cache key as long as those
# buffers stay alive — the slot holds strong references to them (and to
# the packed copies), so ids cannot be recycled while the entry exists.
# One slot bounds the extra residency to one packed study; refitting the
# same partitions (lambda sweeps, protect-mode comparisons, benchmark
# repeats) then skips hundreds of MB of re-packing, the same way the jit
# cache skips re-tracing.
_PACK_MEMO: dict = {}


def pack_partitions(
    parts: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    dtype=jnp.float64,
) -> PackedPartitions:
    """Stack S ragged (X_j, y_j) partitions into one masked batch.

    Once per *study* — repeated calls with the same part arrays return
    the memoized pack.  The padded copies (plus the f32 MXU operand)
    replace S live partition references, traded for a loop-free
    iteration.  Pad/stack/cast run as one jitted graph (a few hundred MB
    of pure memory movement at benchmark scale; doing it eagerly per
    part costs 2-3x that).  ``dtype`` is the X payload: float64 keeps
    the exact oracle payload (plus a separate f32 MXU operand); float32
    stores one f32 buffer total — the TPU layout.
    """
    if not parts:
        raise ValueError("need at least one partition")
    d = parts[0][0].shape[1]
    if any(Xj.shape[1] != d for Xj, _ in parts):
        raise ValueError("all partitions must share the feature dimension")
    # identity-keyed memoization is only sound for immutable buffers:
    # numpy (or other mutable) inputs bypass the memo entirely
    cacheable = all(
        isinstance(Xj, jax.Array) and isinstance(yj, jax.Array)
        for Xj, yj in parts
    )
    key = (
        tuple((id(Xj), id(yj)) for Xj, yj in parts), jnp.dtype(dtype).name
    )
    if cacheable:
        hit = _PACK_MEMO.get("slot")
        if hit is not None and hit[0] == key:
            return hit[2]
    counts = np.asarray([Xj.shape[0] for Xj in (p[0] for p in parts)],
                        np.int32)
    n_max = int(counts.max())
    Xs, X32, ys = _stack_pad(
        [p[0] for p in parts], [p[1] for p in parts], n_max,
        jnp.dtype(dtype).name,
    )
    packed = PackedPartitions(Xs, X32, ys, jnp.asarray(counts))
    if cacheable:
        _PACK_MEMO["slot"] = (key, list(parts), packed)
    return packed


def _reference_summaries(beta, X, y, counts):
    """Masked batched oracle in the payload dtype (f64)."""
    n = X.shape[1]
    mask = (jnp.arange(n)[None, :] < counts[:, None]).astype(X.dtype)
    z = jnp.einsum("snd,d->sn", X, beta.astype(X.dtype))
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p) * mask
    H = jnp.einsum("sni,snj->sij", X * w[..., None], X)
    g = jnp.einsum("snd,sn->sd", X, (y - p) * mask)
    dev = -2.0 * jnp.sum((y * z - jnp.logaddexp(0.0, z)) * mask, axis=1)
    return H, g, dev


def batched_local_summaries(
    beta: jnp.ndarray,
    packed: PackedPartitions,
    backend: str = "pallas",
    interpret: bool = True,
    block_n: int = 512,
) -> LocalSummaries:
    """All S institutions' summaries in one launch.

    Returns a ``LocalSummaries`` whose fields carry a leading S axis:
    hessian (S, d, d), gradient (S, d), deviance (S,), count (S,) — the
    batched mirror of ``local_summaries`` (which remains the
    per-institution oracle).  Everything is traceable, so this composes
    into the jit-resident secure iteration.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if backend == "pallas":
        from ..kernels import ops

        # interpret=True routes to the kernel's XLA simulation inside
        # ops.fused_irls (block_n then has no effect); interpret=False
        # compiles the blocked TPU kernel with VMEM-sized N tiles.
        H, g, dev = ops.fused_irls(
            beta, packed.X, packed.y, packed.counts,
            block_n=block_n, interpret=interpret, mxu_operand=packed.X32,
        )
        # protocol dtype: the fixed-point encode needs f64 past 2**24
        H = H.astype(jnp.float64)
        g = g.astype(jnp.float64)
        dev = dev.astype(jnp.float64)
    else:
        H, g, dev = _reference_summaries(
            beta, packed.X, packed.y, packed.counts
        )
    return LocalSummaries(H, g, dev, packed.counts)
