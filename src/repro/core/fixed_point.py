"""Fixed-point encoding of real summaries into the secret-sharing field.

The paper encodes real-valued summary statistics (Hessians, gradients,
deviances) into a finite field before sharing; the encoding is unspecified.
We use standard two's-complement-style fixed point:

    encode(x) = round(x * 2**frac_bits)  lifted to residues mod p_r
    decode(v) = centered_signed(v) / 2**frac_bits

Exactness contract: the *aggregation* (sums over institutions and the
share-wise homomorphic ops) is exact in the field as long as the aggregate
magnitude stays below ``field.max_signed / 2**frac_bits``.  ``capacity()``
exposes that bound so protocol code can assert headroom (e.g. S institutions
x max |H_ij| each).  Quantization happens once, at encode time.

The Pallas backend fuses this codec into the share/reconstruct kernels
(``kernels.shamir_poly.shamir_encode_share_pallas`` mirrors ``encode``
bit-for-bit via an exact float hi/lo split; the reconstruct kernel emits
the Garner digit that ``decode``'s CRT recombination needs) — this module
remains the leaf-wise oracle those kernels are tested against.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .field import FieldSpec, FIELD_WIDE, crt_combine_signed, lift_signed

__all__ = ["FixedPointCodec"]


@dataclasses.dataclass(frozen=True)
class FixedPointCodec:
    field: FieldSpec = FIELD_WIDE
    # 28 frac bits: quantization 3.7e-9 (below the paper's 1e-10-relative
    # deviance tolerance at realistic deviance magnitudes) while leaving
    # ~8.6e9 of integer headroom for Hessian-scale aggregates.
    frac_bits: int = 28

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    def capacity(self) -> float:
        """Largest |real value| exactly representable (incl. aggregates)."""
        return self.field.max_signed / self.scale

    def encode(self, x: jnp.ndarray) -> jnp.ndarray:
        """float array (...) -> field residues (R, ...) uint64."""
        scaled = jnp.round(jnp.asarray(x, jnp.float64) * self.scale)
        lim = float(self.field.max_signed)
        scaled = jnp.clip(scaled, -lim, lim)
        return lift_signed(scaled.astype(jnp.int64), self.field)

    def decode(self, v: jnp.ndarray, dtype=jnp.float64) -> jnp.ndarray:
        """field residues (R, ...) -> float array (...)."""
        signed = crt_combine_signed(v, self.field)
        return (signed.astype(jnp.float64) / self.scale).astype(dtype)
