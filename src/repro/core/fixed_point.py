"""Fixed-point encoding of real summaries into the secret-sharing field.

The paper encodes real-valued summary statistics (Hessians, gradients,
deviances) into a finite field before sharing; the encoding is unspecified.
We use standard two's-complement-style fixed point:

    encode(x) = round(x * 2**frac_bits)  lifted to residues mod p_r
    decode(v) = centered_signed(v) / 2**frac_bits

Exactness contract: the *aggregation* (sums over institutions and the
share-wise homomorphic ops) is exact in the field as long as the aggregate
magnitude stays below ``field.max_signed / 2**frac_bits``.  ``capacity()``
exposes that bound so protocol code can assert headroom (e.g. S institutions
x max |H_ij| each).  Quantization happens once, at encode time.

Values past capacity saturate (clip to +-max_signed) rather than wrap — a
wrapped aggregate would reveal as an arbitrary float, a saturated one at
least as the capacity bound.  Saturation is still silently *wrong*, so the
protect paths offer a debug-mode overflow check (``check_headroom`` /
``encode(..., check=True)``): a host assert that raises ``OverflowError``
the moment any value exceeds capacity, eagerly outside jit and at the next
sync point inside (the same condition ``SecureAggregator.headroom_ok``
expresses as a predicate).

The Pallas backend fuses this codec into the share/reconstruct kernels
(``kernels.shamir_poly.shamir_encode_share_pallas`` mirrors ``encode``
bit-for-bit via an exact float hi/lo split; the reconstruct kernel emits
the Garner digit that ``decode``'s CRT recombination needs) — this module
remains the leaf-wise oracle those kernels are tested against.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .field import FieldSpec, FIELD_WIDE, crt_combine_signed, lift_signed

__all__ = ["FixedPointCodec"]


def _overflow_cb(max_abs, *, bound: float, capacity: float, what: str):
    """Host-side assert behind ``jax.debug.callback``: raises eagerly in
    op-by-op mode and at the next device sync under jit/shard_map."""
    if float(max_abs) > bound:
        raise OverflowError(
            f"fixed-point overflow in {what}: max |value| {float(max_abs):g} "
            f"exceeds the codec capacity {capacity:g} — the aggregate would "
            "saturate and reveal a plausible-but-wrong float (see "
            "SecureAggregator.headroom_ok for the aggregate bound)"
        )


@dataclasses.dataclass(frozen=True)
class FixedPointCodec:
    field: FieldSpec = FIELD_WIDE
    # 28 frac bits: quantization 3.7e-9 (below the paper's 1e-10-relative
    # deviance tolerance at realistic deviance magnitudes) while leaving
    # ~8.6e9 of integer headroom for Hessian-scale aggregates.
    frac_bits: int = 28

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    def capacity(self) -> float:
        """Largest |real value| exactly representable (incl. aggregates)."""
        return self.field.max_signed / self.scale

    def check_headroom(self, x: jnp.ndarray, num_addends: int = 1,
                       what: str = "protect"):
        """Debug-mode overflow assert on a float tensor headed for encode.

        Raises ``OverflowError`` when ``num_addends * max|x|`` exceeds
        ``capacity()`` — the predicate ``headroom_ok`` tests, turned into a
        hard failure so saturation can never masquerade as a valid reveal.
        Traceable: inside jit the assert fires at the next sync point.
        """
        cap = self.capacity()
        jax.debug.callback(
            _overflow_cb,
            jnp.max(jnp.abs(jnp.asarray(x, jnp.float64))),
            bound=cap / max(1, num_addends), capacity=cap, what=what,
        )

    def encode(self, x: jnp.ndarray, check: bool = False) -> jnp.ndarray:
        """float array (...) -> field residues (R, ...) uint64.

        ``check=True`` arms the debug-mode overflow assert: values whose
        scaled magnitude exceeds ``field.max_signed`` raise instead of
        silently saturating at the clip below.
        """
        scaled = jnp.round(jnp.asarray(x, jnp.float64) * self.scale)
        lim = float(self.field.max_signed)
        if check:
            jax.debug.callback(
                _overflow_cb, jnp.max(jnp.abs(scaled)),
                bound=lim, capacity=self.capacity(), what="encode",
            )
        scaled = jnp.clip(scaled, -lim, lim)
        return lift_signed(scaled.astype(jnp.int64), self.field)

    def decode(self, v: jnp.ndarray, dtype=jnp.float64) -> jnp.ndarray:
        """field residues (R, ...) -> float array (...)."""
        signed = crt_combine_signed(v, self.field)
        return (signed.astype(jnp.float64) / self.scale).astype(dtype)
