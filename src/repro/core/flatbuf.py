"""Flat-buffer pytree codec for the fused secure-aggregation pipeline.

The reference secure path walks the summary pytree leaf by leaf: one
encode, one share kernel, one reconstruct per leaf, per institution.  That
makes protect/aggregate/reveal cost O(num_leaves) dispatches — interpreter
overhead, not algorithm.  This module packs an arbitrary float pytree into
ONE contiguous ``(rows, 128)`` tile buffer (the Pallas lane layout used by
``kernels/ops.py``) so each protocol phase is a single kernel launch
regardless of pytree shape.

Layout contract:

* Leaves are raveled in ``tree_flatten`` order and concatenated.
* The tail is zero-padded up to ``rows * 128`` with ``rows`` a multiple of
  ``row_align`` (default 8 — the float32 sublane tile; also fine for
  uint32.  The sharded ``secure_psum`` wire passes ``lcm(8, D)`` so the
  rows axis reduce-scatters into per-device tiles that keep the (8, 128)
  sublane layout).
* ``FlatLayout`` remembers treedef + shapes + dtypes so ``unpack`` is exact.

Padding is benign end to end: zero floats encode to residue 0, shares of 0
aggregate to shares of 0, and the revealed tail is dropped by ``unpack``.
The layout is static (hashable) so jitted pipelines treat it as a compile-
time constant.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FlatLayout", "pack_pytree", "pack_pytree_batched",
           "unpack_pytree", "unpack_pytree_batched",
           "tile_slices", "unpack_pytree_tile"]

LANES = 128
ROW_ALIGN = 8  # float32 / uint32 sublane tile


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static description of how a pytree maps into one (rows, 128) buffer."""

    treedef: jax.tree_util.PyTreeDef
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[str, ...]
    rows: int

    @property
    def num_elements(self) -> int:
        return sum(int(np.prod(s, dtype=np.int64)) for s in self.shapes)

    @property
    def padded(self) -> int:
        return self.rows * LANES

    def __hash__(self):
        return hash((self.treedef, self.shapes, self.dtypes, self.rows))


def _rows_for(n: int, row_align: int) -> int:
    rows = max(1, -(-n // LANES))
    return -(-rows // row_align) * row_align


def pack_pytree(
    tree, dtype=None, row_align: int = ROW_ALIGN
) -> tuple[jnp.ndarray, FlatLayout]:
    """Pack a float pytree into one zero-padded (rows, 128) buffer.

    ``dtype`` defaults to the promoted dtype of the leaves (float64 trees
    stay float64 — required for exact fixed-point encode past 2**24).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(str(jnp.asarray(l).dtype) for l in leaves)
    if dtype is None:
        dtype = jnp.result_type(*[jnp.asarray(l).dtype for l in leaves])
    flat = jnp.concatenate(
        [jnp.ravel(jnp.asarray(l)).astype(dtype) for l in leaves]
    )
    rows = _rows_for(flat.size, row_align)
    buf = jnp.pad(flat, (0, rows * LANES - flat.size)).reshape(rows, LANES)
    return buf, FlatLayout(treedef, shapes, dtypes, rows)


def pack_pytree_batched(
    tree, dtype=None, row_align: int = ROW_ALIGN
) -> tuple[jnp.ndarray, FlatLayout]:
    """Pack a pytree of S-leading arrays into one (S, rows, 128) buffer.

    Every leaf carries the same leading batch axis (one slot per
    institution); the returned ``FlatLayout`` describes a SINGLE slice —
    leaf shapes without the batch axis — so after reducing the S axis the
    aggregate unpacks with plain ``unpack_pytree``.  All S slices are
    raveled/padded with one concatenate instead of S ``pack_pytree`` calls,
    which is what keeps the batched protect path a single dispatch chain.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    leaves = [jnp.asarray(l) for l in leaves]
    batch = leaves[0].shape[0]
    if any(l.shape[:1] != (batch,) for l in leaves):
        raise ValueError("all leaves need the same leading batch axis")
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(str(l.dtype) for l in leaves)
    if dtype is None:
        dtype = jnp.result_type(*[l.dtype for l in leaves])
    flat = jnp.concatenate(
        [l.reshape(batch, -1).astype(dtype) for l in leaves], axis=1
    )  # (S, num_elements)
    rows = _rows_for(flat.shape[1], row_align)
    buf = jnp.pad(flat, ((0, 0), (0, rows * LANES - flat.shape[1])))
    return buf.reshape(batch, rows, LANES), FlatLayout(
        treedef, shapes, dtypes, rows
    )


def unpack_pytree_batched(buf: jnp.ndarray, layout: FlatLayout, dtype=None):
    """Invert ``pack_pytree_batched``: (B, rows, 128) -> pytree of
    (B, *shape) leaves.

    The batch axis survives as the leading axis of every leaf — this is
    how the selection sweep unpacks one revealed buffer per config from a
    single reveal launch over the (C * rows, 128) stack.
    """
    batch = buf.shape[0]
    flat = buf.reshape(batch, -1)
    leaves, offset = [], 0
    for shape, ldt in zip(layout.shapes, layout.dtypes):
        n = int(np.prod(shape, dtype=np.int64))
        out_dt = dtype if dtype is not None else ldt
        leaves.append(
            flat[:, offset:offset + n].reshape((batch,) + shape)
            .astype(out_dt)
        )
        offset += n
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


@dataclasses.dataclass(frozen=True)
class _TileFragment:
    """One leaf's intersection with one rows-tile (all indices static)."""

    leaf: int                  # index into layout.shapes
    leaf_start: int            # [leaf_start, leaf_stop) of the raveled leaf
    leaf_stop: int
    tile_offset: int           # where the fragment begins inside the tile


def tile_slices(
    layout: FlatLayout, num_tiles: int
) -> tuple[tuple[_TileFragment, ...], ...]:
    """Static table of leaf fragments per rows-tile.

    Splitting the ``(rows, 128)`` buffer into ``num_tiles`` equal row
    blocks (the ``psum_scatter`` layout of ``secure_psum`` with
    ``reveal="sharded"``), entry ``t`` lists which slice of which raveled
    leaf lives in tile ``t``.  Everything here is Python ints derived from
    the static layout, so jitted code can consume the table as
    compile-time constants.  The zero pad tail belongs to no fragment.
    """
    if layout.rows % num_tiles:
        raise ValueError(
            f"rows={layout.rows} does not split into {num_tiles} tiles; "
            "pack with row_align=lcm(ROW_ALIGN, num_tiles)"
        )
    tile_elems = layout.padded // num_tiles
    bounds, offset = [], 0
    for shape in layout.shapes:
        n = int(np.prod(shape, dtype=np.int64))
        bounds.append((offset, offset + n))
        offset += n
    table = []
    for t in range(num_tiles):
        lo, hi = t * tile_elems, (t + 1) * tile_elems
        frags = []
        for i, (a, b) in enumerate(bounds):
            s, e = max(a, lo), min(b, hi)
            if s < e:
                frags.append(_TileFragment(i, s - a, e - a, s - lo))
        table.append(tuple(frags))
    return tuple(table)


def unpack_pytree_tile(
    tile_buf: jnp.ndarray, layout: FlatLayout, tile_index: int,
    num_tiles: int, dtype=None,
):
    """Decode ONE rows-tile into its leaf fragments (no gather needed).

    ``tile_buf`` is one device's ``(rows / num_tiles, 128)`` slice of a
    packed buffer; ``tile_index`` must be a static int (use the
    ``ShardedAggregate`` wrapper when the index is a traced
    ``axis_index``).  Returns ``{leaf_index: (start, stop, fragment)}``
    where ``fragment`` is the flat slice ``raveled_leaf[start:stop]`` —
    a leaf wholly inside the tile comes back complete and can be
    reshaped to ``layout.shapes[leaf_index]`` directly.
    """
    flat = tile_buf.reshape(-1)
    out = {}
    for frag in tile_slices(layout, num_tiles)[tile_index]:
        out_dt = dtype if dtype is not None else layout.dtypes[frag.leaf]
        n = frag.leaf_stop - frag.leaf_start
        out[frag.leaf] = (
            frag.leaf_start,
            frag.leaf_stop,
            flat[frag.tile_offset:frag.tile_offset + n].astype(out_dt),
        )
    return out


def unpack_pytree(buf: jnp.ndarray, layout: FlatLayout, dtype=None):
    """Invert ``pack_pytree``: (rows, 128) buffer -> original pytree.

    ``dtype`` overrides the per-leaf restore dtype (e.g. reveal to float32).
    """
    flat = buf.reshape(-1)
    leaves, offset = [], 0
    for shape, ldt in zip(layout.shapes, layout.dtypes):
        n = int(np.prod(shape, dtype=np.int64))
        out_dt = dtype if dtype is not None else ldt
        leaves.append(flat[offset:offset + n].reshape(shape).astype(out_dt))
        offset += n
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)
