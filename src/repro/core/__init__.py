"""Paper core: secure, distributed L2-regularized logistic regression."""
from .batched_summaries import (
    CVSummaries,
    PackedPartitions,
    batched_cv_summaries,
    batched_local_summaries,
    pack_cache_clear,
    pack_cache_evict,
    pack_cache_len,
    pack_partitions,
)
from .field import FIELD31, FIELD_WIDE, FieldSpec
from .fixed_point import FixedPointCodec
from .flatbuf import (
    FlatLayout,
    pack_pytree,
    pack_pytree_batched,
    tile_slices,
    unpack_pytree,
    unpack_pytree_tile,
)
from .shamir import ShamirScheme
from .collective import SecureCollective, declassify_sum
from .secure_agg import (
    FlatProtected,
    OUT_MODES,
    REVEAL_MODES,
    SecureAggregator,
    ShardedAggregate,
    check_aggregation_headroom,
    secure_add,
    secure_psum,
    secure_scale_by_public,
)
from .logreg import LocalSummaries, local_summaries, predict_proba, deviance
from .newton import (
    FitResult,
    SecureFitDriver,
    centralized_fit,
    newton_step,
    secure_fit,
)
from .protocol import ComputationCenter, Institution, RoundReport, StudyCoordinator

__all__ = [
    "FIELD31", "FIELD_WIDE", "FieldSpec", "FixedPointCodec", "ShamirScheme",
    "FlatLayout", "FlatProtected", "pack_pytree", "pack_pytree_batched",
    "unpack_pytree", "tile_slices", "unpack_pytree_tile",
    "OUT_MODES", "ShardedAggregate",
    "PackedPartitions", "batched_local_summaries", "pack_partitions",
    "CVSummaries", "batched_cv_summaries",
    "pack_cache_clear", "pack_cache_evict", "pack_cache_len",
    "REVEAL_MODES", "SecureAggregator", "SecureCollective",
    "check_aggregation_headroom", "declassify_sum",
    "secure_add", "secure_psum", "secure_scale_by_public",
    "LocalSummaries", "local_summaries", "predict_proba", "deviance",
    "FitResult", "SecureFitDriver", "centralized_fit", "newton_step",
    "secure_fit",
    "ComputationCenter", "Institution", "RoundReport", "StudyCoordinator",
]
