"""Prime-field arithmetic for Shamir secret-sharing, TPU-adapted.

The paper computes over an (unspecified) big-integer prime field.  TPUs have
no 128-bit integer path, so we adapt:

* ``FIELD31``  — single Mersenne prime p = 2**31 - 1.  Elements live in
  uint64; products of two reduced elements are < 2**62 and never overflow.
* ``FIELD_WIDE`` — CRT pair (2**31 - 1, 2**31 - 19).  Residues are carried in
  a leading axis of size 2; every field op is applied per-residue.  The
  combined modulus M = p1*p2 ~= 4.61e18 gives ~61.9 bits of exact dynamic
  range for fixed-point aggregates, and M < 2**62 so CRT recombination fits
  in (u)int64.

All element tensors are uint64 with a leading residue axis ``R`` (R = 1 or 2):
shape ``(R, *secret_shape)``.  Keeping the axis explicit (instead of a sum
type) keeps everything jit/vmap/psum friendly.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax

jax.config.update("jax_enable_x64", True)  # uint64 field math requires x64

import jax.numpy as jnp
import numpy as np

__all__ = [
    "FieldSpec",
    "FIELD31",
    "FIELD_WIDE",
    "fadd",
    "fsub",
    "fmul",
    "fneg",
    "fsum",
    "fpow_host",
    "finv_host",
    "random_elements",
    "random_elements_fast",
    "crt_combine_signed",
]

P31 = np.uint64(2**31 - 1)
P31B = np.uint64(2**31 - 19)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """A prime field (or CRT product of prime fields) for secret sharing."""

    name: str
    moduli: tuple[int, ...]  # python ints, each < 2**31

    @property
    def num_residues(self) -> int:
        return len(self.moduli)

    @property
    def modulus_product(self) -> int:
        m = 1
        for p in self.moduli:
            m *= p
        return m

    @property
    def max_signed(self) -> int:
        """Largest magnitude representable as a centered (signed) value."""
        return (self.modulus_product - 1) // 2

    def moduli_array(self) -> jnp.ndarray:
        """(R, 1, ..) broadcastable moduli as uint64 (caller reshapes)."""
        return jnp.asarray(self.moduli, dtype=jnp.uint64)

    def _bcast(self, x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
        """Moduli broadcast against an element tensor with residue ``axis``."""
        p = self.moduli_array()
        shape = [1] * x.ndim
        shape[axis] = self.num_residues
        return p.reshape(shape)


FIELD31 = FieldSpec("field31", (int(P31),))
FIELD_WIDE = FieldSpec("field_wide", (int(P31), int(P31B)))


def _check(x: jnp.ndarray, field: FieldSpec, axis: int = 0) -> None:
    if x.dtype != jnp.uint64:
        raise TypeError(f"field elements must be uint64, got {x.dtype}")
    if x.shape[axis] != field.num_residues:
        raise ValueError(
            f"residue axis {axis} has size {x.shape[axis]} != field residues "
            f"{field.num_residues}"
        )


def fadd(a: jnp.ndarray, b: jnp.ndarray, field: FieldSpec,
         residue_axis: int = 0) -> jnp.ndarray:
    """(a + b) mod p, per residue.  Inputs reduced; sum < 2**32, no overflow."""
    _check(a, field, residue_axis)
    return (a + b) % field._bcast(a, residue_axis)


def fsub(a: jnp.ndarray, b: jnp.ndarray, field: FieldSpec,
         residue_axis: int = 0) -> jnp.ndarray:
    _check(a, field, residue_axis)
    p = field._bcast(a, residue_axis)
    return (a + (p - b)) % p


def fneg(a: jnp.ndarray, field: FieldSpec, residue_axis: int = 0) -> jnp.ndarray:
    _check(a, field, residue_axis)
    p = field._bcast(a, residue_axis)
    return (p - a) % p


def fmul(a: jnp.ndarray, b: jnp.ndarray, field: FieldSpec,
         residue_axis: int = 0) -> jnp.ndarray:
    """(a * b) mod p.  Reduced inputs < 2**31 so products fit in uint64."""
    _check(a, field, residue_axis)
    return (a * b) % field._bcast(a, residue_axis)


def fsum(stacked: jnp.ndarray, field: FieldSpec, axis: int = 0,
         residue_axis: int = 1) -> jnp.ndarray:
    """Reduce a stacked batch of field tensors mod p in ONE pass.

    ``stacked`` is (S, ..., R, ...) with the residue axis given *after* the
    reduction axis is removed.  The sum runs exact in uint64 (S * p < 2**64
    for any S < 2**33) and reduces mod p once — replacing S-1 pairwise
    ``fadd`` dispatches with a single reduction.  Accepts uint32 share
    tensors (the Pallas flat pipeline's wire format) and returns the input
    dtype.
    """
    dtype = stacked.dtype
    s = jnp.sum(stacked.astype(jnp.uint64), axis=axis)
    _check(s, field, residue_axis)
    return (s % field._bcast(s, residue_axis)).astype(dtype)


def fpow_host(base: int, exp: int, p: int) -> int:
    return pow(int(base), int(exp), int(p))


def finv_host(x: int, p: int) -> int:
    """Modular inverse via Fermat; host-side (public Lagrange points only)."""
    if x % p == 0:
        raise ZeroDivisionError("no inverse of 0")
    return pow(int(x) % p, p - 2, p)


def random_elements(
    key: jax.Array, shape: tuple[int, ...], field: FieldSpec
) -> jnp.ndarray:
    """Uniform random field elements, shape (R, *shape).

    Drawn independently per residue with randint in [0, p_r); exact uniform.
    """
    keys = jax.random.split(key, field.num_residues)
    outs = []
    for r, p in enumerate(field.moduli):
        v = jax.random.randint(keys[r], shape, 0, p, dtype=jnp.int64)
        outs.append(v.astype(jnp.uint64))
    return jnp.stack(outs, axis=0)


def random_elements_fast(
    key: jax.Array, shape: tuple[int, ...], field: FieldSpec
) -> jnp.ndarray:
    """Near-uniform random field elements, shape (R, *shape), as uint64.

    One 64-bit draw reduced mod p per element: modulo bias is p / 2**64
    < 2**-33 — statistically negligible for share-polynomial coefficients,
    and ~5x faster than ``random_elements``'s exact rejection-free randint
    path (which draws and combines twice per element).  The fused Pallas
    protect pipeline uses this; the reference oracle keeps the exact
    sampler.
    """
    keys = jax.random.split(key, field.num_residues)
    outs = []
    for r, p in enumerate(field.moduli):
        v = jax.random.bits(keys[r], shape, jnp.uint64)
        outs.append(v % jnp.uint64(p))
    return jnp.stack(outs, axis=0)


def crt_combine_signed(residues: jnp.ndarray, field: FieldSpec) -> jnp.ndarray:
    """Combine (R, ...) residues into centered signed int64 values.

    For R = 1: center around 0 (values > p/2 map negative).
    For R = 2: Garner's formula — x = r1 + p1 * ((r2 - r1) * inv(p1) mod p2),
    all intermediates < 2**62 so uint64/int64 arithmetic is exact, then
    center around M/2.
    """
    _check(residues, field)
    if field.num_residues == 1:
        p = jnp.uint64(field.moduli[0])
        v = residues[0]
        half = jnp.uint64(field.max_signed)
        return jnp.where(
            v <= half, v.astype(jnp.int64), -( (p - v).astype(jnp.int64) )
        )
    if field.num_residues != 2:
        raise NotImplementedError("only 1- or 2-residue fields supported")
    p1, p2 = field.moduli
    inv_p1 = finv_host(p1, p2)  # public constant
    r1, r2 = residues[0], residues[1]
    u64 = jnp.uint64
    diff = (r2 + (u64(p2) - r1 % u64(p2))) % u64(p2)  # (r2 - r1) mod p2
    k = (diff * u64(inv_p1)) % u64(p2)  # < p2 < 2**31
    x = r1 + u64(p1) * k  # < p1*p2 < 2**62 — exact in uint64
    m = field.modulus_product
    half = u64(field.max_signed)
    neg = -((u64(m) - x).astype(jnp.int64))
    return jnp.where(x <= half, x.astype(jnp.int64), neg)


def lift_signed(values: jnp.ndarray, field: FieldSpec) -> jnp.ndarray:
    """Map signed int64 values (|v| <= max_signed) to (R, ...) residues."""
    outs = []
    for p in field.moduli:
        pp = jnp.int64(p)
        r = values % pp  # python-style mod: already in [0, p)
        outs.append(r.astype(jnp.uint64))
    return jnp.stack(outs, axis=0)
