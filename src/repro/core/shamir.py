"""Shamir t-of-w secret sharing, vectorized over arbitrary tensors/pytrees.

Implements the paper's protection mechanism (Eq. 7): each secret ``m`` is
embedded as the constant term of a random degree-(t-1) polynomial
``q(x) = m + a_1 x + ... + a_{t-1} x^{t-1}`` over a prime field; share ``j``
is ``(j, q(j))`` for j = 1..w.  Reconstruction is Lagrange interpolation at 0
using any t shares.  Everything is elementwise over tensors: one independent
polynomial per tensor element, evaluated with Horner's rule.

Share tensors have shape ``(w, R, *secret_shape)`` where R is the field's
residue count.  The leading axis is the *holder* (Computation Center) axis —
in deployment each slice lives at a different center; in our SPMD simulation
it is carried as a leading dim (or sharded over a mesh axis by the caller).

Backends
--------
``backend="reference"`` (default) runs the uint64 ``%``-reduction math in
plain jnp — the correctness oracle, one dispatch per field op.
``backend="pallas"`` routes the same Horner/Lagrange loops through the
TPU kernels (``kernels/shamir_poly.py`` / ``kernels/shamir_reconstruct.py``,
16-bit-limb ``mulmod31``, interpret mode on CPU).  Given identical
coefficients the two backends produce **bit-identical** shares and
reconstructions — both compute exact field elements; only the word-size
decomposition differs (``share_with_coeffs`` exposes the deterministic
entry point for that contract).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .field import (
    FieldSpec,
    FIELD_WIDE,
    fadd,
    fmul,
    random_elements,
)

__all__ = ["ShamirScheme", "lagrange_coeffs_at_zero", "BACKENDS"]

BACKENDS = ("reference", "pallas")


def lagrange_coeffs_at_zero(
    points: Sequence[int], field: FieldSpec
) -> jnp.ndarray:
    """Public Lagrange weights L_i(0) for reconstruction, per residue.

    Returns (R, len(points)) uint64.  Computed host-side with Python ints —
    the points are public (they identify Computation Centers), so this leaks
    nothing and avoids in-graph modular inverses.
    """
    from ..kernels.shamir_reconstruct import lagrange_weights_host

    return jnp.asarray(
        lagrange_weights_host(tuple(points), field.moduli), dtype=jnp.uint64
    )


@dataclasses.dataclass(frozen=True)
class ShamirScheme:
    """t-of-w threshold scheme over ``field`` with a kernel backend switch."""

    threshold: int = 2  # t: min cooperating centers to reconstruct
    num_shares: int = 3  # w: total Computation Centers
    field: FieldSpec = FIELD_WIDE
    backend: str = "reference"  # "reference" (jnp oracle) | "pallas"
    interpret: bool = True  # pallas interpret mode (CPU container default)

    def __post_init__(self):
        if not (1 <= self.threshold <= self.num_shares):
            raise ValueError("need 1 <= t <= w")
        if self.num_shares >= min(self.field.moduli):
            raise ValueError("w must be < field modulus")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")

    # -- sharing ------------------------------------------------------------
    def share(self, key: jax.Array, secret: jnp.ndarray) -> jnp.ndarray:
        """Split field elements (R, ...) into shares (w, R, ...).

        Coefficients are fresh uniform field elements per tensor element
        (information-theoretic hiding below threshold t); evaluation is
        delegated to ``share_with_coeffs``.
        """
        coeffs = random_elements(
            key, (self.threshold - 1,) + secret.shape[1:], self.field
        )  # (R, t-1, ...)
        return self.share_with_coeffs(secret, coeffs)

    def share_with_coeffs(
        self, secret: jnp.ndarray, coeffs: jnp.ndarray
    ) -> jnp.ndarray:
        """Deterministic share evaluation given coefficients (R, t-1, ...).

        Both backends produce bit-identical output for the same inputs —
        this is the backend-equivalence contract the tests pin down.
        """
        t, w = self.threshold, self.num_shares
        if coeffs.shape[:2] != (self.field.num_residues, t - 1):
            raise ValueError(
                f"coeffs must be (R, t-1, ...), got {coeffs.shape}"
            )
        if t == 1:  # q(x) = m: every share is the secret itself
            return jnp.broadcast_to(secret, (w,) + secret.shape)
        if self.backend == "pallas":
            return self._share_pallas(secret, coeffs)
        return self._share_reference(secret, coeffs)

    def _share_reference(self, secret, coeffs):
        t, w, field = self.threshold, self.num_shares, self.field
        coeffs = jnp.swapaxes(coeffs, 0, 1)  # (t-1, R, ...)

        def eval_at(x: int) -> jnp.ndarray:
            # q(x) = (..(a_{t-1} x + a_{t-2}) x + ..) x + m, per residue
            acc = jnp.zeros_like(secret)
            xs = jnp.full((), x, dtype=jnp.uint64)
            for k in range(t - 2, -1, -1):
                acc = fadd(fmul(acc, xs, field), coeffs[k], field)
            return fadd(fmul(acc, xs, field), secret, field)

        return jnp.stack([eval_at(j) for j in range(1, w + 1)], axis=0)

    def _share_pallas(self, secret, coeffs):
        from ..kernels import ops

        t, w, field = self.threshold, self.num_shares, self.field
        shape = secret.shape[1:]
        per_residue = []
        for r, p in enumerate(field.moduli):
            out = ops.shamir_shares(
                secret[r].reshape(-1).astype(jnp.uint32),
                coeffs[r].reshape(t - 1, -1).astype(jnp.uint32),
                w, p, interpret=self.interpret,
            )  # (w, n) uint32
            per_residue.append(
                out.astype(jnp.uint64).reshape((w,) + shape)
            )
        return jnp.stack(per_residue, axis=1)  # (w, R, ...)

    # -- reconstruction -----------------------------------------------------
    def reconstruct(
        self,
        shares: jnp.ndarray,
        points: Sequence[int] | None = None,
    ) -> jnp.ndarray:
        """Recover secret (R, ...) from >= t shares (k, R, ...).

        ``points`` are the 1-based holder ids of the provided share slices
        (default: 1..k).  Any t-subset suffices; extra shares are consistent.
        """
        k = shares.shape[0]
        if points is None:
            points = list(range(1, k + 1))
        if len(points) != k:
            raise ValueError("points must match share count")
        if k < self.threshold:
            raise ValueError(
                f"need >= t={self.threshold} shares, got {k} "
                "(information-theoretically irrecoverable below threshold)"
            )
        if self.backend == "pallas":
            return self._reconstruct_pallas(shares, points)
        return self._reconstruct_reference(shares, points)

    def _reconstruct_reference(self, shares, points):
        lam = lagrange_coeffs_at_zero(points, self.field)  # (R, k)
        field = self.field
        k = shares.shape[0]
        acc = jnp.zeros_like(shares[0])
        for i in range(k):
            li = lam[:, i].reshape(
                (field.num_residues,) + (1,) * (shares.ndim - 2)
            )
            acc = fadd(acc, fmul(shares[i], li, field), field)
        return acc

    def _reconstruct_pallas(self, shares, points):
        from ..kernels import ops

        field = self.field
        shape = shares.shape[2:]
        k = shares.shape[0]
        per_residue = []
        for r, p in enumerate(field.moduli):
            rec = ops.shamir_reconstruct(
                shares[:, r].reshape(k, -1).astype(jnp.uint32),
                tuple(points), p, interpret=self.interpret,
            )  # (n,) uint32
            per_residue.append(rec.astype(jnp.uint64).reshape(shape))
        return jnp.stack(per_residue, axis=0)  # (R, ...)

    # -- pytree convenience ---------------------------------------------------
    def share_pytree(self, key: jax.Array, tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        shared = [self.share(k, leaf) for k, leaf in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, shared)

    def reconstruct_pytree(self, tree, points: Sequence[int] | None = None):
        return jax.tree_util.tree_map(
            lambda s: self.reconstruct(s, points), tree
        )
