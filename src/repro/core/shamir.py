"""Shamir t-of-w secret sharing, vectorized over arbitrary tensors/pytrees.

Implements the paper's protection mechanism (Eq. 7): each secret ``m`` is
embedded as the constant term of a random degree-(t-1) polynomial
``q(x) = m + a_1 x + ... + a_{t-1} x^{t-1}`` over a prime field; share ``j``
is ``(j, q(j))`` for j = 1..w.  Reconstruction is Lagrange interpolation at 0
using any t shares.  Everything is elementwise over tensors: one independent
polynomial per tensor element, evaluated with Horner's rule (the TPU-friendly
form — t-1 fused multiply-adds in uint64, see kernels/shamir_poly.py for the
Pallas version of the same loop).

Share tensors have shape ``(w, R, *secret_shape)`` where R is the field's
residue count.  The leading axis is the *holder* (Computation Center) axis —
in deployment each slice lives at a different center; in our SPMD simulation
it is carried as a leading dim (or sharded over a mesh axis by the caller).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .field import (
    FieldSpec,
    FIELD_WIDE,
    fadd,
    fmul,
    finv_host,
    random_elements,
)

__all__ = ["ShamirScheme", "lagrange_coeffs_at_zero"]


def lagrange_coeffs_at_zero(
    points: Sequence[int], field: FieldSpec
) -> jnp.ndarray:
    """Public Lagrange weights L_i(0) for reconstruction, per residue.

    Returns (R, len(points)) uint64.  Computed host-side with Python ints —
    the points are public (they identify Computation Centers), so this leaks
    nothing and avoids in-graph modular inverses.
    """
    out = []
    for p in field.moduli:
        row = []
        for i, xi in enumerate(points):
            num, den = 1, 1
            for j, xj in enumerate(points):
                if i == j:
                    continue
                num = (num * xj) % p
                den = (den * ((xj - xi) % p)) % p
            row.append((num * finv_host(den, p)) % p)
        out.append(row)
    return jnp.asarray(out, dtype=jnp.uint64)


@dataclasses.dataclass(frozen=True)
class ShamirScheme:
    """t-of-w threshold scheme over ``field``."""

    threshold: int = 2  # t: min cooperating centers to reconstruct
    num_shares: int = 3  # w: total Computation Centers
    field: FieldSpec = FIELD_WIDE

    def __post_init__(self):
        if not (1 <= self.threshold <= self.num_shares):
            raise ValueError("need 1 <= t <= w")
        if self.num_shares >= min(self.field.moduli):
            raise ValueError("w must be < field modulus")

    # -- sharing ------------------------------------------------------------
    def share(self, key: jax.Array, secret: jnp.ndarray) -> jnp.ndarray:
        """Split field elements (R, ...) into shares (w, R, ...).

        Horner evaluation of the random polynomial at x = 1..w.  Coefficients
        are fresh uniform field elements per tensor element (information-
        theoretic hiding below threshold t).
        """
        t, w, field = self.threshold, self.num_shares, self.field
        coeffs = random_elements(key, (t - 1,) + secret.shape[1:], field)
        # coeffs: (R, t-1, ...) after moving residue axis out front
        coeffs = jnp.swapaxes(coeffs, 0, 1)  # (t-1, R, ...)

        def eval_at(x: int) -> jnp.ndarray:
            # q(x) = (..(a_{t-1} x + a_{t-2}) x + ..) x + m, per residue
            acc = jnp.zeros_like(secret)
            xs = jnp.full((), x, dtype=jnp.uint64)
            for k in range(t - 2, -1, -1):
                acc = fadd(fmul(acc, xs, field), coeffs[k], field)
            return fadd(fmul(acc, xs, field), secret, field)

        return jnp.stack([eval_at(j) for j in range(1, w + 1)], axis=0)

    # -- reconstruction -----------------------------------------------------
    def reconstruct(
        self,
        shares: jnp.ndarray,
        points: Sequence[int] | None = None,
    ) -> jnp.ndarray:
        """Recover secret (R, ...) from >= t shares (k, R, ...).

        ``points`` are the 1-based holder ids of the provided share slices
        (default: 1..k).  Any t-subset suffices; extra shares are consistent.
        """
        k = shares.shape[0]
        if points is None:
            points = list(range(1, k + 1))
        if len(points) != k:
            raise ValueError("points must match share count")
        if k < self.threshold:
            raise ValueError(
                f"need >= t={self.threshold} shares, got {k} "
                "(information-theoretically irrecoverable below threshold)"
            )
        lam = lagrange_coeffs_at_zero(points, self.field)  # (R, k)
        field = self.field
        acc = jnp.zeros_like(shares[0])
        for i in range(k):
            li = lam[:, i].reshape((field.num_residues,) + (1,) * (shares.ndim - 2))
            acc = fadd(acc, fmul(shares[i], li, field), field)
        return acc

    # -- pytree convenience ---------------------------------------------------
    def share_pytree(self, key: jax.Array, tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        shared = [self.share(k, leaf) for k, leaf in zip(keys, leaves)]
        return jax.tree_util.tree_unflatten(treedef, shared)

    def reconstruct_pytree(self, tree, points: Sequence[int] | None = None):
        return jax.tree_util.tree_map(
            lambda s: self.reconstruct(s, points), tree
        )
