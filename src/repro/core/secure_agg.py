"""Secure aggregation primitives (paper Algorithm 2 + mult-by-public-const).

The homomorphism that makes the paper's protocol cheap: if A and B are
secret-shared with the *same* evaluation points, then share-wise addition
yields valid shares of A+B (Algorithm 2), and share-wise multiplication by a
public constant c yields valid shares of c*A.  Aggregating S institutions'
summaries therefore costs S-1 uint64 adds per share — no interaction between
Computation Centers until the final (aggregate-only) reconstruction.

Two deployment styles:

* **Host-side protocol** (paper-faithful simulation, `SecureAggregator`):
  explicit share tensors (w, R, ...) flow institution -> centers -> reveal.
* **In-SPMD** (`secure_psum`): inside a pjit/shard_map program, each pod
  (institution) encodes + shares its local aggregate, a `psum` over the pod
  axis performs Algorithm 2 across institutions *share-wise in the field*,
  and only the global sum is reconstructed.  This is the drop-in replacement
  for a plain gradient all-reduce used by `--secure-agg shamir` training.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .field import FieldSpec, FIELD_WIDE, fadd, fmul
from .fixed_point import FixedPointCodec
from .shamir import ShamirScheme

__all__ = [
    "secure_add",
    "secure_scale_by_public",
    "SecureAggregator",
    "secure_psum",
]


def secure_add(a, b, field: FieldSpec, residue_axis: int = 0):
    """Algorithm 2: share-wise addition (valid for share tensors or trees).

    ``residue_axis`` is 0 for single-holder slices (R, ...) and 1 for full
    share stacks (w, R, ...).
    """
    return jax.tree_util.tree_map(
        lambda x, y: fadd(x, y, field, residue_axis), a, b
    )


def secure_scale_by_public(shares, const_field: jnp.ndarray, field: FieldSpec,
                           residue_axis: int = 0):
    """Multiply a secret (in shares) by a public field constant."""
    return jax.tree_util.tree_map(
        lambda s: fmul(s, const_field, field, residue_axis), shares
    )


@dataclasses.dataclass(frozen=True)
class SecureAggregator:
    """End-to-end protect -> aggregate -> reveal pipeline for float pytrees."""

    scheme: ShamirScheme = ShamirScheme()
    codec: FixedPointCodec = FixedPointCodec()

    def __post_init__(self):
        if self.scheme.field is not self.codec.field and (
            self.scheme.field.moduli != self.codec.field.moduli
        ):
            raise ValueError("scheme and codec must agree on the field")

    # institution side --------------------------------------------------------
    def protect(self, key: jax.Array, tree):
        """Encode floats to the field and split into shares (w, R, ...)."""
        encoded = jax.tree_util.tree_map(self.codec.encode, tree)
        return self.scheme.share_pytree(key, encoded)

    # computation-center side -------------------------------------------------
    def aggregate(self, protected: Sequence):
        """Share-wise sum over institutions (still protected)."""
        if not protected:
            raise ValueError("nothing to aggregate")
        acc = protected[0]
        for p in protected[1:]:
            acc = secure_add(acc, p, self.scheme.field, residue_axis=1)
        return acc

    def reveal(self, protected, points=None, dtype=jnp.float64):
        """Joint reconstruction of the (aggregate) secret -> floats.

        In deployment this is the only step that requires >= t centers to
        cooperate, and it is only ever invoked on *global* aggregates.
        """
        recon = self.scheme.reconstruct_pytree(protected, points)
        return jax.tree_util.tree_map(
            lambda v: self.codec.decode(v, dtype=dtype), recon
        )

    def headroom_ok(self, max_abs: float, num_institutions: int) -> bool:
        """True if S summaries of magnitude <= max_abs aggregate exactly."""
        return max_abs * num_institutions < self.codec.capacity()


def secure_psum(tree, axis_name: str, key: jax.Array,
                aggregator: SecureAggregator | None = None,
                dtype=jnp.float32):
    """Secret-shared all-reduce over a mesh axis (SPMD Algorithm 1, 11-13).

    Per device: fixed-point-encode local float tree, Shamir-share it (fresh
    randomness per device via axis-index key folding), `psum` the share
    tensors over ``axis_name`` — which IS Algorithm 2 executed by the w
    virtual Computation Centers — then reconstruct + decode the global sum.

    The reconstruction here happens on every device for programming-model
    convenience; cryptographically the shares are still only ever *combined*
    (never individually revealed) before the aggregate reconstruction, which
    matches the paper's trust model where centers jointly reveal aggregates.
    """
    agg = aggregator or SecureAggregator()
    idx = jax.lax.axis_index(axis_name)
    key = jax.random.fold_in(key, idx)
    protected = agg.protect(key, tree)

    def field_psum(shares):
        # uint64 psum is exact; reduce mod p afterwards (S * p < 2**64 for
        # any realistic institution count, guard: S < 2**31).
        summed = jax.lax.psum(shares, axis_name)
        p = agg.scheme.field.moduli_array().reshape(
            (1, agg.scheme.field.num_residues) + (1,) * (shares.ndim - 2)
        )
        return summed % p

    aggregated = jax.tree_util.tree_map(field_psum, protected)
    return agg.reveal(aggregated, dtype=dtype)
