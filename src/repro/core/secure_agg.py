"""Secure aggregation primitives (paper Algorithm 2 + mult-by-public-const).

The homomorphism that makes the paper's protocol cheap: if A and B are
secret-shared with the *same* evaluation points, then share-wise addition
yields valid shares of A+B (Algorithm 2), and share-wise multiplication by a
public constant c yields valid shares of c*A.  Aggregating S institutions'
summaries therefore costs one field reduction over the S axis — no
interaction between Computation Centers until the final (aggregate-only)
reconstruction.

Two deployment styles:

* **Host-side protocol** (paper-faithful simulation, `SecureAggregator`):
  explicit share tensors (w, R, ...) flow institution -> centers -> reveal.
* **In-SPMD** (`secure_psum`): inside a pjit/shard_map program, each pod
  (institution) encodes + shares its local aggregate, a `psum` over the pod
  axis performs Algorithm 2 across institutions *share-wise in the field*,
  and only the global sum is reconstructed.  This is the drop-in replacement
  for a plain gradient all-reduce used by `--secure-agg shamir` training.

Backends and the flat-buffer hot path
-------------------------------------
``SecureAggregator(backend="reference")`` walks the summary pytree leaf by
leaf through the uint64 jnp oracle — one dispatch per leaf per field op.

``backend="pallas"`` runs the fused pipeline: the float pytree is packed
into ONE contiguous (rows, 128) tile buffer (`flatbuf.pack_pytree` — pad
once, remember the layout), so each phase is a single kernel launch
regardless of leaf count:

* ``protect``  — fused fixed-point encode + Horner share evaluation
  (`kernels.shamir_poly.shamir_encode_share_pallas`); the intermediate
  uint64 encoded tensor never materializes.  Returns a `FlatProtected`.
* ``aggregate`` — a streaming uint64 accumulator over the S submissions
  (exact sum, one trailing mod): no (S, ...) stack is ever allocated.
* ``reveal``   — fused Lagrange reconstruction + CRT Garner digit
  (`kernels.shamir_reconstruct`), then unpack back to the original pytree.

Share slices travel as uint32 (half the bytes of the reference uint64
path).  `FlatProtected` is a registered pytree whose only leaf is the
share buffer, so protocol code can slice/stack it with ``tree_map``
exactly like a plain share pytree.  All three phases are jitted with the
layout/scheme as static arguments.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .field import (
    FieldSpec,
    FIELD_WIDE,
    fadd,
    fmul,
    fsum,
    random_elements_fast,
)
from .fixed_point import FixedPointCodec
from .flatbuf import (
    FlatLayout,
    LANES,
    pack_pytree,
    pack_pytree_batched,
    unpack_pytree,
)
from .shamir import ShamirScheme

__all__ = [
    "secure_add",
    "secure_scale_by_public",
    "FlatProtected",
    "SecureAggregator",
    "secure_psum",
]


def secure_add(a, b, field: FieldSpec, residue_axis: int = 0):
    """Algorithm 2: share-wise addition (valid for share tensors or trees).

    ``residue_axis`` is 0 for single-holder slices (R, ...) and 1 for full
    share stacks (w, R, ...).
    """
    return jax.tree_util.tree_map(
        lambda x, y: fadd(x, y, field, residue_axis), a, b
    )


def secure_scale_by_public(shares, const_field: jnp.ndarray, field: FieldSpec,
                           residue_axis: int = 0):
    """Multiply a secret (in shares) by a public field constant."""
    return jax.tree_util.tree_map(
        lambda s: fmul(s, const_field, field, residue_axis), shares
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatProtected:
    """Protected flat-buffer representation: one uint32 share tensor.

    ``buf`` is (w, R, rows, 128) fresh from ``protect`` (holder axis
    leading), (R, rows, 128) after per-center slicing, or (k, R, rows, 128)
    once >= t centers stack their aggregate slices for reveal.  ``layout``
    (static aux data) remembers how to unpack the revealed buffer back into
    the original pytree.  Registered as a pytree so protocol-level
    ``tree_map`` slicing/stacking works transparently.
    """

    buf: jnp.ndarray
    layout: FlatLayout

    def tree_flatten(self):
        return (self.buf,), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], layout)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fsum_batched(stacked, field: FieldSpec, residue_axis: int):
    """Jitted S-way field reduction (cast + sum + mod fused by XLA)."""
    return fsum(stacked, field, axis=0, residue_axis=residue_axis)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fold_sum_streaming(submissions, field: FieldSpec, residue_axis: int):
    """Share-wise sum of S submissions WITHOUT materializing an S-stack.

    A running uint64 accumulator folds the submissions one by one (exact:
    S reduced elements sum below 2**64 for any S < 2**33) with a single
    mod at the end.  XLA fuses the unrolled chain into one elementwise
    loop over donation-sized buffers, so peak memory is one accumulator —
    not the (S, ...) stack the eager ``jnp.stack`` reduction allocated,
    which at 1e6+ params made ``aggregate`` allocation-bound.
    """
    acc = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.uint64), submissions[0]
    )
    for nxt in submissions[1:]:
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.uint64), acc, nxt
        )

    def _reduce(a, orig):
        p = field._bcast(a, residue_axis)
        return (a % p).astype(orig.dtype)

    return jax.tree_util.tree_map(_reduce, acc, submissions[0])


@functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "rows")
)
def _protect_flat(key, buf, scheme: ShamirScheme, frac_bits: int, rows: int):
    from ..kernels import ops

    field = scheme.field
    coeffs = random_elements_fast(
        key, (scheme.threshold - 1, rows, LANES), field
    ).astype(jnp.uint32)  # (R, t-1, rows, 128)
    return ops.shamir_protect_flat(
        buf, coeffs, scheme.num_shares, field.moduli, frac_bits,
        interpret=scheme.interpret,
    )  # (w, R, rows, 128) uint32


@functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "points")
)
def _reveal_flat(buf, scheme: ShamirScheme, frac_bits: int,
                 points: tuple[int, ...]):
    from ..kernels import ops

    return ops.shamir_reveal_flat(
        buf, points, scheme.field.moduli, frac_bits,
        interpret=scheme.interpret,
    )  # (rows, 128) float64


@dataclasses.dataclass(frozen=True)
class SecureAggregator:
    """End-to-end protect -> aggregate -> reveal pipeline for float pytrees.

    ``backend=None`` inherits the scheme's backend; passing "pallas" or
    "reference" overrides the scheme to match (convenience so callers can
    write ``SecureAggregator(backend="pallas")``).
    """

    scheme: ShamirScheme = ShamirScheme()
    codec: FixedPointCodec = FixedPointCodec()
    backend: str | None = None

    def __post_init__(self):
        if self.backend is None:
            object.__setattr__(self, "backend", self.scheme.backend)
        elif self.backend != self.scheme.backend:
            object.__setattr__(
                self, "scheme",
                dataclasses.replace(self.scheme, backend=self.backend),
            )
        if self.scheme.field is not self.codec.field and (
            self.scheme.field.moduli != self.codec.field.moduli
        ):
            raise ValueError("scheme and codec must agree on the field")

    # institution side --------------------------------------------------------
    def protect(self, key: jax.Array, tree):
        """Encode floats to the field and split into shares.

        Reference backend: per-leaf share pytree of (w, R, ...) uint64.
        Pallas backend: a single ``FlatProtected`` share buffer.
        """
        if self.backend == "pallas":
            buf, layout = pack_pytree(tree)
            shares = _protect_flat(
                key, buf, self.scheme, self.codec.frac_bits, layout.rows
            )
            return FlatProtected(shares, layout)
        encoded = jax.tree_util.tree_map(self.codec.encode, tree)
        return self.scheme.share_pytree(key, encoded)

    def protect_batched(self, key: jax.Array, tree):
        """Protect S institutions' summaries in ONE kernel launch.

        ``tree`` leaves carry a leading S (institution) axis; the S flat
        slices are packed side by side and pushed through a single
        encode+share launch.  Returns a ``FlatProtected`` whose buffer is
        (w, R, S, rows, 128) — feed it to ``aggregate_batched`` to reduce
        the S axis (the layout describes one slice, i.e. the aggregate).
        Pallas backend only: the batched layout IS the flat wire format.
        """
        if self.backend != "pallas":
            raise ValueError("protect_batched requires the pallas backend")
        buf, layout = pack_pytree_batched(tree)
        s_dim, rows = buf.shape[0], layout.rows
        shares = _protect_flat(
            key, buf.reshape(s_dim * rows, LANES), self.scheme,
            self.codec.frac_bits, s_dim * rows,
        )  # (w, R, S*rows, 128)
        w, num_r = shares.shape[0], shares.shape[1]
        return FlatProtected(
            shares.reshape(w, num_r, s_dim, rows, LANES), layout
        )

    # computation-center side -------------------------------------------------
    def aggregate(self, protected: Sequence):
        """Share-wise sum over institutions (still protected).

        Streams a running uint64 accumulator over the S submissions (one
        fused elementwise chain, single mod) instead of stacking them: at
        1e6+ params the old eager ``jnp.stack`` made this phase
        allocation-bound on the (S, w, R, ...) stack.
        """
        if not protected:
            raise ValueError("nothing to aggregate")
        if len(protected) == 1:
            return protected[0]
        field = self.scheme.field
        # leaves are (w, R, ...) protect outputs: residue axis 1 (same
        # contract as secure_add)
        return _fold_sum_streaming(tuple(protected), field, residue_axis=1)

    def aggregate_batched(self, protected: FlatProtected) -> FlatProtected:
        """Reduce the institution axis of a ``protect_batched`` output.

        One exact uint64 reduction over axis 2 of the (w, R, S, rows, 128)
        share buffer — Algorithm 2 for all S submissions in a single
        dispatch, with no per-submission stacking step.
        """
        buf = fsum(protected.buf, self.scheme.field, axis=2, residue_axis=1)
        return FlatProtected(buf, protected.layout)

    def _validated_points(self, points) -> tuple[int, ...]:
        """Normalize + sanity-check reveal points (1-based, distinct)."""
        w = self.scheme.num_shares
        if points is None:
            points = tuple(range(1, self.scheme.threshold + 1))
        points = tuple(int(p) for p in points)
        if any(not (1 <= p <= w) for p in points):
            raise ValueError(f"points must be in 1..{w}, got {points}")
        if len(set(points)) != len(points):
            raise ValueError(f"points must be distinct, got {points}")
        return points

    def secure_round_batched(self, key: jax.Array, tree,
                             points: Sequence[int] | None = None,
                             dtype=jnp.float64):
        """One whole Algorithm-1+2 round over S-leading summaries.

        protect_batched (ONE encode+share launch) -> aggregate_batched
        (single exact uint64 reduction over the institution axis) ->
        reveal of the *global* aggregate from the ``points`` centers'
        slices.  ``points`` are the 1-based evaluation points of the
        centers participating in the reveal (default: the first t); a
        short list raises the below-threshold error from ``reveal``, so a
        caller that lost too many centers fails loudly instead of
        reducing over a short share axis.  Fully traceable — this is the
        round helper both the fused ``secure_fit`` iteration and the
        fused ``StudyCoordinator.step`` run inside one jitted graph.
        """
        points = self._validated_points(points)
        prot = self.protect_batched(key, tree)
        aggd = self.aggregate_batched(prot)
        sel = jnp.asarray([p - 1 for p in points])
        return self.reveal(
            FlatProtected(aggd.buf[sel], aggd.layout), points=points,
            dtype=dtype,
        )

    def secure_round_multiconfig(self, key: jax.Array, tree,
                                 points: Sequence[int] | None = None,
                                 dtype=jnp.float64):
        """One secure round over a (C, S, ...)-leading summary tree.

        The selection sweep's wire shape: every leaf carries a leading
        (config, institution) pair of axes — C = (lambda x fold) path
        points advancing together, S institutions each submitting one
        summary slice per config.  The whole round is still three
        launches total, independent of C:

        * ONE encode+share launch over the (C * S) flat slices
          (``protect_batched`` on the collapsed leading axis),
        * ONE exact uint64 reduction over the institution axis — the
          share buffer reshapes to (w, R, C, S, rows, 128) and Algorithm
          2 runs per config along axis 3,
        * ONE Lagrange+CRT reveal over the (C * rows, 128) stack of
          per-config aggregates, unpacked back to (C, ...)-leading
          leaves.

        Per-institution validation scores therefore never exist in the
        clear anywhere: held-out metrics enter as shares and only their
        cross-institution sums are reconstructed, per config.  Fully
        traceable; this runs inside the selection scan's jitted graph.
        """
        points = self._validated_points(points)
        if len(points) < self.scheme.threshold:
            raise ValueError(
                f"need >= t={self.scheme.threshold} shares, got "
                f"{len(points)} (information-theoretically irrecoverable "
                "below threshold)"
            )
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("cannot run a round on an empty pytree")
        c_dim, s_dim = leaves[0].shape[0], leaves[0].shape[1]
        if any(l.shape[:2] != (c_dim, s_dim) for l in leaves):
            raise ValueError(
                "all leaves need the same leading (config, institution) axes"
            )
        flat_tree = jax.tree_util.tree_unflatten(
            treedef,
            [l.reshape((c_dim * s_dim,) + l.shape[2:]) for l in leaves],
        )
        prot = self.protect_batched(key, flat_tree)
        w, num_r, _, rows, lanes = prot.buf.shape
        by_config = prot.buf.reshape(w, num_r, c_dim, s_dim, rows, lanes)
        # Algorithm 2 per config: exact uint64 reduction over institutions
        aggd = fsum(by_config, self.scheme.field, axis=3, residue_axis=1)
        sel = jnp.asarray([p - 1 for p in points])
        stacked = aggd[sel].reshape(len(points), num_r, c_dim * rows, lanes)
        flat = _reveal_flat(
            stacked, self.scheme, self.codec.frac_bits, points
        )  # (C * rows, 128) float64
        from .flatbuf import unpack_pytree_batched

        return unpack_pytree_batched(
            flat.reshape(c_dim, rows, lanes), prot.layout, dtype=dtype
        )

    def reveal(self, protected, points=None, dtype=jnp.float64):
        """Joint reconstruction of the (aggregate) secret -> floats.

        In deployment this is the only step that requires >= t centers to
        cooperate, and it is only ever invoked on *global* aggregates.
        """
        if isinstance(protected, FlatProtected):
            k = protected.buf.shape[0]
            pts = tuple(points) if points is not None else tuple(
                range(1, k + 1)
            )
            if len(pts) != k:
                raise ValueError("points must match share count")
            if k < self.scheme.threshold:
                raise ValueError(
                    f"need >= t={self.scheme.threshold} shares, got {k} "
                    "(information-theoretically irrecoverable below "
                    "threshold)"
                )
            flat = _reveal_flat(
                protected.buf, self.scheme, self.codec.frac_bits, pts
            )
            return unpack_pytree(flat, protected.layout, dtype=dtype)
        recon = self.scheme.reconstruct_pytree(protected, points)
        return jax.tree_util.tree_map(
            lambda v: self.codec.decode(v, dtype=dtype), recon
        )

    def headroom_ok(self, max_abs: float, num_institutions: int) -> bool:
        """True if S summaries of magnitude <= max_abs aggregate exactly."""
        return max_abs * num_institutions < self.codec.capacity()


def secure_psum(tree, axis_name: str, key: jax.Array,
                aggregator: SecureAggregator | None = None,
                dtype=jnp.float32):
    """Secret-shared all-reduce over a mesh axis (SPMD Algorithm 1, 11-13).

    Per device: fixed-point-encode local float tree, Shamir-share it (fresh
    randomness per device via axis-index key folding), `psum` the share
    tensors over ``axis_name`` — which IS Algorithm 2 executed by the w
    virtual Computation Centers — then reconstruct + decode the global sum.

    The reconstruction here happens on every device for programming-model
    convenience; cryptographically the shares are still only ever *combined*
    (never individually revealed) before the aggregate reconstruction, which
    matches the paper's trust model where centers jointly reveal aggregates.
    """
    agg = aggregator or SecureAggregator()
    idx = jax.lax.axis_index(axis_name)
    key = jax.random.fold_in(key, idx)
    protected = agg.protect(key, tree)

    def field_psum(shares):
        # uint64 psum is exact; reduce mod p afterwards (S * p < 2**64 for
        # any realistic institution count, guard: S < 2**31).
        summed = jax.lax.psum(shares.astype(jnp.uint64), axis_name)
        p = agg.scheme.field.moduli_array().reshape(
            (1, agg.scheme.field.num_residues) + (1,) * (shares.ndim - 2)
        )
        return (summed % p).astype(shares.dtype)

    aggregated = jax.tree_util.tree_map(field_psum, protected)
    return agg.reveal(aggregated, dtype=dtype)
