"""Secure aggregation: compatibility surface over :mod:`repro.core.collective`.

The full pack -> protect -> aggregate -> reveal -> unpack chain — the
four named declassification boundaries, the flat-buffer wire, the
in-SPMD psum paths and the byte telemetry — lives ONCE in
:mod:`repro.core.collective` (:class:`SecureCollective`).  This module
keeps the historical import surface working (``SecureAggregator`` is an
alias of ``SecureCollective``) and houses the two share-algebra
helpers that sit *outside* the chain:

* :func:`secure_add` — Algorithm 2's share-wise addition, valid for any
  share tensors or trees that used the same evaluation points;
* :func:`secure_scale_by_public` — share-wise multiplication by a
  public field constant.

The homomorphism that makes the paper's protocol cheap: if A and B are
secret-shared with the *same* evaluation points, then share-wise addition
yields valid shares of A+B (Algorithm 2), and share-wise multiplication
by a public constant c yields valid shares of c*A.  Aggregating S
institutions' summaries therefore costs one field reduction over the S
axis — no interaction between Computation Centers until the final
(aggregate-only) reconstruction.

See the :mod:`repro.core.collective` module docstring for the backend
story (reference per-leaf oracle vs the fused pallas flat-buffer path)
and the one-chain audit contract.
"""
from __future__ import annotations

import jax

from .collective import (  # noqa: F401  (compatibility re-exports)
    FlatProtected,
    OUT_MODES,
    REVEAL_MODES,
    SecureCollective,
    ShardedAggregate,
    _declassify_sum_impl,
    _declassify_sum_jit,
    _field_allreduce,
    _fold_sum_streaming,
    _fsum_batched,
    _protect_flat,
    _protect_flat_impl,
    _protect_flat_jit,
    _reveal_flat,
    _reveal_flat_impl,
    _reveal_flat_jit,
    _secure_psum_per_leaf,
    check_aggregation_headroom,
    declassify_sum,
    secure_psum,
)
from .field import FieldSpec, fadd, fmul

__all__ = [
    "secure_add",
    "secure_scale_by_public",
    "check_aggregation_headroom",
    "declassify_sum",
    "FlatProtected",
    "SecureAggregator",
    "ShardedAggregate",
    "secure_psum",
    "REVEAL_MODES",
    "OUT_MODES",
]

# the historical name; every constructor site keeps working and shares
# one jit key-space with SecureCollective (same class, not a subclass)
SecureAggregator = SecureCollective


def secure_add(a, b, field: FieldSpec, residue_axis: int = 0):
    """Algorithm 2: share-wise addition (valid for share tensors or trees).

    ``residue_axis`` is 0 for single-holder slices (R, ...) and 1 for full
    share stacks (w, R, ...).
    """
    return jax.tree_util.tree_map(
        lambda x, y: fadd(x, y, field, residue_axis), a, b
    )


def secure_scale_by_public(shares, const_field, field: FieldSpec,
                           residue_axis: int = 0):
    """Multiply a secret (in shares) by a public field constant."""
    return jax.tree_util.tree_map(
        lambda s: fmul(s, const_field, field, residue_axis), shares
    )
