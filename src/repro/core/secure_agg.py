"""Secure aggregation primitives (paper Algorithm 2 + mult-by-public-const).

The homomorphism that makes the paper's protocol cheap: if A and B are
secret-shared with the *same* evaluation points, then share-wise addition
yields valid shares of A+B (Algorithm 2), and share-wise multiplication by a
public constant c yields valid shares of c*A.  Aggregating S institutions'
summaries therefore costs one field reduction over the S axis — no
interaction between Computation Centers until the final (aggregate-only)
reconstruction.

Two deployment styles:

* **Host-side protocol** (paper-faithful simulation, `SecureAggregator`):
  explicit share tensors (w, R, ...) flow institution -> centers -> reveal.
* **In-SPMD** (`secure_psum`): inside a pjit/shard_map program, each pod
  (institution) packs its local float tree into ONE flat (rows, 128) tile
  buffer, pushes it through the fused encode+share kernel, and all-reduces
  a single uint32 share buffer over the pod axis — Algorithm 2 executed
  share-wise in the field.  Only the *threshold subset* of share slices is
  ever evaluated or transmitted (t of w, at half the element width of the
  old per-leaf uint64 tree), and only the global sum is revealed.  This is
  the drop-in replacement for a plain gradient all-reduce used by
  ``--secure-agg shamir`` training.  Two reveal modes:

  - ``reveal="replicated"`` (default): the t-slice buffer is `psum`-ed
    whole and every device runs the fused Lagrange+CRT reveal on its copy
    (programming-model convenience, matches the old behavior).
  - ``reveal="sharded"``: the share buffer is reduce-scattered over the
    pod axis, so each device only ever holds — and the wire only ever
    moves — a 1/D row-slice of the distributed residues; each device
    reveals its slice and a final all-gather assembles the decoded float
    aggregate.  Roughly halves the all-reduce payload again (the gathered
    plaintext aggregate is far smaller than the share buffer).

  The reference per-leaf path (``aggregator.backend == "reference"``)
  remains available as the bit-exactness oracle; tests parametrize over
  both like the protect/reveal backend switches.

Backends and the flat-buffer hot path
-------------------------------------
``SecureAggregator(backend="reference")`` walks the summary pytree leaf by
leaf through the uint64 jnp oracle — one dispatch per leaf per field op.

``backend="pallas"`` runs the fused pipeline: the float pytree is packed
into ONE contiguous (rows, 128) tile buffer (`flatbuf.pack_pytree` — pad
once, remember the layout), so each phase is a single kernel launch
regardless of leaf count:

* ``protect``  — fused fixed-point encode + Horner share evaluation
  (`kernels.shamir_poly.shamir_encode_share_pallas`); the intermediate
  uint64 encoded tensor never materializes.  Returns a `FlatProtected`.
* ``aggregate`` — a streaming uint64 accumulator over the S submissions
  (exact sum, one trailing mod): no (S, ...) stack is ever allocated.
* ``reveal``   — fused Lagrange reconstruction + CRT Garner digit
  (`kernels.shamir_reconstruct`), then unpack back to the original pytree.

Share slices travel as uint32 (half the bytes of the reference uint64
path).  `FlatProtected` is a registered pytree whose only leaf is the
share buffer, so protocol code can slice/stack it with ``tree_map``
exactly like a plain share pytree.  All three phases are jitted with the
layout/scheme as static arguments.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from ..distributed.compat import axis_size as _compat_axis_size
from ..obs import ledger as _ledger
from ..obs.trace import traced as _traced
from .field import (
    FieldSpec,
    FIELD_WIDE,
    fadd,
    fmul,
    fsum,
    random_elements_fast,
)
from .fixed_point import FixedPointCodec
from .flatbuf import (
    FlatLayout,
    LANES,
    ROW_ALIGN,
    pack_pytree,
    pack_pytree_batched,
    unpack_pytree,
    unpack_pytree_tile,
)
from .shamir import ShamirScheme

__all__ = [
    "secure_add",
    "secure_scale_by_public",
    "check_aggregation_headroom",
    "declassify_sum",
    "FlatProtected",
    "SecureAggregator",
    "ShardedAggregate",
    "secure_psum",
    "REVEAL_MODES",
    "OUT_MODES",
]

REVEAL_MODES = ("replicated", "sharded")
OUT_MODES = ("tree", "tile")


def check_aggregation_headroom(num_addends: int, field: FieldSpec) -> None:
    """Guard the exact-uint64 share sum: ``S * max(p_r) < 2**64``.

    Every aggregation path (streaming fold, batched reduction, in-SPMD
    psum) accumulates reduced share elements (< p_r) in uint64 and applies
    ONE trailing mod, which is exact iff the unreduced sum cannot wrap.
    This is the single shared bound — ~2**33 institutions for the 31-bit
    moduli — enforced here so no path carries its own (historically
    inconsistent) claim.
    """
    if num_addends * max(field.moduli) >= 2**64:
        raise ValueError(
            f"cannot aggregate {num_addends} share tensors exactly: "
            f"{num_addends} * max modulus {max(field.moduli)} >= 2**64 "
            "would overflow the uint64 accumulator before the trailing mod"
        )


def _declassify_sum_impl(x, axis: int = 0):
    return jnp.sum(x, axis=axis)


# the pjit equation must be NAMED declassify_sum — that exact name is the
# key the static taint verifier's declassification rules match on
_declassify_sum_impl.__name__ = "declassify_sum"
_declassify_sum_impl.__qualname__ = "declassify_sum"
_declassify_sum_jit = functools.partial(
    jax.jit, static_argnames=("axis",)
)(_declassify_sum_impl)


def declassify_sum(x, axis: int = 0):
    """The sanctioned PLAINTEXT aggregation over the institution axis.

    Semantically just ``jnp.sum(x, axis=axis)`` — but spelled as a named
    jitted boundary so the static privacy-flow verifier
    (:mod:`repro.analysis`) can certify it.  The paper's pragmatic
    protect modes ("gradient" / "hessian" / "none") deliberately exchange
    SOME summaries in the clear; the protocol contract is that only
    their *cross-institution sums* ever leave the round.  Every driver
    spells those sums through this function, which the taint verifier
    treats as the one annotated SECRET -> PUBLIC declassification for
    unprotected leaves (it still checks the reduction actually
    aggregates >= 2 addends, so a non-reducing "sum" cannot launder an
    individual institution's summary).  A plain ``jnp.sum`` on secret
    data fails the gate — which is the point: intentional plaintext
    aggregation must be visible and auditable.

    The runtime privacy-audit ledger (:mod:`repro.obs.ledger`) counts
    every *Python-level invocation* of this boundary: the hook lives in
    this host wrapper, outside the jitted body, so a host-level call
    records once per call (per round in the loop drivers) and a call
    inside an enclosing ``jit`` records once per call site each time
    the enclosing graph is traced.  Cached dispatches of an already
    certified graph add no new declassification sites by construction —
    ``python -m repro.obs audit`` reconciles the recorded counts against
    a per-equation census of each driver spec's graph.  The hook records
    static metadata only (shape/axis), never values, and adds no
    equation to the graph.
    """
    _ledger.record_site("declassify_sum", what=f"axis{axis}_sum",
                        shape=x.shape)
    return _declassify_sum_jit(x, axis=axis)


def secure_add(a, b, field: FieldSpec, residue_axis: int = 0):
    """Algorithm 2: share-wise addition (valid for share tensors or trees).

    ``residue_axis`` is 0 for single-holder slices (R, ...) and 1 for full
    share stacks (w, R, ...).
    """
    return jax.tree_util.tree_map(
        lambda x, y: fadd(x, y, field, residue_axis), a, b
    )


def secure_scale_by_public(shares, const_field: jnp.ndarray, field: FieldSpec,
                           residue_axis: int = 0):
    """Multiply a secret (in shares) by a public field constant."""
    return jax.tree_util.tree_map(
        lambda s: fmul(s, const_field, field, residue_axis), shares
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatProtected:
    """Protected flat-buffer representation: one uint32 share tensor.

    ``buf`` is (w, R, rows, 128) fresh from ``protect`` (holder axis
    leading), (R, rows, 128) after per-center slicing, or (k, R, rows, 128)
    once >= t centers stack their aggregate slices for reveal.  ``layout``
    (static aux data) remembers how to unpack the revealed buffer back into
    the original pytree.  Registered as a pytree so protocol-level
    ``tree_map`` slicing/stacking works transparently.
    """

    buf: jnp.ndarray
    layout: FlatLayout

    def tree_flatten(self):
        return (self.buf,), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], layout)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fsum_batched(stacked, field: FieldSpec, residue_axis: int):
    """Jitted S-way field reduction (cast + sum + mod fused by XLA)."""
    return fsum(stacked, field, axis=0, residue_axis=residue_axis)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fold_sum_streaming(submissions, field: FieldSpec, residue_axis: int):
    """Share-wise sum of S submissions WITHOUT materializing an S-stack.

    A running uint64 accumulator folds the submissions one by one with a
    single mod at the end — exact iff ``S * max(p_r) < 2**64``, the shared
    bound ``check_aggregation_headroom`` enforces on every caller.  XLA
    fuses the unrolled chain into one elementwise loop over donation-sized
    buffers, so peak memory is one accumulator — not the (S, ...) stack
    the eager ``jnp.stack`` reduction allocated, which at 1e6+ params made
    ``aggregate`` allocation-bound.
    """
    acc = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.uint64), submissions[0]
    )
    for nxt in submissions[1:]:
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.uint64), acc, nxt
        )

    def _reduce(a, orig):
        p = field._bcast(a, residue_axis)
        return (a % p).astype(orig.dtype)

    return jax.tree_util.tree_map(_reduce, acc, submissions[0])


def _protect_flat_impl(key, buf, scheme: ShamirScheme, frac_bits: int,
                       rows: int, points: tuple[int, ...] | None = None):
    from ..kernels import ops

    field = scheme.field
    coeffs = random_elements_fast(
        key, (scheme.threshold - 1, rows, LANES), field
    ).astype(jnp.uint32)  # (R, t-1, rows, 128)
    return ops.shamir_protect_flat(
        buf, coeffs, scheme.num_shares, field.moduli, frac_bits,
        interpret=scheme.interpret, points=points,
    )  # (len(points) or w, R, rows, 128) uint32


# keep the pjit names the taint verifier's declassification rules key on
_protect_flat_impl.__name__ = "_protect_flat"
_protect_flat_impl.__qualname__ = "_protect_flat"
_protect_flat_jit = functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "rows", "points")
)(_protect_flat_impl)


def _protect_flat(key, buf, scheme: ShamirScheme, frac_bits: int, rows: int,
                  points: tuple[int, ...] | None = None):
    """Host wrapper: ledger hook + the jitted protect boundary.

    The audit ledger records per Python-level invocation (see
    :func:`declassify_sum` for the counting semantics).
    """
    _ledger.record_site("_protect_flat", what="encode+share",
                        shape=buf.shape, threshold=scheme.threshold)
    return _protect_flat_jit(key, buf, scheme, frac_bits, rows,
                             points=points)


def _reveal_flat_impl(buf, scheme: ShamirScheme, frac_bits: int,
                      points: tuple[int, ...]):
    from ..kernels import ops

    return ops.shamir_reveal_flat(
        buf, points, scheme.field.moduli, frac_bits,
        interpret=scheme.interpret,
    )  # (rows, 128) float64


_reveal_flat_impl.__name__ = "_reveal_flat"
_reveal_flat_impl.__qualname__ = "_reveal_flat"
_reveal_flat_jit = functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "points")
)(_reveal_flat_impl)


def _reveal_flat(buf, scheme: ShamirScheme, frac_bits: int,
                 points: tuple[int, ...]):
    """Host wrapper: ledger hook + the jitted reveal boundary.

    Every reveal — certified in-graph call sites AND any stray
    host-level call — passes through here, so the runtime audit counts
    it even when the jitted impl hits the compilation cache.
    """
    _ledger.record_site("_reveal_flat", what="lagrange_reveal",
                        shape=buf.shape, threshold=scheme.threshold)
    return _reveal_flat_jit(buf, scheme, frac_bits, points)


@dataclasses.dataclass(frozen=True)
class SecureAggregator:
    """End-to-end protect -> aggregate -> reveal pipeline for float pytrees.

    ``backend=None`` inherits the scheme's backend; passing "pallas" or
    "reference" overrides the scheme to match (convenience so callers can
    write ``SecureAggregator(backend="pallas")``).

    ``overflow_check=True`` arms the debug-mode fixed-point overflow
    assert on every protect path: a value past the capacity bound raises
    ``OverflowError`` (eagerly outside jit, at the next sync inside)
    instead of silently saturating into a plausible-but-wrong reveal —
    the hard-failure form of the ``headroom_ok`` predicate.  Paths that
    know the addend count (``protect_batched`` over S institutions,
    ``secure_psum`` over D devices) tighten the bound to
    ``capacity / S`` so an aggregate that would overflow is caught at
    protect time, not revealed wrong.
    """

    scheme: ShamirScheme = ShamirScheme()
    codec: FixedPointCodec = FixedPointCodec()
    backend: str | None = None
    overflow_check: bool = False

    def __post_init__(self):
        if self.backend is None:
            object.__setattr__(self, "backend", self.scheme.backend)
        elif self.backend != self.scheme.backend:
            object.__setattr__(
                self, "scheme",
                dataclasses.replace(self.scheme, backend=self.backend),
            )
        if self.scheme.field is not self.codec.field and (
            self.scheme.field.moduli != self.codec.field.moduli
        ):
            raise ValueError("scheme and codec must agree on the field")

    # institution side --------------------------------------------------------
    @_traced("protect")
    def protect(self, key: jax.Array, tree):
        """Encode floats to the field and split into shares.

        Reference backend: per-leaf share pytree of (w, R, ...) uint64.
        Pallas backend: a single ``FlatProtected`` share buffer.
        """
        if self.backend == "pallas":
            buf, layout = pack_pytree(tree)
            if self.overflow_check:
                self.codec.check_headroom(buf, what="protect")
            shares = _protect_flat(
                key, buf, self.scheme, self.codec.frac_bits, layout.rows
            )
            return FlatProtected(shares, layout)
        encoded = jax.tree_util.tree_map(
            functools.partial(self.codec.encode, check=self.overflow_check),
            tree,
        )
        return self.scheme.share_pytree(key, encoded)

    @_traced("protect")
    def protect_batched(self, key: jax.Array, tree):
        """Protect S institutions' summaries in ONE kernel launch.

        ``tree`` leaves carry a leading S (institution) axis; the S flat
        slices are packed side by side and pushed through a single
        encode+share launch.  Returns a ``FlatProtected`` whose buffer is
        (w, R, S, rows, 128) — feed it to ``aggregate_batched`` to reduce
        the S axis (the layout describes one slice, i.e. the aggregate).
        Pallas backend only: the batched layout IS the flat wire format.
        """
        if self.backend != "pallas":
            raise ValueError("protect_batched requires the pallas backend")
        buf, layout = pack_pytree_batched(tree)
        if self.overflow_check:
            # the S slices will be summed: bound each by capacity / S so
            # the AGGREGATE cannot overflow (the headroom_ok contract)
            self.codec.check_headroom(
                buf, num_addends=buf.shape[0], what="protect_batched"
            )
        s_dim, rows = buf.shape[0], layout.rows
        shares = _protect_flat(
            key, buf.reshape(s_dim * rows, LANES), self.scheme,
            self.codec.frac_bits, s_dim * rows,
        )  # (w, R, S*rows, 128)
        w, num_r = shares.shape[0], shares.shape[1]
        return FlatProtected(
            shares.reshape(w, num_r, s_dim, rows, LANES), layout
        )

    # computation-center side -------------------------------------------------
    @_traced("aggregate")
    def aggregate(self, protected: Sequence):
        """Share-wise sum over institutions (still protected).

        Streams a running uint64 accumulator over the S submissions (one
        fused elementwise chain, single mod) instead of stacking them: at
        1e6+ params the old eager ``jnp.stack`` made this phase
        allocation-bound on the (S, w, R, ...) stack.
        """
        if not protected:
            raise ValueError("nothing to aggregate")
        if len(protected) == 1:
            return protected[0]
        field = self.scheme.field
        check_aggregation_headroom(len(protected), field)
        # leaves are (w, R, ...) protect outputs: residue axis 1 (same
        # contract as secure_add)
        return _fold_sum_streaming(tuple(protected), field, residue_axis=1)

    @_traced("aggregate")
    def aggregate_batched(self, protected: FlatProtected) -> FlatProtected:
        """Reduce the institution axis of a ``protect_batched`` output.

        One exact uint64 reduction over axis 2 of the (w, R, S, rows, 128)
        share buffer — Algorithm 2 for all S submissions in a single
        dispatch, with no per-submission stacking step.
        """
        check_aggregation_headroom(protected.buf.shape[2], self.scheme.field)
        buf = fsum(protected.buf, self.scheme.field, axis=2, residue_axis=1)
        return FlatProtected(buf, protected.layout)

    def _validated_points(self, points) -> tuple[int, ...]:
        """Normalize + sanity-check reveal points (1-based, distinct).

        ``None`` defaults to the first t points — the SAME t-subset
        default every reveal path uses (reconstruction from any t shares
        is exact, so a t-subset reveal is bit-identical to the all-w one
        and does strictly less work).  Below-threshold subsets are
        rejected here, before any reduction over a short share axis.
        """
        w = self.scheme.num_shares
        if points is None:
            points = tuple(range(1, self.scheme.threshold + 1))
        points = tuple(int(p) for p in points)
        if any(not (1 <= p <= w) for p in points):
            raise ValueError(f"points must be in 1..{w}, got {points}")
        if len(set(points)) != len(points):
            raise ValueError(f"points must be distinct, got {points}")
        if len(points) < self.scheme.threshold:
            raise ValueError(
                f"need >= t={self.scheme.threshold} shares, got "
                f"{len(points)} (information-theoretically irrecoverable "
                "below threshold)"
            )
        return points

    @_traced("secure_round")
    def secure_round_batched(self, key: jax.Array, tree,
                             points: Sequence[int] | None = None,
                             dtype=jnp.float64):
        """One whole Algorithm-1+2 round over S-leading summaries.

        protect_batched (ONE encode+share launch) -> aggregate_batched
        (single exact uint64 reduction over the institution axis) ->
        reveal of the *global* aggregate from the ``points`` centers'
        slices.  ``points`` are the 1-based evaluation points of the
        centers participating in the reveal (default: the first t); a
        short list raises the below-threshold error from ``reveal``, so a
        caller that lost too many centers fails loudly instead of
        reducing over a short share axis.  Fully traceable — this is the
        round helper both the fused ``secure_fit`` iteration and the
        fused ``StudyCoordinator.step`` run inside one jitted graph.
        """
        points = self._validated_points(points)
        prot = self.protect_batched(key, tree)
        aggd = self.aggregate_batched(prot)
        sel = jnp.asarray([p - 1 for p in points])
        return self.reveal(
            FlatProtected(aggd.buf[sel], aggd.layout), points=points,
            dtype=dtype,
        )

    @_traced("secure_round")
    def secure_round_multiconfig(self, key: jax.Array, tree,
                                 points: Sequence[int] | None = None,
                                 dtype=jnp.float64):
        """One secure round over a (C, S, ...)-leading summary tree.

        The selection sweep's wire shape: every leaf carries a leading
        (config, institution) pair of axes — C = (lambda x fold) path
        points advancing together, S institutions each submitting one
        summary slice per config.  The whole round is still three
        launches total, independent of C:

        * ONE encode+share launch over the (C * S) flat slices
          (``protect_batched`` on the collapsed leading axis),
        * ONE exact uint64 reduction over the institution axis — the
          share buffer reshapes to (w, R, C, S, rows, 128) and Algorithm
          2 runs per config along axis 3,
        * ONE Lagrange+CRT reveal over the (C * rows, 128) stack of
          per-config aggregates, unpacked back to (C, ...)-leading
          leaves.

        Per-institution validation scores therefore never exist in the
        clear anywhere: held-out metrics enter as shares and only their
        cross-institution sums are reconstructed, per config.  Fully
        traceable; this runs inside the selection scan's jitted graph.
        """
        points = self._validated_points(points)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("cannot run a round on an empty pytree")
        c_dim, s_dim = leaves[0].shape[0], leaves[0].shape[1]
        if any(l.shape[:2] != (c_dim, s_dim) for l in leaves):
            raise ValueError(
                "all leaves need the same leading (config, institution) axes"
            )
        flat_tree = jax.tree_util.tree_unflatten(
            treedef,
            [l.reshape((c_dim * s_dim,) + l.shape[2:]) for l in leaves],
        )
        prot = self.protect_batched(key, flat_tree)
        w, num_r, _, rows, lanes = prot.buf.shape
        by_config = prot.buf.reshape(w, num_r, c_dim, s_dim, rows, lanes)
        # Algorithm 2 per config: exact uint64 reduction over institutions
        check_aggregation_headroom(s_dim, self.scheme.field)
        aggd = fsum(by_config, self.scheme.field, axis=3, residue_axis=1)
        sel = jnp.asarray([p - 1 for p in points])
        stacked = aggd[sel].reshape(len(points), num_r, c_dim * rows, lanes)
        flat = _reveal_flat(
            stacked, self.scheme, self.codec.frac_bits, points
        )  # (C * rows, 128) float64
        from .flatbuf import unpack_pytree_batched

        return unpack_pytree_batched(
            flat.reshape(c_dim, rows, lanes), prot.layout, dtype=dtype
        )

    @_traced("reveal")
    def reveal(self, protected, points=None, dtype=jnp.float64):
        """Joint reconstruction of the (aggregate) secret -> floats.

        In deployment this is the only step that requires >= t centers to
        cooperate, and it is only ever invoked on *global* aggregates.

        ``points=None`` assumes the share slices are in holder order
        (1..k, as ``protect`` emits them) and reconstructs from the first
        t — the unified ``_validated_points`` default on BOTH backends.
        Reconstruction from any t-subset is exact field arithmetic, so the
        result is bit-identical to an all-k reveal at a fraction of the
        Lagrange work.  Pass explicit ``points`` when the slices are a
        non-contiguous center subset (then they must match the slice
        count).
        """
        t = self.scheme.threshold
        if isinstance(protected, FlatProtected):
            k = protected.buf.shape[0]
            if k < t:
                raise ValueError(
                    f"need >= t={t} shares, got {k} "
                    "(information-theoretically irrecoverable below "
                    "threshold)"
                )
            if points is None:
                buf = protected.buf[:t] if k > t else protected.buf
                pts = self._validated_points(None)
            else:
                buf = protected.buf
                pts = self._validated_points(points)
                if len(pts) != k:
                    raise ValueError("points must match share count")
            flat = _reveal_flat(
                buf, self.scheme, self.codec.frac_bits, pts
            )
            return unpack_pytree(flat, protected.layout, dtype=dtype)
        if points is None:
            # same t-subset default as the flat path: slice each leaf's
            # holder axis down to the first t shares before reconstructing
            leaves = jax.tree_util.tree_leaves(protected)
            k = leaves[0].shape[0] if leaves else 0
            if k < t:
                raise ValueError(
                    f"need >= t={t} shares, got {k} "
                    "(information-theoretically irrecoverable below "
                    "threshold)"
                )
            protected = jax.tree_util.tree_map(
                lambda s: s[:t], protected
            )
            points = self._validated_points(None)
        recon = self.scheme.reconstruct_pytree(protected, list(points))
        return jax.tree_util.tree_map(
            lambda v: self.codec.decode(v, dtype=dtype), recon
        )

    def headroom_ok(self, max_abs: float, num_institutions: int) -> bool:
        """True if S summaries of magnitude <= max_abs aggregate exactly."""
        return max_abs * num_institutions < self.codec.capacity()


def _field_allreduce(shares, axis_name: str, field: FieldSpec,
                     residue_axis: int = 1, scatter_axis: int | None = None):
    """Exact share-wise field sum over a mesh axis (Algorithm 2 on the wire).

    The accumulation widens to uint64 so XLA's collective (which has no
    per-hop modular reduction) stays exact — the shared
    ``check_aggregation_headroom`` bound ``S * max(p_r) < 2**64`` — and a
    single trailing mod returns the reduced wire dtype.  A deployment
    fabric doing per-hop modular adds would move the reduced uint32
    elements instead; the payload accounting counts those (see
    ``benchmarks/secure_psum.py``).

    ``scatter_axis=None`` all-reduces (every device gets the full summed
    buffer); an integer reduce-scatters that axis so each device keeps
    only its 1/D tile of the distributed residues.
    """
    summed = jax.lax.psum(shares.astype(jnp.uint64), axis_name) \
        if scatter_axis is None else jax.lax.psum_scatter(
            shares.astype(jnp.uint64), axis_name,
            scatter_dimension=scatter_axis, tiled=True,
        )
    return (summed % field._bcast(summed, residue_axis)).astype(shares.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedAggregate:
    """A revealed aggregate that STAYS sharded over the reduce axis.

    ``secure_psum(reveal="sharded", out="tile")`` hands every device its
    decoded ``(rows / D, 128)`` plaintext tile of the flat aggregate
    buffer instead of all-gathering + unpacking.  Downstream code that
    consumes the aggregate shard-wise (a distributed solve, a sharded
    optimizer update) skips the gather entirely; anything that needs the
    whole tree calls :meth:`gather` — which is exactly what
    ``out="tree"`` would have done, so the two spellings are bit-equal.

    Registered as a pytree with the tile as its only leaf (layout and
    tile count are static aux data), so it crosses ``shard_map`` /
    ``jit`` boundaries like a plain array.
    """

    tile: jnp.ndarray
    layout: FlatLayout
    num_tiles: int

    def gather(self, axis_name: str, dtype=jnp.float32):
        """All-gather the plaintext tiles and unpack the full pytree."""
        flat = jax.lax.all_gather(self.tile, axis_name, axis=0, tiled=True)
        return unpack_pytree(flat, self.layout, dtype=dtype)

    def local_fragments(self, tile_index: int, dtype=None):
        """Leaf fragments in THIS tile (static ``tile_index`` required).

        See :func:`repro.core.flatbuf.unpack_pytree_tile` for the
        ``{leaf: (start, stop, fragment)}`` contract.
        """
        return unpack_pytree_tile(
            self.tile, self.layout, tile_index, self.num_tiles, dtype=dtype
        )

    def tree_flatten(self):
        return (self.tile,), (self.layout, self.num_tiles)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], *aux)


def _secure_psum_per_leaf(tree, axis_name: str, key: jax.Array,
                          agg: SecureAggregator, points: tuple[int, ...],
                          dtype):
    """The original per-leaf uint64 wire: the bit-exactness oracle.

    Protects leaf by leaf through the reference pipeline and all-reduces
    every holder's full (w, R, ...) uint64 share tree — w * R * 8 bytes
    per parameter on the wire, reconstruction on every device.  Kept (and
    parametrized in tests) as the oracle the flat-buffer wire is measured
    against; new code wants the flat path.
    """
    protected = agg.protect(key, tree)
    aggregated = jax.tree_util.tree_map(
        lambda s: _field_allreduce(s, axis_name, agg.scheme.field), protected
    )
    sel = jnp.asarray([p - 1 for p in points])
    subset = jax.tree_util.tree_map(lambda s: s[sel], aggregated)
    return agg.reveal(subset, points=points, dtype=dtype)


@_traced("secure_psum")
def secure_psum(tree, axis_name: str, key: jax.Array,
                aggregator: SecureAggregator | None = None,
                dtype=jnp.float32, reveal: str = "replicated",
                points: Sequence[int] | None = None,
                out: str = "tree"):
    """Secret-shared all-reduce over a mesh axis (SPMD Algorithm 1, 11-13).

    Per device: pack the local float tree into ONE flat (rows, 128) tile
    buffer, push it through the fused fixed-point-encode + Horner-share
    kernel (fresh randomness per device via axis-index key folding), and
    reduce the uint32 share buffer over ``axis_name`` — which IS Algorithm
    2 executed by the virtual Computation Centers — then reveal + decode
    only the global sum via the fused Lagrange+CRT kernel.  Only the
    ``points`` subset of share slices (default: the first t, the unified
    reveal default) is ever evaluated or transmitted, so the wire carries
    a (t, R, rows, 128) uint32 buffer — t/w of the slices at half the
    element width of the per-leaf uint64 tree.

    ``reveal`` selects where the residues live between reduction and
    decode:

    * ``"replicated"`` — one `psum`; every device holds the full summed
      share buffer and reconstructs its own copy of the aggregate
      (programming-model convenience, the pre-sharded behavior).
    * ``"sharded"`` — `psum_scatter` over the rows axis: each device only
      ever holds a 1/D row-tile of the aggregated residues, reveals just
      that tile, and a final all-gather assembles the *decoded* float
      aggregate — the share buffer crosses the wire once instead of
      twice, cutting the all-reduce payload roughly in half (the gathered
      plaintext is ``dtype``-sized, far smaller than the share buffer).

    ``out`` selects the return shape of the sharded reveal:

    * ``"tree"`` (default) — all-gather the decoded tiles and unpack the
      full float pytree on every device (the historical behavior).
    * ``"tile"`` — skip the gather: return a :class:`ShardedAggregate`
      whose ``tile`` leaf is this device's decoded plaintext row-tile.
      ``.gather(axis_name)`` reproduces ``out="tree"`` bit-exactly;
      shard-wise consumers never pay for the assembled tree.

    Passing ``aggregator=SecureAggregator(backend="reference")`` selects
    the original per-leaf uint64 wire (replicated reveal only) — the
    bit-exactness oracle.  Cryptographically, both modes only ever
    *combine* shares (never reveal an individual contribution) before the
    aggregate reconstruction, matching the paper's trust model where
    centers jointly reveal aggregates.
    """
    agg = aggregator or SecureAggregator(backend="pallas")
    if reveal not in REVEAL_MODES:
        raise ValueError(f"reveal must be one of {REVEAL_MODES}")
    if out not in OUT_MODES:
        raise ValueError(f"out must be one of {OUT_MODES}")
    if out == "tile" and reveal != "sharded":
        raise ValueError(
            "out='tile' only makes sense with reveal='sharded' — the "
            "replicated reveal already holds the full aggregate everywhere"
        )
    pts = agg._validated_points(points)
    num_devices = _compat_axis_size(axis_name)
    check_aggregation_headroom(num_devices, agg.scheme.field)
    if agg.overflow_check:
        # every device's contribution is bounded by capacity / D so the
        # D-way field sum cannot overflow (headroom_ok, hard-failure form)
        jax.tree_util.tree_map(
            lambda leaf: agg.codec.check_headroom(
                leaf, num_addends=num_devices, what="secure_psum"
            ),
            tree,
        )
    idx = jax.lax.axis_index(axis_name)
    key = jax.random.fold_in(key, idx)
    if agg.backend != "pallas":
        if reveal != "replicated":
            raise ValueError(
                "reveal='sharded' needs the flat-buffer wire (pallas "
                "backend); the per-leaf reference oracle is replicated-only"
            )
        return _secure_psum_per_leaf(tree, axis_name, key, agg, pts, dtype)

    # sharded reveal scatters the rows axis: align rows to lcm(8, D) so
    # every device's tile keeps the (8, 128) sublane layout (the zero
    # tail packs to zero shares — benign through reduce and reveal)
    row_align = ROW_ALIGN if reveal == "replicated" else math.lcm(
        ROW_ALIGN, num_devices
    )
    buf, layout = pack_pytree(tree, row_align=row_align)
    shares = _protect_flat(
        key, buf, agg.scheme, agg.codec.frac_bits, layout.rows, points=pts
    )  # (t', R, rows, 128) uint32 — only the reveal subset exists
    if reveal == "replicated":
        summed = _field_allreduce(shares, axis_name, agg.scheme.field)
        flat = _reveal_flat(summed, agg.scheme, agg.codec.frac_bits, pts)
        return unpack_pytree(flat, layout, dtype=dtype)
    tile = _field_allreduce(
        shares, axis_name, agg.scheme.field, scatter_axis=2
    )  # (t', R, rows / D, 128): this device's slice of the residues
    flat_tile = _reveal_flat(
        tile, agg.scheme, agg.codec.frac_bits, pts
    ).astype(dtype)  # decode locally, gather plaintext (dtype-sized)
    if out == "tile":
        return ShardedAggregate(flat_tile, layout, num_devices)
    flat = jax.lax.all_gather(flat_tile, axis_name, axis=0, tiled=True)
    return unpack_pytree(flat, layout, dtype=dtype)
