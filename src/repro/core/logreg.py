"""L2-regularized logistic regression: local summary statistics (Eqs. 4-6).

Convention note: the paper writes the gradient as ``sum (1-p_i) y_i x_i``
(Eq. 5), which is the y in {-1,+1} form with p_i = sigmoid(y_i * beta^T x_i);
for y in {0,1} the same quantity is ``sum (y_i - p_i) x_i``.  The two produce
identical Newton iterates.  We implement the {0,1} form internally (it is
what the evaluation datasets use) and expose it as the paper's ``g_j``.

Everything here is *local to one institution*: pure functions of that
institution's (X_j, y_j) and the current public beta.  No privacy machinery
at this layer — that is core.secure_agg's job — exactly mirroring the paper's
"distributed phase" (Algorithm 1, steps 3-8).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["LocalSummaries", "local_summaries", "predict_proba", "deviance"]


class LocalSummaries(NamedTuple):
    """Per-institution summary statistics (the protocol's 'aggregates')."""

    hessian: jnp.ndarray  # (d, d)  sum_i w_ii x_i x_i^T   (unregularized)
    gradient: jnp.ndarray  # (d,)    sum_i (y_i - p_i) x_i  (unregularized)
    deviance: jnp.ndarray  # ()      -2 sum_i log-likelihood_i
    count: jnp.ndarray  # ()      N_j (public in the paper's setting)


def predict_proba(beta: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """p(y=1 | x; beta) = sigmoid(X beta)  (Eq. 1)."""
    return jax.nn.sigmoid(X @ beta)


def deviance(beta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """-2 log L(beta) (Eq. 6), numerically stable via logaddexp."""
    z = X @ beta
    # y log p + (1-y) log(1-p) = y*z - log(1 + e^z)
    ll = y * z - jnp.logaddexp(0.0, z)
    return -2.0 * jnp.sum(ll)


@jax.jit
def local_summaries(
    beta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray
) -> LocalSummaries:
    """Compute H_j, g_j, dev_j for one institution (Algorithm 1 steps 4-6).

    H_j = X_j^T W_j X_j with w_ii = p_i (1 - p_i); g_j = X_j^T (y_j - p_j).
    The lambda terms are *center-side* (they involve the public beta only)
    and are applied in newton.newton_step, matching Eqs. 4-5 where the
    regularizer sits outside the per-institution sums.
    """
    z = X @ beta
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p)
    hessian = (X * w[:, None]).T @ X
    gradient = X.T @ (y - p)
    ll = y * z - jnp.logaddexp(0.0, z)
    dev = -2.0 * jnp.sum(ll)
    return LocalSummaries(hessian, gradient, dev, jnp.asarray(X.shape[0]))
