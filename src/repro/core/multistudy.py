"""Slot-packed multi-study rounds: the seed of the study server.

ROADMAP direction 1's serving layer wants one deployment to advance
MANY independent studies (cohort x lambda x protect-mode combinations)
without paying one secure round per study.  The
:class:`repro.core.collective.SecureCollective` multiconfig wire makes
that a packing exercise: a study is just one more leading slot axis on
the summary tree, exactly like the selection sweep's (lambda x fold)
config axis.  :func:`fused_multistudy_iteration` advances M independent
cohorts by ONE collective round on a shared (study-slot, S, ...) batch:

* per-study batched summaries (one fused-IRLS launch per study — the
  studies have different betas, so the summaries cannot share a launch),
* ONE encode+share launch over the (M * S) flat slices, ONE exact
  uint64 reduction over the institution axis per slot, ONE Lagrange+CRT
  reveal of the M per-study aggregates
  (``SecureCollective.secure_round_multiconfig`` with the study slot as
  the config axis),
* per-study Newton/prox updates on the revealed aggregates.

Because Shamir reconstruction cancels the sharing polynomials exactly
in the field, each slot's revealed aggregate is the same field decode an
independent per-study round would produce — so a slot-packed fit
matches M independent fits to fixed-point quantization (pinned in
``tests/test_collective.py``).  Privacy is unchanged: slots are
independent payload lanes of the one certified chain; no cross-study
term ever forms, and only per-study cross-institution aggregates are
revealed.

Studies with different cohort sizes pack by padding: extra institutions
enter with ``count=0`` (their masked summaries are exactly zero, which
encodes to the zero field element and drops out of the aggregate), and
shorter record axes zero-pad below the count mask.  See
:func:`stack_studies`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .batched_summaries import (
    PackedPartitions,
    batched_local_summaries,
    pack_partitions,
)
from .collective import SecureCollective, declassify_sum
from .newton import (
    _protected_tree,
    prox_newton_step,
    regularized_objective,
)

__all__ = ["stack_studies", "fused_multistudy_iteration",
           "run_multistudy_rounds"]


def stack_studies(studies) -> PackedPartitions:
    """Stack M studies' partition lists into one (M, S, N, d) batch.

    ``studies`` is a sequence of per-study partition lists (each a list
    of ``(X_j, y_j)`` pairs, as :func:`pack_partitions` takes).  Ragged
    studies are padded to the widest cohort and the longest record axis:
    padding institutions carry ``count=0`` and all-zero rows, so their
    masked summaries are exactly zero and vanish from every aggregate —
    the packed fit stays bit-equal to the unpadded one.
    """
    if not studies:
        raise ValueError("need at least one study")
    packs = [pack_partitions(list(parts)) for parts in studies]
    d = packs[0].X.shape[-1]
    if any(p.X.shape[-1] != d for p in packs):
        raise ValueError("all studies must share the feature dimension")
    s_max = max(p.X.shape[0] for p in packs)
    n_max = max(p.X.shape[1] for p in packs)

    def pad(arr, s_dim, n_dim=None):
        widths = [(0, s_dim - arr.shape[0])]
        if n_dim is not None:
            widths.append((0, n_dim - arr.shape[1]))
        widths.extend([(0, 0)] * (arr.ndim - len(widths)))
        return jnp.pad(arr, widths)

    X = jnp.stack([pad(p.X, s_max, n_max) for p in packs])
    X32 = jnp.stack([pad(p.X32, s_max, n_max) for p in packs])
    y = jnp.stack([pad(p.y, s_max, n_max) for p in packs])
    counts = jnp.stack([pad(p.counts, s_max) for p in packs])
    return PackedPartitions(X, X32, y, counts)


@functools.partial(
    jax.jit, static_argnames=("agg", "protect", "l1", "interpret", "points",
                              "include_count", "summaries_backend")
)
def fused_multistudy_iteration(betas, key, X, X32, y, counts, lams,
                               agg: SecureCollective, protect: str,
                               l1: float, interpret: bool,
                               points: tuple[int, ...] | None = None,
                               include_count: bool = False,
                               summaries_backend: str = "pallas"):
    """M independent secure Newton rounds as ONE collective round.

    Arrays carry a leading study-slot axis: ``betas`` (M, d), ``lams``
    (M,), ``X``/``X32``/``y``/``counts`` as stacked by
    :func:`stack_studies`.  The per-study summaries stack into a
    (study-slot, S, ...) tree and advance through ONE
    ``secure_round_multiconfig`` — one encode+share launch, one
    per-slot institution reduction, one reveal — then each study applies
    its own prox/Newton update on its revealed aggregate.  Returns
    ``(betas_new, objectives, grad_norms, step_norms)``, each with the
    leading M axis; the scalars are the same PUBLIC metric leaves the
    single-study fused iteration emits.

    ``protect``/``l1``/``points``/``include_count`` are shared across
    slots (one wire contract per deployment); per-study lambda rides in
    ``lams``.  Unprotected leaves leave the round per slot only as
    cross-institution sums through the annotated ``declassify_sum``
    boundary, exactly as in the single-study drivers.
    """
    num_studies = X.shape[0]
    sms = [
        batched_local_summaries(
            betas[m], PackedPartitions(X[m], X32[m], y[m], counts[m]),
            backend=summaries_backend, interpret=interpret,
        )
        for m in range(num_studies)
    ]
    hessian = jnp.stack([sm.hessian for sm in sms])    # (M, S, d, d)
    gradient = jnp.stack([sm.gradient for sm in sms])  # (M, S, d)
    dev = jnp.stack([sm.deviance for sm in sms])       # (M, S)
    revealed = {}
    tree = _protected_tree(protect, hessian, gradient, dev)
    if tree and include_count:
        tree["count"] = counts.astype(jnp.float64)
    if tree:
        revealed = agg.secure_round_multiconfig(key, tree, points=points)
    global_h = revealed["hessian"] if protect in ("hessian", "both") \
        else declassify_sum(hessian, axis=1)
    global_g = revealed["gradient"] if protect in ("gradient", "both") \
        else declassify_sum(gradient, axis=1)
    global_dev = revealed["deviance"] if protect != "none" \
        else declassify_sum(dev, axis=1)

    def per_study(H, g, dv, beta, lam):
        obj = regularized_objective(dv, beta, lam, l1)
        beta_new = prox_newton_step(
            beta, jnp.asarray(H, jnp.float64), jnp.asarray(g, jnp.float64),
            lam, l1,
        )
        gnorm = jnp.linalg.norm(jnp.asarray(g, jnp.float64))
        snorm = jnp.linalg.norm(beta_new - beta)
        return beta_new, obj, gnorm, snorm

    return jax.vmap(per_study)(global_h, global_g, global_dev, betas, lams)


def run_multistudy_rounds(studies, lams, num_rounds: int,
                          aggregator: SecureCollective | None = None,
                          protect: str = "both", l1: float = 0.0,
                          key: jax.Array | None = None,
                          summaries_backend: str = "pallas",
                          interpret: bool = True):
    """Advance M studies ``num_rounds`` rounds, one collective round each.

    Host-loop convenience over :func:`fused_multistudy_iteration` (the
    study-server seed has no convergence machinery yet — every study
    runs the full budget).  Returns ``(betas, objective_trace)`` with
    ``betas`` (M, d) and ``objective_trace`` (num_rounds, M).  The
    protect rng follows the one :meth:`SecureCollective.round_key`
    discipline — round r folds ``(key, r)`` — though the revealed
    aggregates (and hence the betas) are rng-independent either way.
    """
    agg = aggregator or SecureCollective(backend="pallas")
    packed = stack_studies(studies)
    d = packed.X.shape[-1]
    betas = jnp.zeros((len(studies), d), jnp.float64)
    lams = jnp.asarray(lams, jnp.float64)
    if key is None:
        key = jax.random.PRNGKey(0)
    trace = []
    for r in range(num_rounds):
        betas, objs, _, _ = fused_multistudy_iteration(
            betas, agg.round_key(key, r), packed.X, packed.X32, packed.y,
            packed.counts, lams, agg, protect, l1, interpret,
            summaries_backend=summaries_backend,
        )
        trace.append(objs)
    return betas, jnp.stack(trace)
