"""The one secure collective: pack -> protect -> aggregate -> reveal -> unpack.

The paper's entire protocol is a single primitive — institutions protect
local summaries, Computation Centers aggregate share-wise (Algorithm 2),
and only the threshold-met *aggregate* is ever reconstructed.  Before
this module the repo implemented that chain four near-identical times
(the host-side ``SecureAggregator`` rounds, the driver round bodies, the
selection sweep, and the in-SPMD ``secure_psum``/``secure_psum_2d``
wires).  :class:`SecureCollective` now owns the chain ONCE, with an
explicit axis for every way a consumer varies it:

* **batching** — :meth:`secure_round_batched` (S-leading institution
  batches) and :meth:`secure_round_multiconfig` ((config x institution)
  leading axes: the selection sweep's lambda x fold points, or the
  multi-study slot axis of :mod:`repro.core.multistudy`).
* **wire** — :meth:`psum` (1D pod-axis reduction of the flat uint32
  share buffer) and :meth:`psum_2d` (2D (pod, share) mesh where the
  reveal itself is a share-axis collective of Lagrange-weighted slices).
* **reveal placement** — ``reveal="replicated" | "sharded"`` and
  ``out="tree" | "tile"`` on the wire paths (:data:`REVEAL_MODES`,
  :data:`OUT_MODES`, :class:`ShardedAggregate`).
* **rng threading** — :meth:`round_key`: the ``fold_in(key, slot)``
  discipline every scan-resident consumer uses, so round r's sharing
  randomness is ``fold_in(key, r)`` regardless of block cutting.
* **byte telemetry** — :meth:`round_bytes`: the single static size
  model behind ``SecureFitDriver``, ``StudyCoordinator`` and the
  selection path's reports (previously three parallel accountings).
* **declassification sites** — the four named jit boundaries the static
  taint gate (:mod:`repro.analysis`) and the runtime privacy ledger
  (:mod:`repro.obs.ledger`) both key on live HERE and only here:
  ``_protect_flat``, ``_reveal_flat``, ``_distributed_reveal``,
  ``declassify_sum``.  A lint (``lint_collective_sites``) fails the gate
  if a direct call site appears outside this module, so the privacy
  review surface cannot silently grow back to four copies.

``repro.core.secure_agg`` remains the compatibility import surface
(``SecureAggregator`` is an alias of :class:`SecureCollective`); all
drivers and the SPMD wires route through this module.

Backends and the flat-buffer hot path
-------------------------------------
``backend="reference"`` walks the summary pytree leaf by leaf through
the uint64 jnp oracle — one dispatch per leaf per field op; it is the
bit-exactness oracle the flat wire is measured against.

``backend="pallas"`` runs the fused pipeline: the float pytree is packed
into ONE contiguous (rows, 128) tile buffer (`flatbuf.pack_pytree` —
pad once, remember the layout), so each phase is a single kernel launch
regardless of leaf count:

* ``protect``  — fused fixed-point encode + Horner share evaluation
  (`kernels.shamir_poly.shamir_encode_share_pallas`); the intermediate
  uint64 encoded tensor never materializes.  Returns a `FlatProtected`.
* ``aggregate`` — a streaming uint64 accumulator over the S submissions
  (exact sum, one trailing mod): no (S, ...) stack is ever allocated.
* ``reveal``   — fused Lagrange reconstruction + CRT Garner digit
  (`kernels.shamir_reconstruct`), then unpack back to the original
  pytree.

Share slices travel as uint32 (half the bytes of the reference uint64
path).  `FlatProtected` is a registered pytree whose only leaf is the
share buffer, so protocol code can slice/stack it with ``tree_map``
exactly like a plain share pytree.  All phases are jitted with the
layout/scheme as static arguments.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from ..distributed.compat import axis_size as _compat_axis_size
from ..distributed.sharding import POD_AXIS, SHARE_AXIS
from ..obs import ledger as _ledger
from ..obs.trace import traced as _traced
from .field import (
    FieldSpec,
    fsum,
    random_elements_fast,
)
from .fixed_point import FixedPointCodec
from .flatbuf import (
    FlatLayout,
    LANES,
    ROW_ALIGN,
    _rows_for,
    pack_pytree,
    pack_pytree_batched,
    unpack_pytree,
    unpack_pytree_tile,
)
from .shamir import ShamirScheme

__all__ = [
    "check_aggregation_headroom",
    "declassify_sum",
    "FlatProtected",
    "SecureCollective",
    "ShardedAggregate",
    "secure_psum",
    "REVEAL_MODES",
    "OUT_MODES",
]

REVEAL_MODES = ("replicated", "sharded")
OUT_MODES = ("tree", "tile")


def check_aggregation_headroom(num_addends: int, field: FieldSpec) -> None:
    """Guard the exact-uint64 share sum: ``S * max(p_r) < 2**64``.

    Every aggregation path (streaming fold, batched reduction, in-SPMD
    psum) accumulates reduced share elements (< p_r) in uint64 and applies
    ONE trailing mod, which is exact iff the unreduced sum cannot wrap.
    This is the single shared bound — ~2**33 institutions for the 31-bit
    moduli — enforced here so no path carries its own (historically
    inconsistent) claim.
    """
    if num_addends * max(field.moduli) >= 2**64:
        raise ValueError(
            f"cannot aggregate {num_addends} share tensors exactly: "
            f"{num_addends} * max modulus {max(field.moduli)} >= 2**64 "
            "would overflow the uint64 accumulator before the trailing mod"
        )


# ------------------------------------------------------------------------
# The four named declassification boundaries.  Each is a triple: an impl
# with a forced __name__/__qualname__ (the pjit equation name the static
# taint verifier's rules match on), a jitted form, and a host wrapper
# that records to the runtime privacy ledger before dispatching.  These
# are the ONLY direct call sites of the boundary wrappers in the tree
# (enforced by ``repro.analysis.lints.lint_collective_sites``).
# ------------------------------------------------------------------------


def _declassify_sum_impl(x, axis: int = 0):
    return jnp.sum(x, axis=axis)


# the pjit equation must be NAMED declassify_sum — that exact name is the
# key the static taint verifier's declassification rules match on
_declassify_sum_impl.__name__ = "declassify_sum"
_declassify_sum_impl.__qualname__ = "declassify_sum"
_declassify_sum_jit = functools.partial(
    jax.jit, static_argnames=("axis",)
)(_declassify_sum_impl)


def declassify_sum(x, axis: int = 0):
    """The sanctioned PLAINTEXT aggregation over the institution axis.

    Semantically just ``jnp.sum(x, axis=axis)`` — but spelled as a named
    jitted boundary so the static privacy-flow verifier
    (:mod:`repro.analysis`) can certify it.  The paper's pragmatic
    protect modes ("gradient" / "hessian" / "none") deliberately exchange
    SOME summaries in the clear; the protocol contract is that only
    their *cross-institution sums* ever leave the round.  Every driver
    spells those sums through this function, which the taint verifier
    treats as the one annotated SECRET -> PUBLIC declassification for
    unprotected leaves (it still checks the reduction actually
    aggregates >= 2 addends, so a non-reducing "sum" cannot launder an
    individual institution's summary).  A plain ``jnp.sum`` on secret
    data fails the gate — which is the point: intentional plaintext
    aggregation must be visible and auditable.

    The runtime privacy-audit ledger (:mod:`repro.obs.ledger`) counts
    every *Python-level invocation* of this boundary: the hook lives in
    this host wrapper, outside the jitted body, so a host-level call
    records once per call (per round in the loop drivers) and a call
    inside an enclosing ``jit`` records once per call site each time
    the enclosing graph is traced.  Cached dispatches of an already
    certified graph add no new declassification sites by construction —
    ``python -m repro.obs audit`` reconciles the recorded counts against
    a per-equation census of each driver spec's graph.  The hook records
    static metadata only (shape/axis), never values, and adds no
    equation to the graph.
    """
    _ledger.record_site("declassify_sum", what=f"axis{axis}_sum",
                        shape=x.shape)
    return _declassify_sum_jit(x, axis=axis)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FlatProtected:
    """Protected flat-buffer representation: one uint32 share tensor.

    ``buf`` is (w, R, rows, 128) fresh from ``protect`` (holder axis
    leading), (R, rows, 128) after per-center slicing, or (k, R, rows, 128)
    once >= t centers stack their aggregate slices for reveal.  ``layout``
    (static aux data) remembers how to unpack the revealed buffer back into
    the original pytree.  Registered as a pytree so protocol-level
    ``tree_map`` slicing/stacking works transparently.
    """

    buf: jnp.ndarray
    layout: FlatLayout

    def tree_flatten(self):
        return (self.buf,), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], layout)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fsum_batched(stacked, field: FieldSpec, residue_axis: int):
    """Jitted S-way field reduction (cast + sum + mod fused by XLA)."""
    return fsum(stacked, field, axis=0, residue_axis=residue_axis)


@functools.partial(
    jax.jit, static_argnames=("field", "residue_axis")
)
def _fold_sum_streaming(submissions, field: FieldSpec, residue_axis: int):
    """Share-wise sum of S submissions WITHOUT materializing an S-stack.

    A running uint64 accumulator folds the submissions one by one with a
    single mod at the end — exact iff ``S * max(p_r) < 2**64``, the shared
    bound ``check_aggregation_headroom`` enforces on every caller.  XLA
    fuses the unrolled chain into one elementwise loop over donation-sized
    buffers, so peak memory is one accumulator — not the (S, ...) stack
    the eager ``jnp.stack`` reduction allocated, which at 1e6+ params made
    ``aggregate`` allocation-bound.
    """
    acc = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.uint64), submissions[0]
    )
    for nxt in submissions[1:]:
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.uint64), acc, nxt
        )

    def _reduce(a, orig):
        p = field._bcast(a, residue_axis)
        return (a % p).astype(orig.dtype)

    return jax.tree_util.tree_map(_reduce, acc, submissions[0])


def _protect_flat_impl(key, buf, scheme: ShamirScheme, frac_bits: int,
                       rows: int, points: tuple[int, ...] | None = None):
    from ..kernels import ops

    field = scheme.field
    coeffs = random_elements_fast(
        key, (scheme.threshold - 1, rows, LANES), field
    ).astype(jnp.uint32)  # (R, t-1, rows, 128)
    return ops.shamir_protect_flat(
        buf, coeffs, scheme.num_shares, field.moduli, frac_bits,
        interpret=scheme.interpret, points=points,
    )  # (len(points) or w, R, rows, 128) uint32


# keep the pjit names the taint verifier's declassification rules key on
_protect_flat_impl.__name__ = "_protect_flat"
_protect_flat_impl.__qualname__ = "_protect_flat"
_protect_flat_jit = functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "rows", "points")
)(_protect_flat_impl)


def _protect_flat(key, buf, scheme: ShamirScheme, frac_bits: int, rows: int,
                  points: tuple[int, ...] | None = None):
    """Host wrapper: ledger hook + the jitted protect boundary.

    The audit ledger records per Python-level invocation (see
    :func:`declassify_sum` for the counting semantics).
    """
    _ledger.record_site("_protect_flat", what="encode+share",
                        shape=buf.shape, threshold=scheme.threshold)
    return _protect_flat_jit(key, buf, scheme, frac_bits, rows,
                             points=points)


def _reveal_flat_impl(buf, scheme: ShamirScheme, frac_bits: int,
                      points: tuple[int, ...]):
    from ..kernels import ops

    return ops.shamir_reveal_flat(
        buf, points, scheme.field.moduli, frac_bits,
        interpret=scheme.interpret,
    )  # (rows, 128) float64


_reveal_flat_impl.__name__ = "_reveal_flat"
_reveal_flat_impl.__qualname__ = "_reveal_flat"
_reveal_flat_jit = functools.partial(
    jax.jit, static_argnames=("scheme", "frac_bits", "points")
)(_reveal_flat_impl)


def _reveal_flat(buf, scheme: ShamirScheme, frac_bits: int,
                 points: tuple[int, ...]):
    """Host wrapper: ledger hook + the jitted reveal boundary.

    Every reveal — certified in-graph call sites AND any stray
    host-level call — passes through here, so the runtime audit counts
    it even when the jitted impl hits the compilation cache.
    """
    _ledger.record_site("_reveal_flat", what="lagrange_reveal",
                        shape=buf.shape, threshold=scheme.threshold)
    return _reveal_flat_jit(buf, scheme, frac_bits, points)


def _distributed_reveal_impl(agg_slice, scheme, codec, points, share_axis,
                             dtype):
    """Lagrange reconstruction as a SHARE_AXIS collective.

    ``agg_slice`` is this center's aggregated share slice (R, rows, 128)
    uint32.  Each center multiplies by its own public weight
    ``L_j(0) mod p_r`` (field mul, uint64), then ONE psum over the share
    axis + trailing mod yields the aggregate residues — exact because
    the k partial products are each < p_r < 2**31 and k << 2**33
    (the shared aggregation-headroom bound).  CRT decode is local.

    Jitted under its own name on purpose: the static privacy-flow gate
    (:mod:`repro.analysis`) recognizes the ``_distributed_reveal`` pjit
    as the 2D mesh's ONE sanctioned declassification and checks its
    operand is the pod-aggregated share slice revealed over a
    threshold-satisfying share axis.
    """
    from .field import crt_combine_signed
    from .shamir import lagrange_coeffs_at_zero

    field = scheme.field
    lam = lagrange_coeffs_at_zero(points, field)  # (R, k) uint64
    j = jax.lax.axis_index(share_axis)
    w = jnp.take(lam, j, axis=1)  # (R,) this center's weight
    partial = (agg_slice.astype(jnp.uint64) * w[:, None, None]) \
        % field._bcast(agg_slice, 0)
    summed = jax.lax.psum(partial, share_axis) % field._bcast(partial, 0)
    signed = crt_combine_signed(summed, field)
    return (signed.astype(jnp.float64) / codec.scale).astype(dtype)


# the pjit equation must keep the exact name the static gate's
# declassification rules match on
_distributed_reveal_impl.__name__ = "_distributed_reveal"
_distributed_reveal_impl.__qualname__ = "_distributed_reveal"
_distributed_reveal_jit = functools.partial(
    jax.jit, static_argnames=("scheme", "codec", "points", "share_axis",
                              "dtype")
)(_distributed_reveal_impl)


def _distributed_reveal(agg_slice, scheme, codec, points, share_axis,
                        dtype):
    """Host wrapper: privacy-ledger hook + the jitted collective reveal.

    The runtime audit counts per Python-level invocation — once per
    trace of the enclosing ``shard_map`` graph (see
    :func:`declassify_sum` for semantics).
    """
    _ledger.record_site("_distributed_reveal", what="share_axis_reveal",
                        shape=agg_slice.shape,
                        threshold=scheme.threshold)
    return _distributed_reveal_jit(agg_slice, scheme, codec, points,
                                   share_axis, dtype)


def _field_allreduce(shares, axis_name: str, field: FieldSpec,
                     residue_axis: int = 1, scatter_axis: int | None = None):
    """Exact share-wise field sum over a mesh axis (Algorithm 2 on the wire).

    The accumulation widens to uint64 so XLA's collective (which has no
    per-hop modular reduction) stays exact — the shared
    ``check_aggregation_headroom`` bound ``S * max(p_r) < 2**64`` — and a
    single trailing mod returns the reduced wire dtype.  A deployment
    fabric doing per-hop modular adds would move the reduced uint32
    elements instead; the payload accounting counts those (see
    ``benchmarks/secure_psum.py``).

    ``scatter_axis=None`` all-reduces (every device gets the full summed
    buffer); an integer reduce-scatters that axis so each device keeps
    only its 1/D tile of the distributed residues.
    """
    summed = jax.lax.psum(shares.astype(jnp.uint64), axis_name) \
        if scatter_axis is None else jax.lax.psum_scatter(
            shares.astype(jnp.uint64), axis_name,
            scatter_dimension=scatter_axis, tiled=True,
        )
    return (summed % field._bcast(summed, residue_axis)).astype(shares.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedAggregate:
    """A revealed aggregate that STAYS sharded over the reduce axis.

    ``secure_psum(reveal="sharded", out="tile")`` hands every device its
    decoded ``(rows / D, 128)`` plaintext tile of the flat aggregate
    buffer instead of all-gathering + unpacking.  Downstream code that
    consumes the aggregate shard-wise (a distributed solve, a sharded
    optimizer update) skips the gather entirely; anything that needs the
    whole tree calls :meth:`gather` — which is exactly what
    ``out="tree"`` would have done, so the two spellings are bit-equal.

    Registered as a pytree with the tile as its only leaf (layout and
    tile count are static aux data), so it crosses ``shard_map`` /
    ``jit`` boundaries like a plain array.
    """

    tile: jnp.ndarray
    layout: FlatLayout
    num_tiles: int

    def gather(self, axis_name: str, dtype=jnp.float32):
        """All-gather the plaintext tiles and unpack the full pytree."""
        flat = jax.lax.all_gather(self.tile, axis_name, axis=0, tiled=True)
        return unpack_pytree(flat, self.layout, dtype=dtype)

    def local_fragments(self, tile_index: int, dtype=None):
        """Leaf fragments in THIS tile (static ``tile_index`` required).

        See :func:`repro.core.flatbuf.unpack_pytree_tile` for the
        ``{leaf: (start, stop, fragment)}`` contract.
        """
        return unpack_pytree_tile(
            self.tile, self.layout, tile_index, self.num_tiles, dtype=dtype
        )

    def tree_flatten(self):
        return (self.tile,), (self.layout, self.num_tiles)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], *aux)


@dataclasses.dataclass(frozen=True)
class SecureCollective:
    """The one protect -> aggregate -> reveal pipeline for float pytrees.

    ``backend=None`` inherits the scheme's backend; passing "pallas" or
    "reference" overrides the scheme to match (convenience so callers can
    write ``SecureCollective(backend="pallas")``).

    ``overflow_check=True`` arms the debug-mode fixed-point overflow
    assert on every protect path: a value past the capacity bound raises
    ``OverflowError`` (eagerly outside jit, at the next sync inside)
    instead of silently saturating into a plausible-but-wrong reveal —
    the hard-failure form of the ``headroom_ok`` predicate.  Paths that
    know the addend count (``protect_batched`` over S institutions,
    ``psum`` over D devices) tighten the bound to ``capacity / S`` so an
    aggregate that would overflow is caught at protect time, not
    revealed wrong.

    Every secure driver routes here: the fused/scanned fit rounds via
    :meth:`secure_round_batched`, the selection sweep (and the
    multi-study slot packing) via :meth:`secure_round_multiconfig`, the
    SPMD wires via :meth:`psum` / :meth:`psum_2d`, and the scan-resident
    wire via :meth:`allreduce` + :meth:`reveal_wire`.  Byte telemetry
    for all of them comes from :meth:`round_bytes`.
    """

    scheme: ShamirScheme = ShamirScheme()
    codec: FixedPointCodec = FixedPointCodec()
    backend: str | None = None
    overflow_check: bool = False

    def __post_init__(self):
        if self.backend is None:
            object.__setattr__(self, "backend", self.scheme.backend)
        elif self.backend != self.scheme.backend:
            object.__setattr__(
                self, "scheme",
                dataclasses.replace(self.scheme, backend=self.backend),
            )
        if self.scheme.field is not self.codec.field and (
            self.scheme.field.moduli != self.codec.field.moduli
        ):
            raise ValueError("scheme and codec must agree on the field")

    # rng threading --------------------------------------------------------
    @staticmethod
    def round_key(key: jax.Array, slot) -> jax.Array:
        """The one rng-threading rule: round r's key is ``fold_in(key, r)``.

        Every scan-resident consumer (``fit_scan_block``, the selection
        sweep, ``scan_secure_rounds``) folds the protect rng in-graph
        from a single key and the round slot, so executed round r always
        sees the same sharing randomness regardless of how the fit was
        cut into blocks — which is what makes ``state_dict`` resume
        bit-identical to an uninterrupted run.
        """
        return jax.random.fold_in(key, slot)

    # institution side --------------------------------------------------------
    @_traced("protect")
    def protect(self, key: jax.Array, tree):
        """Encode floats to the field and split into shares.

        Reference backend: per-leaf share pytree of (w, R, ...) uint64.
        Pallas backend: a single ``FlatProtected`` share buffer.
        """
        if self.backend == "pallas":
            buf, layout = pack_pytree(tree)
            if self.overflow_check:
                self.codec.check_headroom(buf, what="protect")
            shares = _protect_flat(
                key, buf, self.scheme, self.codec.frac_bits, layout.rows
            )
            return FlatProtected(shares, layout)
        encoded = jax.tree_util.tree_map(
            functools.partial(self.codec.encode, check=self.overflow_check),
            tree,
        )
        return self.scheme.share_pytree(key, encoded)

    @_traced("protect")
    def protect_batched(self, key: jax.Array, tree):
        """Protect S institutions' summaries in ONE kernel launch.

        ``tree`` leaves carry a leading S (institution) axis; the S flat
        slices are packed side by side and pushed through a single
        encode+share launch.  Returns a ``FlatProtected`` whose buffer is
        (w, R, S, rows, 128) — feed it to ``aggregate_batched`` to reduce
        the S axis (the layout describes one slice, i.e. the aggregate).
        Pallas backend only: the batched layout IS the flat wire format.
        """
        if self.backend != "pallas":
            raise ValueError("protect_batched requires the pallas backend")
        buf, layout = pack_pytree_batched(tree)
        if self.overflow_check:
            # the S slices will be summed: bound each by capacity / S so
            # the AGGREGATE cannot overflow (the headroom_ok contract)
            self.codec.check_headroom(
                buf, num_addends=buf.shape[0], what="protect_batched"
            )
        s_dim, rows = buf.shape[0], layout.rows
        shares = _protect_flat(
            key, buf.reshape(s_dim * rows, LANES), self.scheme,
            self.codec.frac_bits, s_dim * rows,
        )  # (w, R, S*rows, 128)
        w, num_r = shares.shape[0], shares.shape[1]
        return FlatProtected(
            shares.reshape(w, num_r, s_dim, rows, LANES), layout
        )

    # computation-center side -------------------------------------------------
    @_traced("aggregate")
    def aggregate(self, protected: Sequence):
        """Share-wise sum over institutions (still protected).

        Streams a running uint64 accumulator over the S submissions (one
        fused elementwise chain, single mod) instead of stacking them: at
        1e6+ params the old eager ``jnp.stack`` made this phase
        allocation-bound on the (S, w, R, ...) stack.
        """
        if not protected:
            raise ValueError("nothing to aggregate")
        if len(protected) == 1:
            return protected[0]
        field = self.scheme.field
        check_aggregation_headroom(len(protected), field)
        # leaves are (w, R, ...) protect outputs: residue axis 1 (same
        # contract as secure_add)
        return _fold_sum_streaming(tuple(protected), field, residue_axis=1)

    @_traced("aggregate")
    def aggregate_batched(self, protected: FlatProtected) -> FlatProtected:
        """Reduce the institution axis of a ``protect_batched`` output.

        One exact uint64 reduction over axis 2 of the (w, R, S, rows, 128)
        share buffer — Algorithm 2 for all S submissions in a single
        dispatch, with no per-submission stacking step.
        """
        check_aggregation_headroom(protected.buf.shape[2], self.scheme.field)
        buf = fsum(protected.buf, self.scheme.field, axis=2, residue_axis=1)
        return FlatProtected(buf, protected.layout)

    def allreduce(self, shares, axis_name: str, residue_axis: int = 1,
                  scatter_axis: int | None = None):
        """Algorithm 2 over a mesh axis: exact field psum of share slices.

        The in-SPMD aggregation step of the wire paths; see
        :func:`_field_allreduce` for the exactness argument.
        """
        return _field_allreduce(shares, axis_name, self.scheme.field,
                                residue_axis=residue_axis,
                                scatter_axis=scatter_axis)

    def _validated_points(self, points) -> tuple[int, ...]:
        """Normalize + sanity-check reveal points (1-based, distinct).

        ``None`` defaults to the first t points — the SAME t-subset
        default every reveal path uses (reconstruction from any t shares
        is exact, so a t-subset reveal is bit-identical to the all-w one
        and does strictly less work).  Below-threshold subsets are
        rejected here, before any reduction over a short share axis.
        """
        w = self.scheme.num_shares
        if points is None:
            points = tuple(range(1, self.scheme.threshold + 1))
        points = tuple(int(p) for p in points)
        if any(not (1 <= p <= w) for p in points):
            raise ValueError(f"points must be in 1..{w}, got {points}")
        if len(set(points)) != len(points):
            raise ValueError(f"points must be distinct, got {points}")
        if len(points) < self.scheme.threshold:
            raise ValueError(
                f"need >= t={self.scheme.threshold} shares, got "
                f"{len(points)} (information-theoretically irrecoverable "
                "below threshold)"
            )
        return points

    @_traced("secure_round")
    def secure_round_batched(self, key: jax.Array, tree,
                             points: Sequence[int] | None = None,
                             dtype=jnp.float64):
        """One whole Algorithm-1+2 round over S-leading summaries.

        protect_batched (ONE encode+share launch) -> aggregate_batched
        (single exact uint64 reduction over the institution axis) ->
        reveal of the *global* aggregate from the ``points`` centers'
        slices.  ``points`` are the 1-based evaluation points of the
        centers participating in the reveal (default: the first t); a
        short list raises the below-threshold error from ``reveal``, so a
        caller that lost too many centers fails loudly instead of
        reducing over a short share axis.  Fully traceable — this is the
        round helper both the fused ``secure_fit`` iteration and the
        fused ``StudyCoordinator.step`` run inside one jitted graph.
        """
        points = self._validated_points(points)
        prot = self.protect_batched(key, tree)
        aggd = self.aggregate_batched(prot)
        sel = jnp.asarray([p - 1 for p in points])
        return self.reveal(
            FlatProtected(aggd.buf[sel], aggd.layout), points=points,
            dtype=dtype,
        )

    @_traced("secure_round")
    def secure_round_multiconfig(self, key: jax.Array, tree,
                                 points: Sequence[int] | None = None,
                                 dtype=jnp.float64):
        """One secure round over a (C, S, ...)-leading summary tree.

        The slot-packed wire shape: every leaf carries a leading
        (config, institution) pair of axes.  For the selection sweep the
        C axis is the (lambda x fold) path points advancing together;
        for the multi-study server seed (:mod:`repro.core.multistudy`)
        it is the study slot — independent cohorts advanced by one
        round.  The whole round is still three launches total,
        independent of C:

        * ONE encode+share launch over the (C * S) flat slices
          (``protect_batched`` on the collapsed leading axis),
        * ONE exact uint64 reduction over the institution axis — the
          share buffer reshapes to (w, R, C, S, rows, 128) and Algorithm
          2 runs per config along axis 3,
        * ONE Lagrange+CRT reveal over the (C * rows, 128) stack of
          per-config aggregates, unpacked back to (C, ...)-leading
          leaves.

        Per-institution validation scores therefore never exist in the
        clear anywhere: held-out metrics enter as shares and only their
        cross-institution sums are reconstructed, per config.  Fully
        traceable; this runs inside the selection scan's jitted graph.
        """
        points = self._validated_points(points)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("cannot run a round on an empty pytree")
        c_dim, s_dim = leaves[0].shape[0], leaves[0].shape[1]
        if any(l.shape[:2] != (c_dim, s_dim) for l in leaves):
            raise ValueError(
                "all leaves need the same leading (config, institution) axes"
            )
        flat_tree = jax.tree_util.tree_unflatten(
            treedef,
            [l.reshape((c_dim * s_dim,) + l.shape[2:]) for l in leaves],
        )
        prot = self.protect_batched(key, flat_tree)
        w, num_r, _, rows, lanes = prot.buf.shape
        by_config = prot.buf.reshape(w, num_r, c_dim, s_dim, rows, lanes)
        # Algorithm 2 per config: exact uint64 reduction over institutions
        check_aggregation_headroom(s_dim, self.scheme.field)
        aggd = fsum(by_config, self.scheme.field, axis=3, residue_axis=1)
        sel = jnp.asarray([p - 1 for p in points])
        stacked = aggd[sel].reshape(len(points), num_r, c_dim * rows, lanes)
        flat = _reveal_flat(
            stacked, self.scheme, self.codec.frac_bits, points
        )  # (C * rows, 128) float64
        from .flatbuf import unpack_pytree_batched

        return unpack_pytree_batched(
            flat.reshape(c_dim, rows, lanes), prot.layout, dtype=dtype
        )

    @_traced("reveal")
    def reveal(self, protected, points=None, dtype=jnp.float64):
        """Joint reconstruction of the (aggregate) secret -> floats.

        In deployment this is the only step that requires >= t centers to
        cooperate, and it is only ever invoked on *global* aggregates.

        ``points=None`` assumes the share slices are in holder order
        (1..k, as ``protect`` emits them) and reconstructs from the first
        t — the unified ``_validated_points`` default on BOTH backends.
        Reconstruction from any t-subset is exact field arithmetic, so the
        result is bit-identical to an all-k reveal at a fraction of the
        Lagrange work.  Pass explicit ``points`` when the slices are a
        non-contiguous center subset (then they must match the slice
        count).
        """
        t = self.scheme.threshold
        if isinstance(protected, FlatProtected):
            k = protected.buf.shape[0]
            if k < t:
                raise ValueError(
                    f"need >= t={t} shares, got {k} "
                    "(information-theoretically irrecoverable below "
                    "threshold)"
                )
            if points is None:
                buf = protected.buf[:t] if k > t else protected.buf
                pts = self._validated_points(None)
            else:
                buf = protected.buf
                pts = self._validated_points(points)
                if len(pts) != k:
                    raise ValueError("points must match share count")
            flat = _reveal_flat(
                buf, self.scheme, self.codec.frac_bits, pts
            )
            return unpack_pytree(flat, protected.layout, dtype=dtype)
        if points is None:
            # same t-subset default as the flat path: slice each leaf's
            # holder axis down to the first t shares before reconstructing
            leaves = jax.tree_util.tree_leaves(protected)
            k = leaves[0].shape[0] if leaves else 0
            if k < t:
                raise ValueError(
                    f"need >= t={t} shares, got {k} "
                    "(information-theoretically irrecoverable below "
                    "threshold)"
                )
            protected = jax.tree_util.tree_map(
                lambda s: s[:t], protected
            )
            points = self._validated_points(None)
        recon = self.scheme.reconstruct_pytree(protected, list(points))
        return jax.tree_util.tree_map(
            lambda v: self.codec.decode(v, dtype=dtype), recon
        )

    def reveal_wire(self, buf, points: tuple[int, ...]):
        """Reveal a raw (k, R, rows, 128) aggregated share buffer in-graph.

        The wire-level reveal entry for scan-resident consumers
        (``distributed.multihost.scan_secure_rounds``) that carry the
        flat buffer themselves instead of a ``FlatProtected``: Lagrange
        + CRT decode to a (rows, 128) float64 tile.  Exists so the
        ``_reveal_flat`` boundary is only ever invoked from this module
        (the ``lint_collective_sites`` contract); semantics are exactly
        :func:`_reveal_flat`.
        """
        return _reveal_flat(buf, self.scheme, self.codec.frac_bits, points)

    def headroom_ok(self, max_abs: float, num_institutions: int) -> bool:
        """True if S summaries of magnitude <= max_abs aggregate exactly."""
        return max_abs * num_institutions < self.codec.capacity()

    # byte telemetry ----------------------------------------------------------
    def round_bytes(self, d: int, num_parts: int, protect: str,
                    include_count: bool = False,
                    num_live_centers: int | None = None,
                    num_configs: int = 1, extra_scalars: int = 0) -> int:
        """Per-round wire bytes from static shapes/dtypes alone.

        The ONE size model behind every driver's telemetry
        (``SecureFitDriver``, ``StudyCoordinator.reports``, the selection
        path's ``bytes_per_round`` — previously three parallel
        accountings).  Every round moves the same messages (the summary
        shapes never change), so telemetry needs no per-leaf walk inside
        the loop: shares travel as w x R slices of the flat uint32 tile
        buffer (pallas) or uint64 leaf tensors (reference); unprotected
        leaves go plain in f64.

        ``include_count`` mirrors the coordinator wire protocol's extra
        ``count`` leaf; ``num_live_centers`` switches from secure_fit's
        all-w accounting to the coordinator's per-center slicing (each
        online center receives one 1/w slice of the share buffer).
        ``num_configs`` multiplies the whole message set for the
        multiconfig wire's (lambda x fold, or study-slot) config axis —
        every config ships its own summary tree per round — and
        ``extra_scalars`` accounts for the selection path's additional
        held-out-metric leaves (val deviance / correct / count) riding
        in each config's protected buffer.
        """
        extra = (2 if include_count else 1) + extra_scalars
        n_protected = 0
        if protect in ("gradient", "both"):
            n_protected += d
        if protect in ("hessian", "both"):
            n_protected += d * d
        if protect != "none":
            n_protected += extra
        scheme = self.scheme
        w, num_r = scheme.num_shares, scheme.field.num_residues
        share_bytes = 0
        if n_protected:
            if self.backend == "pallas":
                rows = _rows_for(n_protected, ROW_ALIGN)
                share_bytes = w * num_r * rows * LANES * 4  # uint32 wire
            else:
                share_bytes = w * num_r * n_protected * 8  # uint64 leaves
            if num_live_centers is not None:
                share_bytes = (share_bytes // w) * num_live_centers
        n_plain = 0
        if protect in ("none", "hessian"):
            n_plain += d
        if protect in ("none", "gradient"):
            n_plain += d * d
        if protect == "none":
            n_plain += extra
        return num_configs * num_parts * (share_bytes + n_plain * 8)

    # in-SPMD wires -----------------------------------------------------------
    def psum(self, tree, axis_name: str, key: jax.Array,
             dtype=jnp.float32, reveal: str = "replicated",
             points: Sequence[int] | None = None, out: str = "tree"):
        """Secret-shared all-reduce over a mesh axis (the 1D wire).

        See :func:`secure_psum` (the traced module-level entry) for the
        full wire/reveal/out contract; this method is the chain itself.
        """
        if reveal not in REVEAL_MODES:
            raise ValueError(f"reveal must be one of {REVEAL_MODES}")
        if out not in OUT_MODES:
            raise ValueError(f"out must be one of {OUT_MODES}")
        if out == "tile" and reveal != "sharded":
            raise ValueError(
                "out='tile' only makes sense with reveal='sharded' — the "
                "replicated reveal already holds the full aggregate "
                "everywhere"
            )
        pts = self._validated_points(points)
        num_devices = _compat_axis_size(axis_name)
        check_aggregation_headroom(num_devices, self.scheme.field)
        if self.overflow_check:
            # every device's contribution is bounded by capacity / D so the
            # D-way field sum cannot overflow (headroom_ok, hard-failure
            # form)
            jax.tree_util.tree_map(
                lambda leaf: self.codec.check_headroom(
                    leaf, num_addends=num_devices, what="secure_psum"
                ),
                tree,
            )
        idx = jax.lax.axis_index(axis_name)
        key = self.round_key(key, idx)
        if self.backend != "pallas":
            if reveal != "replicated":
                raise ValueError(
                    "reveal='sharded' needs the flat-buffer wire (pallas "
                    "backend); the per-leaf reference oracle is "
                    "replicated-only"
                )
            return _secure_psum_per_leaf(tree, axis_name, key, self, pts,
                                         dtype)

        # sharded reveal scatters the rows axis: align rows to lcm(8, D) so
        # every device's tile keeps the (8, 128) sublane layout (the zero
        # tail packs to zero shares — benign through reduce and reveal)
        row_align = ROW_ALIGN if reveal == "replicated" else math.lcm(
            ROW_ALIGN, num_devices
        )
        buf, layout = pack_pytree(tree, row_align=row_align)
        shares = _protect_flat(
            key, buf, self.scheme, self.codec.frac_bits, layout.rows,
            points=pts,
        )  # (t', R, rows, 128) uint32 — only the reveal subset exists
        if reveal == "replicated":
            summed = self.allreduce(shares, axis_name)
            flat = _reveal_flat(summed, self.scheme, self.codec.frac_bits,
                                pts)
            return unpack_pytree(flat, layout, dtype=dtype)
        tile = self.allreduce(
            shares, axis_name, scatter_axis=2
        )  # (t', R, rows / D, 128): this device's slice of the residues
        flat_tile = _reveal_flat(
            tile, self.scheme, self.codec.frac_bits, pts
        ).astype(dtype)  # decode locally, gather plaintext (dtype-sized)
        if out == "tile":
            return ShardedAggregate(flat_tile, layout, num_devices)
        flat = jax.lax.all_gather(flat_tile, axis_name, axis=0, tiled=True)
        return unpack_pytree(flat, layout, dtype=dtype)

    def psum_2d(self, tree, key: jax.Array, dtype=jnp.float32,
                pod_axis: str = POD_AXIS, share_axis: str = SHARE_AXIS,
                points: Sequence[int] | None = None):
        """Secret-shared all-reduce on a 2D (pod, share) mesh.

        Call from inside ``shard_map`` over
        :func:`repro.distributed.multihost.pod_share_mesh`.  The
        share-axis size must equal the reveal subset (default: the
        scheme threshold t).  Every (pod, share) device derives the SAME
        sharing polynomial for its pod (the rng folds only the pod
        index), keeps only its own slice, and the two collectives are

        1. uint64 psum over ``pod_axis``  — Algorithm 2 at center j;
        2. weighted uint64 psum over ``share_axis`` — the distributed
           Lagrange reveal (:func:`_distributed_reveal`).

        Bit-equal to the 1D :meth:`psum` wire: both reveal the exact
        field encoding of the global sum.
        """
        if self.backend != "pallas":
            raise ValueError("secure_psum_2d needs the flat-buffer wire "
                             "(pallas backend)")
        pts = self._validated_points(points)
        k = _compat_axis_size(share_axis)
        if k != len(pts):
            raise ValueError(
                f"share axis has {k} devices but the reveal subset is "
                f"{len(pts)} points — one center per revealed slice"
            )
        num_pods = _compat_axis_size(pod_axis)
        check_aggregation_headroom(num_pods, self.scheme.field)
        key = self.round_key(key, jax.lax.axis_index(pod_axis))
        buf, layout = pack_pytree(tree)
        shares = _protect_flat(
            key, buf, self.scheme, self.codec.frac_bits, layout.rows,
            points=pts,
        )  # (k, R, rows, 128); same on every share column of this pod
        j = jax.lax.axis_index(share_axis)
        mine = jnp.take(shares, j, axis=0)  # (R, rows, 128): center j's
        agg_slice = self.allreduce(mine, pod_axis, residue_axis=0)
        flat = _distributed_reveal(
            agg_slice, self.scheme, self.codec, pts, share_axis,
            jnp.float64,
        )
        return unpack_pytree(flat, layout, dtype=dtype)


def _secure_psum_per_leaf(tree, axis_name: str, key: jax.Array,
                          agg: SecureCollective, points: tuple[int, ...],
                          dtype):
    """The original per-leaf uint64 wire: the bit-exactness oracle.

    Protects leaf by leaf through the reference pipeline and all-reduces
    every holder's full (w, R, ...) uint64 share tree — w * R * 8 bytes
    per parameter on the wire, reconstruction on every device.  Kept (and
    parametrized in tests) as the oracle the flat-buffer wire is measured
    against; new code wants the flat path.
    """
    protected = agg.protect(key, tree)
    aggregated = jax.tree_util.tree_map(
        lambda s: _field_allreduce(s, axis_name, agg.scheme.field), protected
    )
    sel = jnp.asarray([p - 1 for p in points])
    subset = jax.tree_util.tree_map(lambda s: s[sel], aggregated)
    return agg.reveal(subset, points=points, dtype=dtype)


@_traced("secure_psum")
def secure_psum(tree, axis_name: str, key: jax.Array,
                aggregator: SecureCollective | None = None,
                dtype=jnp.float32, reveal: str = "replicated",
                points: Sequence[int] | None = None,
                out: str = "tree"):
    """Secret-shared all-reduce over a mesh axis (SPMD Algorithm 1, 11-13).

    Per device: pack the local float tree into ONE flat (rows, 128) tile
    buffer, push it through the fused fixed-point-encode + Horner-share
    kernel (fresh randomness per device via axis-index key folding), and
    reduce the uint32 share buffer over ``axis_name`` — which IS Algorithm
    2 executed by the virtual Computation Centers — then reveal + decode
    only the global sum via the fused Lagrange+CRT kernel.  Only the
    ``points`` subset of share slices (default: the first t, the unified
    reveal default) is ever evaluated or transmitted, so the wire carries
    a (t, R, rows, 128) uint32 buffer — t/w of the slices at half the
    element width of the per-leaf uint64 tree.

    ``reveal`` selects where the residues live between reduction and
    decode:

    * ``"replicated"`` — one `psum`; every device holds the full summed
      share buffer and reconstructs its own copy of the aggregate
      (programming-model convenience, the pre-sharded behavior).
    * ``"sharded"`` — `psum_scatter` over the rows axis: each device only
      ever holds a 1/D row-tile of the aggregated residues, reveals just
      that tile, and a final all-gather assembles the *decoded* float
      aggregate — the share buffer crosses the wire once instead of
      twice, cutting the all-reduce payload roughly in half (the gathered
      plaintext is ``dtype``-sized, far smaller than the share buffer).

    ``out`` selects the return shape of the sharded reveal:

    * ``"tree"`` (default) — all-gather the decoded tiles and unpack the
      full float pytree on every device (the historical behavior).
    * ``"tile"`` — skip the gather: return a :class:`ShardedAggregate`
      whose ``tile`` leaf is this device's decoded plaintext row-tile.
      ``.gather(axis_name)`` reproduces ``out="tree"`` bit-exactly;
      shard-wise consumers never pay for the assembled tree.

    Passing ``aggregator=SecureCollective(backend="reference")`` selects
    the original per-leaf uint64 wire (replicated reveal only) — the
    bit-exactness oracle.  Cryptographically, both modes only ever
    *combine* shares (never reveal an individual contribution) before the
    aggregate reconstruction, matching the paper's trust model where
    centers jointly reveal aggregates.
    """
    agg = aggregator or SecureCollective(backend="pallas")
    return agg.psum(tree, axis_name, key, dtype=dtype, reveal=reveal,
                    points=points, out=out)


def secure_psum_2d(tree, key, aggregator: SecureCollective | None = None,
                   dtype=jnp.float32, pod_axis: str = POD_AXIS,
                   share_axis: str = SHARE_AXIS,
                   points: Sequence[int] | None = None):
    """Module-level entry for the 2D (pod, share) wire; see :meth:`psum_2d`.

    Re-exported by :mod:`repro.distributed.multihost` (the historical
    home); the chain itself lives on :class:`SecureCollective`.
    """
    agg = aggregator or SecureCollective(backend="pallas")
    return agg.psum_2d(tree, key, dtype=dtype, pod_axis=pod_axis,
                       share_axis=share_axis, points=points)
