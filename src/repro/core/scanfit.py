"""Whole-fit scan residency: shared ``lax.scan`` round machinery.

PR 4 made the *selection* sweep scan-resident; this module extracts that
round-graph shape so every secure driver can use it:

* :func:`scan_rounds` — the generic skeleton: ``num_rounds`` slots of
  ``lax.cond(settled, skip, round)`` under one ``lax.scan``.  The round
  body folds the protect rng IN-GRAPH from a single key and the slot
  counter (``fold_in(key, slot)``), so a whole block of secure Newton
  rounds runs without re-entering Python: one host sync per block (the
  trace readback) instead of one per round.  Skipped slots still advance
  the slot counter, which makes the rng fold of executed round r equal
  to ``fold_in(key, r)`` regardless of how the fit was cut into blocks —
  and therefore makes ``state_dict`` resume mid-scan bit-identical to an
  uninterrupted run.
* :func:`fit_scan_block` — the single-config secure fit round under that
  skeleton: batched summaries -> batched protect -> exact uint64
  share-sum (Algorithm 2) -> reveal of the global aggregate ->
  prox/Newton update, with the ``should_stop``-driven freeze matching
  the sequential drivers' break-before-update semantics.  This is the
  graph behind ``SecureFitDriver(rounds="scan")`` and
  ``StudyCoordinator(rounds="scan")``; ``selection/path.py`` runs its
  multi-config variant through the same :func:`scan_rounds` skeleton.

rng-scheme note: the per-round drivers split a host key every round
(``key, sub = jax.random.split``) while the scan folds slots from one
fixed key.  The revealed aggregates are IDENTICAL either way — Shamir
reconstruction cancels the sharing polynomials exactly in the field, so
the revealed field elements (and hence every objective float and beta)
do not depend on the rng stream at all.  Tests pin the scanned drivers
against the per-round oracles at fixed-point-quantization tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .batched_summaries import PackedPartitions, batched_local_summaries
from .collective import SecureCollective

__all__ = ["scan_rounds", "fit_scan_block"]


def scan_rounds(round_fn, skip_fn, settled_fn, carry0, num_rounds: int):
    """``num_rounds`` round slots as ONE ``lax.scan`` with early-skip.

    Each slot runs ``round_fn(carry)`` unless ``settled_fn(carry)`` is
    already True, in which case ``skip_fn(carry)`` advances the slot for
    free — overshooting a converged fit costs nothing.  Both callables
    return ``(carry, emit)`` with identical structures (the scan's
    stacked emits are the caller's one readback per block).
    """

    def body(carry, _):
        return jax.lax.cond(settled_fn(carry), skip_fn, round_fn, carry)

    return jax.lax.scan(body, carry0, None, length=num_rounds)


@functools.partial(
    jax.jit,
    static_argnames=("agg", "protect", "l1", "tol", "interpret", "points",
                     "include_count", "summaries_backend", "num_rounds",
                     "num_parts", "max_rounds"),
)
def fit_scan_block(beta, obj_prev, converged, iters, key, round_base,
                   X, X32, y, counts, lam,
                   agg: SecureCollective, protect: str, l1: float,
                   tol: float, interpret: bool,
                   points: tuple[int, ...] | None,
                   include_count: bool, summaries_backend: str,
                   num_rounds: int, num_parts: int, max_rounds: int):
    """``num_rounds`` secure Newton rounds as ONE jitted ``lax.scan``.

    The single-λ mirror of the selection sweep's ``_cv_sweep_block``:
    every slot runs the full protect -> aggregate -> reveal -> Newton
    round in-graph, with the protect rng folded from ``(key, slot)``.
    Returns ``(carry, objs, actives, grad_norms, step_norms)`` where
    carry is ``(beta, obj_prev, converged, iters, slot)`` and the
    ``(num_rounds,)`` objective/active/metric traces are the caller's
    only host readback.  The metric leaves (||revealed global
    gradient||, ||beta_new - beta|| per executed slot; 0.0 on skipped
    slots) are ALWAYS emitted — they derive from already-revealed
    aggregates, so the graph is identical whether or not observability
    consumes them.

    Semantics pinned to the per-round drivers:

    * a round that trips ``should_stop`` keeps the beta its objective was
      measured at (break-before-update) and flips ``converged``;
    * a round that spends the last budgeted slot (``iters`` reaching
      ``max_rounds``) WITHOUT converging still applies its Newton update
      — exactly what ``SecureFitDriver.run()`` leaves behind when the
      iteration limit ends the loop;
    * ``iters`` counts executed rounds (the stopping round included),
      matching ``driver.iteration``; the slot counter advances every
      slot, executed or skipped, so the rng fold of round r is always
      ``fold_in(key, round_base + r)``.
    """
    from .newton import (
        _protected_tree,
        prox_newton_step,
        regularized_objective,
        should_stop,
    )
    from .collective import declassify_sum

    packed = PackedPartitions(X, X32, y, counts)
    scale = agg.codec.scale

    def round_fn(carry):
        beta, obj_prev, converged, iters, slot = carry
        kr = agg.round_key(key, slot)
        sm = batched_local_summaries(
            beta, packed, backend=summaries_backend, interpret=interpret,
        )
        tree = _protected_tree(protect, sm.hessian, sm.gradient,
                               sm.deviance)
        if tree and include_count:
            tree["count"] = counts.astype(jnp.float64)
        revealed = agg.secure_round_batched(kr, tree, points=points) \
            if tree else {}
        # unprotected leaves leave the round ONLY as cross-institution
        # sums — the annotated declassification the static gate checks
        H = revealed["hessian"] if protect in ("hessian", "both") \
            else declassify_sum(sm.hessian, axis=0)
        g = revealed["gradient"] if protect in ("gradient", "both") \
            else declassify_sum(sm.gradient, axis=0)
        dev = revealed["deviance"] if protect != "none" \
            else declassify_sum(sm.deviance, axis=0)
        obj = regularized_objective(dev, beta, lam, l1)
        active = ~converged & (iters < max_rounds)
        stop = should_stop(obj_prev, obj, tol, num_parts, scale)
        conv_new = converged | (active & stop)
        beta_new = prox_newton_step(
            beta, jnp.asarray(H, jnp.float64), jnp.asarray(g, jnp.float64),
            lam, l1,
        )
        freeze = conv_new | ~active
        # PUBLIC metric leaves riding the existing trace readback: both
        # derive from the revealed global aggregate, never from shares
        gnorm = jnp.linalg.norm(jnp.asarray(g, jnp.float64))
        snorm = jnp.linalg.norm(beta_new - beta)
        beta = jnp.where(freeze, beta, beta_new)
        obj_prev = jnp.where(freeze, obj_prev, obj)
        iters = iters + active.astype(jnp.int32)
        return ((beta, obj_prev, conv_new, iters, slot + 1),
                (obj, active, gnorm, snorm))

    def skip_fn(carry):
        beta, obj_prev, converged, iters, slot = carry
        zero = jnp.zeros((), jnp.float64)
        return ((beta, obj_prev, converged, iters, slot + 1),
                (obj_prev, jnp.zeros((), bool), zero, zero))

    def settled(carry):
        return carry[2] | (carry[3] >= max_rounds)

    carry0 = (beta, obj_prev, converged, iters, round_base)
    carry, (objs, actives, grad_norms, step_norms) = scan_rounds(
        round_fn, skip_fn, settled, carry0, num_rounds
    )
    return carry, objs, actives, grad_norms, step_norms
