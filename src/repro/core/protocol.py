"""Algorithm 1 as an explicit multi-party protocol with failure handling.

`newton.secure_fit` is the compact in-process form; this module models the
*deployment* shape: Institution and ComputationCenter objects exchanging
messages through a coordinator, with the fault-tolerance features a
1000-node fleet needs:

* **Straggler mitigation** — each round has a deadline; institutions that
  miss it are excluded from that round's aggregate (the sums in Eqs. 4-6 are
  over whoever responded; the Newton iterate remains a valid ascent step on
  the responding cohort, and late institutions rejoin next round).
* **Center failure tolerance** — Shamir t-of-w: any t of the w centers can
  reconstruct, so up to w-t centers may be down in a round with zero effect
  on the result.
* **Elastic membership** — institutions may join/leave between rounds; the
  coordinator re-forms the cohort each iteration.
* **Checkpoint/restart** — protocol state (beta, iteration, deviance trace,
  rng) serializes to a dict for repro.checkpoint.

Timing is simulated (per-institution latency draws) so straggler logic is
deterministic and testable without wall-clock sleeps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .logreg import local_summaries
from .newton import newton_step
from .secure_agg import SecureAggregator

__all__ = ["Institution", "ComputationCenter", "StudyCoordinator", "RoundReport"]


@dataclasses.dataclass
class Institution:
    """One data-holding party. Owns (X, y); never exports them."""

    name: str
    X: jnp.ndarray
    y: jnp.ndarray
    # simulated response latency (seconds) used for straggler decisions
    latency: float = 0.0
    online: bool = True

    def compute_and_protect(self, beta, protect: str, agg: SecureAggregator,
                            key):
        s = local_summaries(beta, self.X, self.y)
        tree = {"deviance": s.deviance, "count": s.count.astype(jnp.float64)}
        if protect in ("gradient", "both"):
            tree["gradient"] = s.gradient
        if protect in ("hessian", "both"):
            tree["hessian"] = s.hessian
        shares = agg.protect(key, tree)
        plain = {}
        if protect in ("none", "gradient"):
            plain["hessian"] = s.hessian
        if protect in ("none", "hessian"):
            plain["gradient"] = s.gradient
        if protect == "none":
            plain["deviance"] = s.deviance
            plain["count"] = s.count.astype(jnp.float64)
            shares = {}
        return shares, plain


@dataclasses.dataclass
class ComputationCenter:
    """Holds one share slice of every protected submission."""

    index: int  # 1-based Shamir evaluation point
    online: bool = True
    _stash: list = dataclasses.field(default_factory=list)

    def receive(self, share_slice):
        self._stash.append(share_slice)

    def aggregate_local(self, field):
        """Algorithm 2 run at this center: share-wise sum of its slices.

        Streams a running uint64 accumulator over the stash (exact sum +
        single mod, fused by XLA) — no (S, ...) stack of submissions is
        allocated, so a center's memory high-water mark is one slice
        regardless of cohort size.
        """
        from .secure_agg import _fold_sum_streaming

        if len(self._stash) == 1:
            return self._stash[0]
        acc = _fold_sum_streaming(tuple(self._stash), field, residue_axis=0)
        self._stash = [acc]
        return acc

    def clear(self):
        self._stash = []


@dataclasses.dataclass
class RoundReport:
    iteration: int
    responders: list
    stragglers: list
    centers_used: list
    objective: float
    bytes_transmitted: int


class StudyCoordinator:
    """Drives Algorithm 1 across institutions + centers, fault-tolerantly."""

    def __init__(
        self,
        institutions: Sequence[Institution],
        lam: float = 1.0,
        protect: str = "gradient",
        aggregator: SecureAggregator | None = None,
        num_centers: int | None = None,
        deadline: float | None = None,
        min_responders: int = 1,
        tol: float = 1e-10,
        seed: int = 0,
    ):
        self.institutions = list(institutions)
        self.lam = lam
        self.protect = protect
        self.agg = aggregator or SecureAggregator()
        w = num_centers or self.agg.scheme.num_shares
        if w != self.agg.scheme.num_shares:
            raise ValueError("num_centers must equal scheme.num_shares")
        self.centers = [ComputationCenter(i + 1) for i in range(w)]
        self.deadline = deadline
        self.min_responders = min_responders
        self.tol = tol
        self.key = jax.random.PRNGKey(seed)
        d = self.institutions[0].X.shape[1]
        self.beta = jnp.zeros((d,), dtype=jnp.float64)
        self.iteration = 0
        self.trace: list[float] = []
        self.reports: list[RoundReport] = []
        self._obj_prev = np.inf
        self.converged = False

    # -- fault/elasticity hooks ----------------------------------------------
    def cohort(self) -> list[Institution]:
        """Current-round responders: online and under the deadline."""
        live = [i for i in self.institutions if i.online]
        if self.deadline is not None:
            ok = [i for i in live if i.latency <= self.deadline]
        else:
            ok = live
        if len(ok) < self.min_responders:
            raise RuntimeError(
                f"only {len(ok)} responders < min {self.min_responders}"
            )
        return ok

    def live_centers(self) -> list[ComputationCenter]:
        up = [c for c in self.centers if c.online]
        if len(up) < self.agg.scheme.threshold:
            raise RuntimeError(
                f"{len(up)} centers < threshold {self.agg.scheme.threshold}; "
                "aggregate unrecoverable this round"
            )
        return up

    def add_institution(self, inst: Institution):
        self.institutions.append(inst)

    def remove_institution(self, name: str):
        self.institutions = [i for i in self.institutions if i.name != name]

    # -- one Newton round ------------------------------------------------------
    def step(self) -> RoundReport:
        self.iteration += 1
        cohort = self.cohort()
        stragglers = [
            i.name for i in self.institutions
            if i.online and i not in cohort
        ]
        for c in self.centers:
            c.clear()
        nbytes = 0
        plains = []
        submissions = []
        num_live = sum(1 for c in self.centers if c.online)
        w = self.agg.scheme.num_shares
        for inst in cohort:
            self.key, sub = jax.random.split(self.key)
            shares, plain = inst.compute_and_protect(
                self.beta, self.protect, self.agg, sub
            )
            plains.append(plain)
            if shares:
                submissions.append(shares)
                for w_idx, center in enumerate(self.centers):
                    if not center.online:
                        continue  # lost share slice; t-of-w absorbs it
                    center.receive(jax.tree_util.tree_map(
                        lambda s, i=w_idx: s[i], shares
                    ))
                # each online center holds one 1/w slice of the stack
                share_bytes = sum(
                    leaf.size * leaf.dtype.itemsize
                    for leaf in jax.tree_util.tree_leaves(shares)
                )
                nbytes += (share_bytes // w) * num_live
            nbytes += sum(
                leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree_util.tree_leaves(plain)
            )

        # centers run Algorithm 2 share-wise — each stacks its S received
        # slices and reduces them in one fused pass (exact in the field,
        # so bit-identical to sequential accumulation) — then >= t of
        # them jointly reconstruct the global aggregate only
        revealed = {}
        if self.protect != "none" and submissions:
            up = self.live_centers()
            agg_slices = [c.aggregate_local(self.agg.scheme.field) for c in up]
            points = [c.index for c in up]
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *agg_slices
            )
            revealed = self.agg.reveal(stacked, points=points)

        plain_sum = {
            k: sum(pl[k] for pl in plains) for k in plains[0]
        } if plains and plains[0] else {}
        merged = {**plain_sum, **revealed}
        H = jnp.asarray(merged["hessian"], jnp.float64)
        g = jnp.asarray(merged["gradient"], jnp.float64)
        dev = float(merged["deviance"])

        obj = dev + self.lam * float(jnp.sum(self.beta**2))
        self.trace.append(obj)
        quant_floor = (len(cohort) + 1) * 0.5 / self.agg.codec.scale
        if abs(self._obj_prev - obj) < max(
            self.tol * (1.0 + abs(obj)), quant_floor
        ):
            self.converged = True
        else:
            self._obj_prev = obj
            self.beta = newton_step(self.beta, H, g, self.lam)
        report = RoundReport(
            self.iteration,
            [i.name for i in cohort],
            stragglers,
            [c.index for c in self.centers if c.online],
            obj,
            nbytes,
        )
        self.reports.append(report)
        return report

    def run(self, max_iter: int = 50) -> np.ndarray:
        while not self.converged and self.iteration < max_iter:
            self.step()
        return np.asarray(self.beta)

    # -- checkpointing ----------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "beta": np.asarray(self.beta),
            "iteration": np.asarray(self.iteration),
            "obj_prev": np.asarray(self._obj_prev),
            "trace": np.asarray(self.trace),
            "key": np.asarray(self.key),
            "converged": np.asarray(self.converged),
        }

    def load_state_dict(self, state: dict):
        self.beta = jnp.asarray(state["beta"])
        self.iteration = int(state["iteration"])
        self._obj_prev = float(state["obj_prev"])
        self.trace = [float(x) for x in state["trace"]]
        self.key = jnp.asarray(state["key"], dtype=jnp.uint32)
        self.converged = bool(state["converged"])
