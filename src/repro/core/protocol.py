"""Algorithm 1 as an explicit multi-party protocol with failure handling.

`newton.secure_fit` is the compact in-process form; this module models the
*deployment* shape: Institution and ComputationCenter objects exchanging
messages through a coordinator, with the fault-tolerance features a
1000-node fleet needs:

* **Straggler mitigation** — each round has a deadline; institutions that
  miss it are excluded from that round's aggregate (the sums in Eqs. 4-6 are
  over whoever responded; the Newton iterate remains a valid ascent step on
  the responding cohort, and late institutions rejoin next round).
* **Center failure tolerance** — Shamir t-of-w: any t of the w centers can
  reconstruct, so up to w-t centers may be down in a round with zero effect
  on the result.
* **Elastic membership** — institutions may join/leave between rounds; the
  coordinator re-forms the cohort each iteration.
* **Checkpoint/restart** — protocol state (beta, iteration, deviance trace,
  rng) serializes to a dict for repro.checkpoint.

Timing is simulated (per-institution latency draws) so straggler logic is
deterministic and testable without wall-clock sleeps.

Two execution shapes for one round, selected by ``fused=``:

* **loop** (default) — the paper-shaped walk over Institution /
  ComputationCenter objects: one ``local_summaries`` + one protect
  dispatch per institution, explicit share slices at each center.  This
  is the oracle: bit-exact across secure-aggregation backends.
* **fused** — the cohort-level batched round (pallas backend only): the
  co-scheduled cohort's partitions pack ONCE (LRU-cached across churn)
  into the (S, N_max, d) layout, and the whole round — batched f64
  summaries, one encode+share launch over the S-leading flat buffers,
  single exact uint64 reduction (Algorithm 2), reveal from the *live*
  centers' slices, Newton update — runs as the same jitted graph
  ``secure_fit`` uses (``newton._fused_secure_iteration``).  Per-round
  betas match the loop oracle within fixed-point quantization; center
  dropout below threshold raises the identical ``RuntimeError``.
  ``summaries_backend="pallas"|"mixed"`` trades that per-round parity
  for f32-Gram speed (converged-beta parity only — the ``secure_fit``
  contract); see ``StudyCoordinator.__init__``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from ..obs.trace import traced as _traced
from .batched_summaries import (
    BACKENDS as SUMMARY_BACKENDS,
    pack_cache_evict,
    pack_partitions,
)
from .logreg import local_summaries
from .newton import (
    RoundReport,
    _fused_secure_iteration,
    newton_step,
    regularized_objective,
    should_stop_host,
)
from .collective import SecureCollective

__all__ = ["Institution", "ComputationCenter", "StudyCoordinator", "RoundReport"]


@dataclasses.dataclass
class Institution:
    """One data-holding party. Owns (X, y); never exports them."""

    name: str
    X: jnp.ndarray
    y: jnp.ndarray
    # simulated response latency (seconds) used for straggler decisions
    latency: float = 0.0
    online: bool = True

    def compute_and_protect(self, beta, protect: str, agg: SecureCollective,
                            key):
        s = local_summaries(beta, self.X, self.y)
        tree = {"deviance": s.deviance, "count": s.count.astype(jnp.float64)}
        if protect in ("gradient", "both"):
            tree["gradient"] = s.gradient
        if protect in ("hessian", "both"):
            tree["hessian"] = s.hessian
        shares = agg.protect(key, tree)
        plain = {}
        if protect in ("none", "gradient"):
            plain["hessian"] = s.hessian
        if protect in ("none", "hessian"):
            plain["gradient"] = s.gradient
        if protect == "none":
            plain["deviance"] = s.deviance
            plain["count"] = s.count.astype(jnp.float64)
            shares = {}
        return shares, plain


@dataclasses.dataclass
class ComputationCenter:
    """Holds one share slice of every protected submission."""

    index: int  # 1-based Shamir evaluation point
    online: bool = True
    _stash: list = dataclasses.field(default_factory=list)

    def receive(self, share_slice):
        self._stash.append(share_slice)

    @_traced("aggregate")
    def aggregate_local(self, field):
        """Algorithm 2 run at this center: share-wise sum of its slices.

        Streams a running uint64 accumulator over the stash (exact sum +
        single mod, fused by XLA) — no (S, ...) stack of submissions is
        allocated, so a center's memory high-water mark is one slice
        regardless of cohort size.
        """
        from .collective import _fold_sum_streaming

        if len(self._stash) == 1:
            return self._stash[0]
        acc = _fold_sum_streaming(tuple(self._stash), field, residue_axis=0)
        self._stash = [acc]
        return acc

    def clear(self):
        self._stash = []


# RoundReport now lives in .newton (it is shared by SecureFitDriver and the
# coordinator) and is re-exported here for the existing import surface.


# the result is cheap arithmetic; the small bound just avoids pinning
# every aggregator config a long-lived process ever constructs
@functools.lru_cache(maxsize=64)
def _round_bytes(d: int, cohort_size: int, protect: str,
                 agg: SecureCollective, num_live_centers: int) -> int:
    """Per-round wire bytes from static shapes/dtypes alone.

    Every round moves the same messages for a given (cohort size, protect
    mode, scheme) — the summary shapes never change — so the telemetry
    needs no per-leaf walk inside the round.  Delegates to the one
    ``SecureCollective.round_bytes`` size model with the coordinator wire
    protocol's two deltas: the protected tree carries the extra ``count``
    leaf, and each online center receives a 1/w slice of the share
    buffer (uint32 flat tiles on pallas, uint64 leaf tensors on
    reference).  ``tests/test_protocol.py`` pins this formula against a
    per-leaf walk of the actual messages.
    """
    return agg.round_bytes(
        d, cohort_size, protect, include_count=True,
        num_live_centers=num_live_centers,
    )


class StudyCoordinator:
    """Drives Algorithm 1 across institutions + centers, fault-tolerantly."""

    def __init__(
        self,
        institutions: Sequence[Institution],
        lam: float = 1.0,
        protect: str = "gradient",
        aggregator: SecureCollective | None = None,
        num_centers: int | None = None,
        deadline: float | None = None,
        min_responders: int = 1,
        tol: float = 1e-10,
        seed: int = 0,
        fused: bool = False,
        summaries_backend: str | None = None,
        rounds: str = "step",
        rounds_per_sync: int | None = None,
    ):
        self.institutions = list(institutions)
        self.lam = lam
        self.protect = protect
        self.agg = aggregator or SecureCollective()
        # fused rounds need the pallas flat-buffer wire format; the loop
        # stays the default because it is the bit-exact backend oracle
        if fused and self.agg.backend != "pallas":
            raise ValueError(
                "fused coordinator rounds require the pallas backend (the "
                "flat share buffers ARE the batched wire format); use "
                "fused=False with backend='reference'"
            )
        self.fused = fused
        if rounds not in ("step", "scan"):
            raise ValueError("rounds must be 'step' or 'scan'")
        if rounds == "scan" and not fused:
            raise ValueError(
                "rounds='scan' requires fused=True (the scan body IS the "
                "fused cohort round); the loop path stays per-round"
            )
        if rounds_per_sync is not None and rounds_per_sync < 1:
            raise ValueError("rounds_per_sync must be >= 1 (or None for "
                             "one scan block per run)")
        self.rounds = rounds
        self.rounds_per_sync = rounds_per_sync
        # Precision ladder for the fused round's batched summaries.
        # "reference" (default) — f64, per-ROUND beta parity with the loop
        # oracle at the f64 rounding floor (well inside fixed-point
        # quantization); the coordinator's contract.  "pallas" / "mixed" —
        # the f32-Gram kernel layouts (TPU dtype / split-accumulation):
        # measurably faster at production N, but the mid-run Newton
        # transient amplifies the f32 Hessian perturbation ~10-40x, so
        # only the CONVERGED beta (fixed by the f64 gradient, not H) is
        # guaranteed within quantization — the same relaxed contract the
        # fused ``secure_fit`` ships with.
        if summaries_backend is None:
            summaries_backend = "reference"
        if summaries_backend not in SUMMARY_BACKENDS:
            raise ValueError(
                f"summaries_backend must be one of {SUMMARY_BACKENDS}"
            )
        self.summaries_backend = summaries_backend
        # Fewer centers than shares is allowed: the scheme's remaining
        # evaluation points stay FREE, and ``provision_center`` can bring a
        # replacement up at one of them after a center failure (a fresh
        # point's share slice was never sent to the failed node).  More
        # centers than shares is impossible — there is no share to give
        # them — and fewer than t can never reconstruct.
        w = self.agg.scheme.num_shares
        n_centers = w if num_centers is None else num_centers
        if not (self.agg.scheme.threshold <= n_centers <= w):
            raise ValueError(
                f"num_centers must lie in [threshold={self.agg.scheme.threshold}, "
                f"num_shares={w}] (points beyond num_centers stay free for "
                "re-provisioning)"
            )
        self.centers = [ComputationCenter(i + 1) for i in range(n_centers)]
        # one-shot callables fired between protect and reveal of the next
        # round — the chaos harness's center-death-inside-a-round events
        self._midround_hooks: list[Callable[[], None]] = []
        self.deadline = deadline
        self.min_responders = min_responders
        self.tol = tol
        self.key = jax.random.PRNGKey(seed)
        d = self.institutions[0].X.shape[1]
        self.beta = jnp.zeros((d,), dtype=jnp.float64)
        # scan-mode rng slot counter (executed or skipped slots both
        # advance it — see core.scanfit): checkpointed for mid-scan resume
        self._round_base = 0
        self.iteration = 0
        self.trace: list[float] = []
        self.reports: list[RoundReport] = []
        self._obj_prev = np.inf
        self.converged = False
        # (grad_norm, step_norm) from the last fused round's piggybacked
        # readback; None on the loop path (no in-graph metric leaves)
        self._last_round_metrics: tuple[float, float] | None = None

    # -- fault/elasticity hooks ----------------------------------------------
    def cohort(self) -> list[Institution]:
        """Current-round responders: online and under the deadline."""
        live = [i for i in self.institutions if i.online]
        if self.deadline is not None:
            ok = [i for i in live if i.latency <= self.deadline]
        else:
            ok = live
        if len(ok) < self.min_responders:
            raise RuntimeError(
                f"only {len(ok)} responders < min {self.min_responders}"
            )
        return ok

    def live_centers(self) -> list[ComputationCenter]:
        up = [c for c in self.centers if c.online]
        if len(up) < self.agg.scheme.threshold:
            raise RuntimeError(
                f"{len(up)} centers < threshold {self.agg.scheme.threshold}; "
                "aggregate unrecoverable this round"
            )
        return up

    def add_institution(self, inst: Institution):
        # churn invalidation: no later cohort may reuse a padded batch
        # built around this institution's buffer ids.  Belt-and-braces on
        # top of the cache's identity keys + evict-on-collect weakrefs —
        # it trades a repack of the churned cohort (packs without this
        # institution stay resident) for making stale reuse structurally
        # impossible even if a caller mutates non-jax buffers in place.
        pack_cache_evict([(inst.X, inst.y)])
        self.institutions.append(inst)

    def remove_institution(self, name: str):
        gone = [i for i in self.institutions if i.name == name]
        self.institutions = [i for i in self.institutions if i.name != name]
        pack_cache_evict([(i.X, i.y) for i in gone])

    def provision_center(self, index: int | None = None) -> ComputationCenter:
        """Bring up a replacement/additional Computation Center.

        With no ``index``, prefer a FRESH evaluation point — one of the
        scheme's points in 1..w not currently assigned to any center —
        since a fresh point's share slice was never distributed to the
        failed node; fall back to replacing the lowest-indexed dead
        center in place.  Replacing at an old point is still safe:
        every round shares fresh polynomials, so a replacement center
        learns nothing about earlier rounds' secrets, and
        ``SecureCollective._validated_points`` guards every reveal
        against duplicate/out-of-range points.  The next round's shares
        are simply cut against the new point set.
        """
        w = self.agg.scheme.num_shares
        used = {c.index for c in self.centers}
        if index is None:
            free = [p for p in range(1, w + 1) if p not in used]
            if free:
                index = free[0]
            else:
                dead = [c.index for c in self.centers if not c.online]
                if not dead:
                    raise RuntimeError(
                        "no free evaluation point and no dead center to "
                        "replace"
                    )
                index = min(dead)
        if not (1 <= index <= w):
            raise ValueError(f"evaluation point must be in 1..{w}")
        fresh = ComputationCenter(index)
        if index in used:
            old = next(c for c in self.centers if c.index == index)
            if old.online:
                raise RuntimeError(
                    f"center at point {index} is still online; refusing to "
                    "replace it"
                )
            self.centers[self.centers.index(old)] = fresh
        else:
            self.centers.append(fresh)
            self.centers.sort(key=lambda c: c.index)
        return fresh

    def _fire_midround_hooks(self):
        hooks, self._midround_hooks = self._midround_hooks, []
        for h in hooks:
            h()

    # -- one Newton round ------------------------------------------------------
    @_traced("newton")
    def step(self, fused: bool | None = None) -> RoundReport:
        """One secure Newton round.  ``fused=None`` uses the constructor
        setting; an explicit value overrides it for this round only (the
        two shapes interleave freely: round state is just beta/rng)."""
        use_fused = self.fused if fused is None else fused
        if use_fused and self.agg.backend != "pallas":
            raise ValueError(
                "fused coordinator rounds require the pallas backend"
            )
        if self.rounds == "scan" and use_fused:
            # a supervised "round" in scan mode is one scan block; a raise
            # inside leaves all round state unmutated, so retries re-enter
            # at the failed block exactly like a failed per-round step
            reports = self.step_block()
            if reports:
                return reports[-1]
            if self.reports:  # stepped past convergence
                return self.reports[-1]
            raise RuntimeError("scan block executed no rounds")
        # Validate the round BEFORE mutating any state: a round that cannot
        # run (below quorum, below center threshold) must leave
        # iteration/trace/beta exactly as they were, so a supervised retry
        # or a state_dict resume replays cleanly (the counter used to
        # advance first, making every failed round an off-by-one in the
        # resumed trace).
        cohort = self.cohort()
        if self.protect != "none":
            self.live_centers()
        stragglers = [
            i.name for i in self.institutions
            if i.online and i not in cohort
        ]
        # bytes are accounted at protect time: a center that dies between
        # protect and reveal already received its slice this round
        num_live = sum(1 for c in self.centers if c.online)
        nbytes = _round_bytes(
            cohort[0].X.shape[1], len(cohort), self.protect, self.agg,
            num_live,
        )
        if use_fused:
            obj, make_beta_new = self._round_fused(cohort)
        else:
            obj, make_beta_new = self._round_loop(cohort)
        return self._finish_round(
            obj, make_beta_new, cohort, stragglers, nbytes
        )

    def _round_loop(self, cohort):
        """The per-institution oracle walk (paper-shaped deployment)."""
        self._last_round_metrics = None
        for c in self.centers:
            c.clear()
        plains = []
        submissions = []
        for inst in cohort:
            self.key, sub = jax.random.split(self.key)
            shares, plain = inst.compute_and_protect(
                self.beta, self.protect, self.agg, sub
            )
            plains.append(plain)
            if shares:
                submissions.append(shares)
                for center in self.centers:
                    if not center.online:
                        continue  # lost share slice; t-of-w absorbs it
                    # slice by the center's own evaluation point, not its
                    # list position: after re-provisioning the point set
                    # may be non-contiguous
                    center.receive(jax.tree_util.tree_map(
                        lambda s, i=center.index - 1: s[i], shares
                    ))

        # center death BETWEEN protect and reveal lands here: the one-shot
        # mid-round hooks flip liveness after the slices were distributed,
        # and live_centers() below reveals from the survivors (>= t is
        # bit-identical — any t-subset reconstructs exactly) or raises and
        # aborts the round; the retry re-shares with fresh polynomials
        self._fire_midround_hooks()

        # centers run Algorithm 2 share-wise — each stacks its S received
        # slices and reduces them in one fused pass (exact in the field,
        # so bit-identical to sequential accumulation) — then >= t of
        # them jointly reconstruct the global aggregate only
        revealed = {}
        if self.protect != "none" and submissions:
            up = self.live_centers()
            agg_slices = [c.aggregate_local(self.agg.scheme.field) for c in up]
            points = [c.index for c in up]
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *agg_slices
            )
            revealed = self.agg.reveal(stacked, points=points)

        plain_sum = {
            k: sum(pl[k] for pl in plains) for k in plains[0]
        } if plains and plains[0] else {}
        merged = {**plain_sum, **revealed}
        H = jnp.asarray(merged["hessian"], jnp.float64)
        g = jnp.asarray(merged["gradient"], jnp.float64)
        # same objective expression as the fused graph: the loop and fused
        # drivers must compare bit-identical floats in the stopping rule
        obj = float(regularized_objective(
            merged["deviance"], self.beta, self.lam
        ))
        return obj, lambda: newton_step(self.beta, H, g, self.lam)

    def _round_fused(self, cohort):
        """Cohort-level batched round: one jitted graph, one host sync.

        The co-scheduled cohort's partitions pack once into the
        (S, N_max, d) layout, LRU-cached on the part buffers: repeated
        rounds and straggler-shrunk cohorts hit the cache; packs
        containing a churned (added/removed) institution are invalidated
        by the membership hooks and rebuilt on next use.  The whole
        round runs as
        the fused ``secure_fit`` iteration with the coordinator's wire
        tree (deviance + count + protected summaries) revealed from the
        LIVE centers' share slices.  A cohort below the center threshold
        raises the same ``RuntimeError`` as the loop path — never a
        reduction over a short share axis.  ``summaries_backend`` picks
        the precision contract (see ``__init__``).
        """
        # the fused graph has no host point between protect and reveal, so
        # the mid-round death hooks fire before dispatch and the reveal
        # points are derived from the survivors — exact for the revealed
        # values (any >= t points reconstruct identically), and the same
        # abort semantics as the loop path below threshold
        self._fire_midround_hooks()
        if self.protect != "none":
            # identical failure semantics to the loop path, checked
            # BEFORE any computation so a dropped center can't be
            # silently absorbed by revealing from a default prefix
            points = tuple(c.index for c in self.live_centers())
        else:
            points = None
        packed = pack_partitions([(i.X, i.y) for i in cohort])
        self.key, sub = jax.random.split(self.key)
        beta_new, obj, grad_norm, step_norm = _fused_secure_iteration(
            self.beta, sub, packed.X, packed.X32, packed.y, packed.counts,
            self.lam, self.agg, self.protect, 0.0,
            self.agg.scheme.interpret, points=points, include_count=True,
            summaries_backend=self.summaries_backend,
        )
        # host-sync: the round's one readback (secure_fit's twin) —
        # objective plus the PUBLIC in-graph metric leaves, one transfer
        obj, grad_norm, step_norm = jax.device_get(
            (obj, grad_norm, step_norm)
        )
        self._last_round_metrics = (float(grad_norm), float(step_norm))
        return float(obj), lambda: beta_new

    # -- scan-resident blocks --------------------------------------------------
    @_traced("newton")
    def step_block(self, num_rounds: int | None = None
                   ) -> list[RoundReport]:
        """Up to ``num_rounds`` fused cohort rounds as ONE ``lax.scan``.

        The deployment-shaped twin of ``SecureFitDriver.step_block``: the
        whole block runs as a single jitted graph (in-graph rng folds,
        ``should_stop``-driven freeze), with one host sync — the block's
        trace readback — from which the per-round ``RoundReport`` records
        are rebuilt through the same ``_finish_round`` bookkeeping the
        per-round paths use.  The cohort and live centers are frozen for
        the block; mid-round death hooks fire before dispatch (the fused
        path's usual approximation — exact for the revealed values) and a
        below-threshold block raises with all round state unmutated.
        Default block length: ``rounds_per_sync``, or the remaining
        ``run()`` budget (one sync per study).
        """
        if self.rounds != "scan":
            raise RuntimeError("step_block requires rounds='scan'")
        from .scanfit import fit_scan_block

        cohort = self.cohort()
        if self.protect != "none":
            self.live_centers()
        stragglers = [
            i.name for i in self.institutions
            if i.online and i not in cohort
        ]
        num_live = sum(1 for c in self.centers if c.online)
        d = cohort[0].X.shape[1]
        nbytes = _round_bytes(d, len(cohort), self.protect, self.agg,
                              num_live)
        if num_rounds is None:
            # 50 is run()'s default max_iter — the whole-study budget
            num_rounds = self.rounds_per_sync or max(50 - self.iteration, 1)
        self._fire_midround_hooks()
        if self.protect != "none":
            points = tuple(c.index for c in self.live_centers())
        else:
            points = None
        packed = pack_partitions([(i.X, i.y) for i in cohort])
        carry, objs, actives, gnorms, snorms = fit_scan_block(
            self.beta,
            jnp.asarray(self._obj_prev, jnp.float64),
            jnp.asarray(self.converged),
            jnp.zeros((), jnp.int32),
            self.key,
            jnp.asarray(self._round_base, jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts, self.lam,
            agg=self.agg, protect=self.protect, l1=0.0,
            tol=float(self.tol), interpret=self.agg.scheme.interpret,
            points=points, include_count=True,
            summaries_backend=self.summaries_backend,
            num_rounds=num_rounds, num_parts=len(cohort),
            max_rounds=num_rounds,
        )
        # host-sync: the block's ONE readback — trace + metric leaves +
        # scalar carry in a single transfer (beta stays on device)
        objs, actives, gnorms, snorms, obj_prev_h, conv_h, base_h = \
            jax.device_get(
                (objs, actives, gnorms, snorms,
                 carry[1], carry[2], carry[4])
            )
        new_reports: list[RoundReport] = []
        for r in range(num_rounds):
            if not actives[r]:
                break
            self.iteration += 1
            self.trace.append(float(objs[r]))
            new_reports.append(RoundReport(
                self.iteration,
                [i.name for i in cohort],
                stragglers,
                [c.index for c in self.centers if c.online],
                float(objs[r]),
                nbytes,
                grad_norm=float(gnorms[r]),
                step_norm=float(snorms[r]),
            ))
            self.reports.append(new_reports[-1])
            _metrics.observe_round(
                "coordinator_scan", nbytes,
                objective=float(objs[r]),
                grad_norm=float(gnorms[r]), step_norm=float(snorms[r]),
            )
        self.beta = carry[0]
        self._obj_prev = float(obj_prev_h)
        self.converged = bool(conv_h)
        self._round_base = int(base_h)
        return new_reports

    def _finish_round(self, obj, make_beta_new, cohort, stragglers,
                      nbytes) -> RoundReport:
        """Convergence bookkeeping shared verbatim by both round shapes.

        The ONLY place round state mutates: a raise anywhere earlier in
        ``step`` leaves the coordinator exactly as it was.
        """
        self.iteration += 1
        self.trace.append(obj)
        if should_stop_host(self._obj_prev, obj, self.tol, len(cohort),
                            self.agg.codec.scale):
            self.converged = True
        else:
            self._obj_prev = obj
            self.beta = make_beta_new()
        gn, sn = self._last_round_metrics or (0.0, 0.0)
        report = RoundReport(
            self.iteration,
            [i.name for i in cohort],
            stragglers,
            [c.index for c in self.centers if c.online],
            obj,
            nbytes,
            grad_norm=gn,
            step_norm=sn,
        )
        self.reports.append(report)
        _metrics.observe_round(
            "coordinator", nbytes, objective=obj,
            grad_norm=gn if self._last_round_metrics else None,
            step_norm=sn if self._last_round_metrics else None,
        )
        return report

    def run(self, max_iter: int = 50) -> np.ndarray:
        while not self.converged and self.iteration < max_iter:
            if self.rounds == "scan" and self.fused:
                block = self.rounds_per_sync or (max_iter - self.iteration)
                self.step_block(min(block, max_iter - self.iteration))
            else:
                self.step()
        return np.asarray(self.beta)

    # -- checkpointing ----------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "beta": np.asarray(self.beta),
            "iteration": np.asarray(self.iteration),
            "obj_prev": np.asarray(self._obj_prev),
            "trace": np.asarray(self.trace),
            "key": np.asarray(self.key),
            "converged": np.asarray(self.converged),
            "round_base": np.asarray(self._round_base),
        }

    def load_state_dict(self, state: dict):
        self.beta = jnp.asarray(state["beta"])
        self.iteration = int(state["iteration"])
        self._obj_prev = float(state["obj_prev"])
        self.trace = [float(x) for x in state["trace"]]
        self.key = jnp.asarray(state["key"], dtype=jnp.uint32)
        self.converged = bool(state["converged"])
        # pre-scan checkpoints: slots == executed rounds in step mode
        self._round_base = int(state.get("round_base", state["iteration"]))
