"""Newton-Raphson drivers: centralized gold standard + secure distributed.

``centralized_fit`` is the oracle the paper compares against (Fig. 2's "gold
standard", i.e. what R's glmnet-style IRLS would produce).  ``secure_fit``
runs the paper's Algorithm 1: per-institution summaries -> Shamir protection
-> share-wise aggregation at the Computation Centers -> reconstruction of the
*global* aggregate only -> Newton update (Eq. 3) -> deviance-based
convergence check.  Both converge to the same beta (R^2 = 1.00, Fig. 2);
tests assert this to ~1e-6 which is far below the fixed-point quantization
we configure.

Two execution shapes for the secure loop:

* **fused** (default on the pallas backend) — the whole iteration is one
  jitted graph: a single batched fused-IRLS launch over all S (ragged)
  institutions, one batched protect launch over the S flat buffers, one
  exact uint64 reduction for Algorithm 2, one reveal, and the Newton/prox
  update — the only host sync per iteration is the scalar deviance read
  for the convergence test.
* **loop** (reference backend, or ``fused=False``) — the paper-shaped
  Python loop over institutions, one protect per institution.  Kept as
  the correctness comparator and as the pre-fusion baseline that
  ``benchmarks/e2e_secure_fit.py`` measures against.

On top of the per-round shapes, ``SecureFitDriver(rounds="scan")`` runs
whole BLOCKS of fused rounds as one ``lax.scan`` (``core.scanfit``): the
protect rng folds in-graph from a single key, convergence freezes the
carry via ``lax.cond``, and the objective trace reads back once per
block — one host sync per fit (``rounds_per_sync=None``) instead of one
per round.  The per-round paths stay as the bit-exact oracles; tests
pin the scanned trajectory against them at quantization tolerance.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .batched_summaries import (
    BACKENDS as SUMMARY_BACKENDS,
    PackedPartitions,
    batched_local_summaries,
    pack_partitions,
)
from ..obs import metrics as _metrics
from ..obs.trace import traced as _traced
from .logreg import LocalSummaries, local_summaries, deviance
from .collective import SecureCollective, declassify_sum

__all__ = ["FitResult", "RoundReport", "newton_step", "prox_newton_step",
           "centralized_fit", "secure_fit", "SecureFitDriver",
           "regularized_objective", "stop_threshold", "should_stop",
           "stop_threshold_host", "should_stop_host"]

PROTECT_CHOICES = ("none", "gradient", "hessian", "both")


# -- the one stopping rule -----------------------------------------------------
#
# Every secure driver (secure_fit loop + fused, StudyCoordinator loop +
# fused rounds, and the selection sweep's in-graph scan) terminates on the
# SAME deviance test, computed from identically-formed objectives.  Before
# unification the loop drivers summed ``float(dev) + lam * float(...)`` in
# host Python while the fused graph summed in one jnp expression — a
# 1-ulp objective difference that could flip the iteration count when a
# tolerance landed exactly on a round's deviance delta.  All helpers are
# jnp-traceable (they vectorize over a config axis inside the selection
# scan) and exact for host floats.

def regularized_objective(dev, beta, lam, l1=0.0):
    """The convergence objective at beta: deviance + lam ||b||^2 (+ L1).

    ``beta`` may carry a leading config axis (objective per config); lam
    broadcasts (per-config lambda on the selection path).  Every driver
    forms its objective through this one expression so the stopping test
    below compares bit-identical floats across execution shapes.
    """
    beta = jnp.asarray(beta, jnp.float64)
    return (jnp.asarray(dev, jnp.float64)
            + lam * jnp.sum(beta**2, axis=-1)
            + 2.0 * l1 * jnp.sum(jnp.abs(beta), axis=-1))


def stop_threshold(obj, tol: float, num_parts: int, scale: float):
    """max(relative tolerance, fixed-point quantization floor).

    The deviance travels through the fixed-point codec, so no driver may
    test convergence tighter than the aggregate quantization of S
    institution deviances plus the revealed sum ((S+1) half-ulps at
    ``scale`` fractional resolution).
    """
    quant_floor = (num_parts + 1) * 0.5 / scale
    return jnp.maximum(tol * (1.0 + jnp.abs(obj)), quant_floor)


def should_stop(obj_prev, obj, tol: float, num_parts: int, scale: float):
    """True when |obj_prev - obj| clears the shared threshold."""
    return jnp.abs(obj_prev - obj) < stop_threshold(obj, tol, num_parts,
                                                    scale)


def stop_threshold_host(obj: float, tol: float, num_parts: int,
                        scale: float) -> float:
    """Pure-host twin of ``stop_threshold`` (IEEE-identical for floats).

    The per-round drivers test convergence on an objective that is
    ALREADY a host float (the round's one sync); routing it back through
    the jnp version cost a device round-trip per round for scalar
    arithmetic.  Same expression, same f64 semantics — a test pins the
    two bit-equal across a value grid including inf.
    """
    quant_floor = (num_parts + 1) * 0.5 / scale
    return max(tol * (1.0 + abs(obj)), quant_floor)


def should_stop_host(obj_prev: float, obj: float, tol: float,
                     num_parts: int, scale: float) -> bool:
    """Pure-host twin of ``should_stop`` for already-synced objectives."""
    return abs(obj_prev - obj) < stop_threshold_host(obj, tol, num_parts,
                                                     scale)


@dataclasses.dataclass
class FitResult:
    beta: np.ndarray
    iterations: int
    converged: bool
    deviance_trace: list
    # telemetry for Table 1 style reporting
    central_seconds: float = 0.0
    total_seconds: float = 0.0
    bytes_transmitted: int = 0


@dataclasses.dataclass
class RoundReport:
    """One secure round's audit record, shared by every driver.

    The first six fields are the per-round protocol telemetry; the
    trailing fault-supervision fields are filled in by
    ``runtime.supervisor.RoundSupervisor`` — an unsupervised round
    reports the fault-free defaults (no retries, no backoff, not
    degraded).
    """

    iteration: int
    responders: list
    stragglers: list
    centers_used: list
    objective: float
    bytes_transmitted: int
    retries: int = 0
    backoff_seconds: float = 0.0
    aborted_attempts: int = 0
    degraded: bool = False
    # PUBLIC in-graph metric leaves, piggybacked on the round's one
    # marked host sync (0.0 on paths that don't compute them in-graph)
    grad_norm: float = 0.0
    step_norm: float = 0.0


def newton_step(
    beta: jnp.ndarray,
    hessian: jnp.ndarray,
    gradient: jnp.ndarray,
    lam: float,
) -> jnp.ndarray:
    """Eq. 3: beta + (X^T W X + lam I)^{-1} (g - lam beta).

    This is the "securely derive beta_new" step (Algorithm 1, line 15)
    which operates on *revealed global aggregates* plus public
    lambda/beta.  The regularized Hessian is SPD, but at protocol-scale d
    the dense solve is sub-millisecond either way and the plain solve
    lowers to one LAPACK call — the Cholesky/cho_solve pair costs several
    custom-call round trips per iteration for no measurable accuracy or
    speed gain at d <= 512.
    """
    d = beta.shape[0]
    A = hessian + lam * jnp.eye(d, dtype=hessian.dtype)
    rhs = gradient - lam * beta
    return beta + jnp.linalg.solve(A, rhs)


def _soft_threshold(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def prox_newton_step(
    beta: jnp.ndarray,
    hessian: jnp.ndarray,
    gradient: jnp.ndarray,
    lam: float,
    l1: float,
    inner_steps: int = 200,
) -> jnp.ndarray:
    """Proximal Newton step for elastic-net logistic regression.

    The paper notes L1 support "is also possible" (Materials & Methods);
    crucially the *institution-side protocol is unchanged* — H_j and g_j
    are the same secret-shared summaries — only the Computation Centers'
    solver differs.  We minimize the local quadratic model

        m(b) = -g^T (b - beta) + 1/2 (b - beta)^T H (b - beta)
               + lam/2 ||b||^2 + l1 ||b||_1

    with FISTA (d x d problem, trivially cheap at the center; runs on
    *revealed global aggregates* only, like newton_step).  l1 = 0 reduces
    exactly to the L2 Newton step.
    """
    if l1 == 0.0:
        return newton_step(beta, hessian, gradient, lam)
    d = beta.shape[0]
    A = hessian + lam * jnp.eye(d, dtype=hessian.dtype)
    # Lipschitz constant of the quadratic part
    L = jnp.linalg.norm(A, 2) + 1e-12
    # gradient of the smooth part at b: A (b - beta) - g + lam*beta
    #   (expand: H(b-beta) + lam*b - g ... careful) — derive:
    #   m_smooth(b) = -g^T(b-beta) + .5 (b-beta)^T H (b-beta) + lam/2 b^T b
    #   grad = -g + H (b - beta) + lam b

    def grad_smooth(b):
        return -gradient + hessian @ (b - beta) + lam * b

    def fista(carry, _):
        b, z, t = carry
        b_new = _soft_threshold(z - grad_smooth(z) / L, l1 / L)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = b_new + ((t - 1.0) / t_new) * (b_new - b)
        return (b_new, z_new, t_new), None

    (b, _, _), _ = jax.lax.scan(
        fista, (beta, beta, jnp.asarray(1.0, beta.dtype)), None,
        length=inner_steps,
    )
    return b


def centralized_fit(
    X: jnp.ndarray,
    y: jnp.ndarray,
    lam: float = 1.0,
    tol: float = 1e-10,
    max_iter: int = 50,
) -> FitResult:
    """Gold-standard pooled IRLS (no privacy) for accuracy comparison."""
    d = X.shape[1]
    beta = jnp.zeros((d,), dtype=jnp.float64)
    dev_prev = np.inf
    trace: list[float] = []
    converged = False
    it = 0
    for it in range(1, max_iter + 1):
        s = local_summaries(beta, X, y)
        # regularized objective at the *current* beta (same ordering as the
        # secure protocol, where dev_j arrives with the summaries)
        obj = float(regularized_objective(s.deviance, beta, lam))
        trace.append(obj)
        if abs(dev_prev - obj) < tol * (1.0 + abs(obj)):
            converged = True
            break
        dev_prev = obj
        beta = newton_step(beta, s.hessian, s.gradient, lam)
    return FitResult(np.asarray(beta), it, converged, trace)


def _protected_tree(protect: str, hessian, gradient, dev):
    """The leaves Algorithm 1 secret-shares under a given protect mode."""
    tree = {}
    if protect in ("gradient", "both"):
        tree["gradient"] = gradient
    if protect in ("hessian", "both"):
        tree["hessian"] = hessian
    if protect != "none":
        tree["deviance"] = dev
    return tree


def _iteration_bytes(d: int, num_parts: int, protect: str,
                     agg: SecureCollective, include_count: bool = False,
                     num_live_centers: int | None = None,
                     num_configs: int = 1, extra_scalars: int = 0) -> int:
    """Per-iteration wire bytes (compat shim).

    The one static size model now lives on
    :meth:`repro.core.collective.SecureCollective.round_bytes`; this
    keeps the historical free-function signature working.
    """
    return agg.round_bytes(
        d, num_parts, protect, include_count=include_count,
        num_live_centers=num_live_centers, num_configs=num_configs,
        extra_scalars=extra_scalars,
    )


@functools.partial(
    jax.jit, static_argnames=("agg", "protect", "l1", "interpret", "points",
                              "include_count", "summaries_backend")
)
def _fused_secure_iteration(beta, key, X, X32, y, counts, lam,
                            agg: SecureCollective, protect: str, l1: float,
                            interpret: bool,
                            points: tuple[int, ...] | None = None,
                            include_count: bool = False,
                            summaries_backend: str = "pallas"):
    """One whole secure Newton iteration as a single jitted graph.

    batched summaries -> batched protect (ONE encode+share launch over the
    S-leading flat buffers) -> single exact uint64 reduction over the
    institution axis (Algorithm 2) -> reveal of the *global* aggregate
    only -> prox/Newton update.  Returns ``(beta_new, objective,
    grad_norm, step_norm)``; the caller reads the three PUBLIC scalars
    back in the round's ONE host sync.  The metric leaves (||revealed
    global gradient||, ||beta_new - beta||) are ALWAYS computed — they
    derive from already-revealed aggregates, adding no declassification
    — so the graph is identical whether or not observability consumes
    them (the tracing-disabled bit-parity gate in
    ``benchmarks/obs_overhead.py`` relies on this).

    ``points``/``include_count``/``summaries_backend`` are the coordinator
    hooks: the fused ``StudyCoordinator.step`` reveals from its *live*
    centers' share slices (any >= t of the w points), mirrors the wire
    protocol's protected ``count`` leaf, and selects the summaries
    precision — "reference" (f64) for per-round parity with the loop
    oracle (the mid-run Newton transient amplifies Hessian perturbation
    ~10-40x, so f32-Gram backends hold only converged-beta parity),
    "pallas"/"mixed" for f32-Gram speed under that relaxed contract.
    """
    packed = PackedPartitions(X, X32, y, counts)
    sm = batched_local_summaries(
        beta, packed, backend=summaries_backend, interpret=interpret
    )
    hessian, gradient, dev = sm.hessian, sm.gradient, sm.deviance
    revealed = {}
    tree = _protected_tree(protect, hessian, gradient, dev)
    if tree and include_count:
        tree["count"] = counts.astype(jnp.float64)
    if tree:
        revealed = agg.secure_round_batched(key, tree, points=points)
    # unprotected leaves still only ever leave as cross-institution sums:
    # the annotated declassification the static taint gate certifies
    global_h = revealed["hessian"] if protect in ("hessian", "both") \
        else declassify_sum(hessian, axis=0)
    global_g = revealed["gradient"] if protect in ("gradient", "both") \
        else declassify_sum(gradient, axis=0)
    global_dev = revealed["deviance"] if protect != "none" \
        else declassify_sum(dev, axis=0)
    obj = regularized_objective(global_dev, beta, lam, l1)
    beta_new = prox_newton_step(
        beta, jnp.asarray(global_h, jnp.float64),
        jnp.asarray(global_g, jnp.float64), lam, l1,
    )
    grad_norm = jnp.linalg.norm(jnp.asarray(global_g, jnp.float64))
    step_norm = jnp.linalg.norm(beta_new - beta)
    return beta_new, obj, grad_norm, step_norm


class SecureFitDriver:
    """Stepwise Algorithm 1 with membership, liveness and crash-resume.

    ``secure_fit`` packs the whole fit into one call; this driver exposes
    the same computation round by round with the fault surface the
    deployment-shaped ``protocol.StudyCoordinator`` already has, so the
    ``runtime.supervisor.RoundSupervisor`` can drive all three secure
    drivers through one interface:

    * ``step()`` — one secure Newton round over the currently-responding
      institutions (online and under ``deadline``), revealed from the
      live centers' evaluation points.  An unrunnable round (fewer than
      ``min_responders`` institutions, fewer than t live centers) raises
      ``RuntimeError`` and leaves the fit state untouched, so a failed
      round can be retried or resumed cleanly.
    * ``state_dict()``/``load_state_dict()`` — a resumed driver replays
      BIT-identically (same rng stream, same trace floats) against an
      uninterrupted run: the coordinator-crash story.
    * liveness hooks — ``set_online``/``set_latency`` per institution
      name, ``set_center_online`` per evaluation point, and
      ``_midround_hooks`` (one-shot callables fired between protect and
      reveal) for center death inside a round: if >= t centers survive
      the round reveals from the survivors (bit-identical — any t-subset
      reconstructs exactly); below t it aborts with ``RuntimeError`` and
      the retry re-shares with fresh polynomials.

    A driver with every institution online, zero latencies and all
    centers live executes the exact ``secure_fit`` iteration sequence —
    same rng splits, same objective floats, same byte accounting — which
    is what lets ``secure_fit`` delegate here without disturbing its
    pinned parity tests.
    """

    def __init__(
        self,
        parts: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
        lam: float = 1.0,
        tol: float = 1e-10,
        max_iter: int = 50,
        protect: str = "gradient",
        aggregator: SecureCollective | None = None,
        seed: int = 0,
        l1: float = 0.0,
        fused: bool | None = None,
        names: Sequence[str] | None = None,
        deadline: float | None = None,
        min_responders: int = 1,
        rounds: str = "step",
        rounds_per_sync: int | None = None,
        summaries_backend: str | None = None,
    ):
        if protect not in PROTECT_CHOICES:
            raise ValueError(f"protect must be one of {PROTECT_CHOICES}")
        self.agg = aggregator or SecureCollective()
        if fused is None:
            fused = self.agg.backend == "pallas"
        if fused and self.agg.backend != "pallas":
            raise ValueError(
                "fused secure_fit requires the pallas backend (the flat "
                "share buffers ARE its wire format); use fused=False with "
                "backend='reference'"
            )
        self.fused = fused
        if rounds not in ("step", "scan"):
            raise ValueError("rounds must be 'step' or 'scan'")
        if rounds == "scan" and not fused:
            raise ValueError(
                "rounds='scan' requires the fused pallas path (the scan "
                "body IS the fused iteration graph); use rounds='step' "
                "with fused=False for the loop oracle"
            )
        if rounds_per_sync is not None and rounds_per_sync < 1:
            raise ValueError("rounds_per_sync must be >= 1 (or None for "
                             "one scan block per fit)")
        self.rounds = rounds
        self.rounds_per_sync = rounds_per_sync
        # the fused iteration's summaries precision rung; None keeps the
        # historical fused-secure_fit default (the f32-Gram kernel rung,
        # converged-beta parity contract — see _fused_secure_iteration)
        if summaries_backend is None:
            summaries_backend = "pallas"
        if summaries_backend not in SUMMARY_BACKENDS:
            raise ValueError(
                f"summaries_backend must be one of {SUMMARY_BACKENDS}"
            )
        self.summaries_backend = summaries_backend
        self.parts = list(parts)
        self.names = (list(names) if names is not None
                      else [f"inst{j}" for j in range(len(self.parts))])
        if len(self.names) != len(self.parts):
            raise ValueError("names must match parts 1:1")
        self.lam = lam
        self.tol = tol
        self.max_iter = max_iter
        self.protect = protect
        self.l1 = float(l1)
        self.deadline = deadline
        self.min_responders = min_responders
        self.dim = self.parts[0][0].shape[1]
        self.online = [True] * len(self.parts)
        self.latency = [0.0] * len(self.parts)
        self.centers_online = [True] * self.agg.scheme.num_shares
        self._midround_hooks: list[Callable[[], None]] = []
        self.key = jax.random.PRNGKey(seed)
        self.beta = jnp.zeros((self.dim,), dtype=jnp.float64)
        # scan-mode rng slot counter: executed OR skipped scan slots both
        # advance it, so round r's in-graph fold is fold_in(key, r)
        # regardless of how the fit was cut into blocks (what makes
        # mid-scan resume bit-identical to an uninterrupted run)
        self._round_base = 0
        self.iteration = 0
        self.trace: list[float] = []
        self.reports: list[RoundReport] = []
        self._obj_prev = np.inf
        self.converged = False
        # (grad_norm, step_norm) from the last fused round's piggybacked
        # readback; None on the loop path (no in-graph metric leaves)
        self._last_round_metrics: tuple[float, float] | None = None
        self.central_seconds = 0.0
        self.total_seconds = 0.0
        self.bytes_transmitted = 0

    # -- liveness hooks (names mirror the supervisor's driver interface) ----
    def _idx(self, name: str) -> int:
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"unknown institution {name!r}") from None

    def set_online(self, name: str, up: bool):
        self.online[self._idx(name)] = bool(up)

    def set_latency(self, name: str, latency: float):
        self.latency[self._idx(name)] = float(latency)

    def get_latency(self, name: str) -> float:
        return self.latency[self._idx(name)]

    def set_center_online(self, index: int, up: bool):
        if not (1 <= index <= len(self.centers_online)):
            raise ValueError(f"no center at evaluation point {index}")
        self.centers_online[index - 1] = bool(up)

    def cohort_indices(self) -> list[int]:
        """Current-round responders: online and under the deadline."""
        ok = [
            j for j in range(len(self.parts))
            if self.online[j]
            and (self.deadline is None or self.latency[j] <= self.deadline)
        ]
        if len(ok) < self.min_responders:
            raise RuntimeError(
                f"only {len(ok)} responders < min {self.min_responders}"
            )
        return ok

    def live_points(self) -> tuple[int, ...] | None:
        """Live centers' evaluation points (None when nothing is shared)."""
        if self.protect == "none":
            return None
        pts = tuple(
            i + 1 for i, up in enumerate(self.centers_online) if up
        )
        t = self.agg.scheme.threshold
        if len(pts) < t:
            raise RuntimeError(
                f"{len(pts)} centers < threshold {t}; "
                "aggregate unrecoverable this round"
            )
        return pts

    def _post_protect_points(self, points):
        """Re-check center liveness between protect and reveal.

        Fires the one-shot mid-round hooks (the chaos harness's
        center-death-inside-a-round events), then re-derives the reveal
        points from whoever is STILL online: >= t survivors reveal
        bit-identically; below t raises and the round aborts — the retry
        re-shares against fresh polynomials, so nothing about the aborted
        round's secrets is ever reconstructable.
        """
        hooks, self._midround_hooks = self._midround_hooks, []
        for h in hooks:
            h()
        if points is None:
            return None
        return self.live_points()

    # -- one Newton round ---------------------------------------------------
    @_traced("newton")
    def step(self) -> RoundReport:
        if self.rounds == "scan":
            # a supervised "round" in scan mode is one scan block: the
            # supervisor's retry re-enters at the failed block (a raise
            # below leaves ALL fit state unmutated, exactly like a failed
            # per-round step)
            reports = self.step_block()
            if reports:
                return reports[-1]
            if self.reports:  # stepped past convergence: nothing executed
                return self.reports[-1]
            raise RuntimeError("scan block executed no rounds")
        # validate the round BEFORE mutating any fit state: a failed round
        # must leave iteration/trace/beta untouched (rng advances only once
        # shares have actually been cut)
        cohort = self.cohort_indices()
        points = self.live_points()
        parts = [self.parts[j] for j in cohort]
        in_cohort = set(cohort)
        stragglers = [
            self.names[j] for j in range(len(self.parts))
            if self.online[j] and j not in in_cohort
        ]
        num_live = None if points is None else len(points)
        nbytes = self.agg.round_bytes(
            self.dim, len(parts), self.protect,
            num_live_centers=num_live,
        )
        if self.fused:
            obj, make_beta_new = self._round_fused(parts, points)
        else:
            obj, make_beta_new = self._round_loop(parts, points)
        # ---- the round is known-good: mutate state (mirrors
        #      StudyCoordinator._finish_round)
        self.iteration += 1
        self.trace.append(obj)
        self.bytes_transmitted += nbytes
        if should_stop_host(self._obj_prev, obj, self.tol, len(parts),
                            self.agg.codec.scale):
            self.converged = True
        else:
            self._obj_prev = obj
            self.beta = make_beta_new()
        gn, sn = self._last_round_metrics or (0.0, 0.0)
        report = RoundReport(
            self.iteration,
            [self.names[j] for j in cohort],
            stragglers,
            list(points or ()),
            obj,
            nbytes,
            grad_norm=gn,
            step_norm=sn,
        )
        self.reports.append(report)
        _metrics.observe_round(
            "secure_fit", nbytes, objective=obj,
            grad_norm=gn if self._last_round_metrics else None,
            step_norm=sn if self._last_round_metrics else None,
        )
        return report

    def _round_loop(self, parts, points):
        """The per-institution oracle walk (Algorithm 1 steps 3-16)."""
        self._last_round_metrics = None
        locals_: list[LocalSummaries] = [
            local_summaries(self.beta, Xj, yj) for Xj, yj in parts
        ]
        protected, plain = [], []
        for s in locals_:
            tree = _protected_tree(self.protect, s.hessian, s.gradient,
                                   s.deviance)
            self.key, sub = jax.random.split(self.key)
            protected.append(self.agg.protect(sub, tree) if tree else {})
            plain.append(
                {
                    k: v
                    for k, v in s._asdict().items()
                    if k not in tree and k != "count"
                }
            )

        # ---- centralized phase (Computation Centers, steps 11-16)
        t0 = time.perf_counter()
        revealed = {}
        if self.protect != "none":
            agg_protected = self.agg.aggregate(protected)
            pts = self._post_protect_points(points)
            if len(pts) < self.agg.scheme.num_shares:
                # non-contiguous survivor subset: slice the share axis to
                # the live points and reveal from them explicitly
                sel = jnp.asarray([p - 1 for p in pts])
                sliced = jax.tree_util.tree_map(
                    lambda sh: sh[sel], agg_protected
                )
                revealed = self.agg.reveal(sliced, points=list(pts))
            else:
                revealed = self.agg.reveal(agg_protected)
        else:
            self._post_protect_points(points)
        summed_plain = {
            k: sum(pl[k] for pl in plain) for k in plain[0]
        } if plain and plain[0] else {}
        global_h = revealed.get("hessian", summed_plain.get("hessian"))
        global_g = revealed.get("gradient", summed_plain.get("gradient"))
        global_dev = revealed.get("deviance", summed_plain.get("deviance"))
        # regularized objective at the current beta (summaries' beta) —
        # formed through the same expression as the fused graph so both
        # drivers compare bit-identical floats at the tolerance boundary
        obj = float(regularized_objective(global_dev, self.beta, self.lam,
                                          self.l1))
        self.central_seconds += time.perf_counter() - t0

        def make_beta_new():
            t1 = time.perf_counter()
            beta_new = prox_newton_step(
                self.beta,
                jnp.asarray(global_h, jnp.float64),
                jnp.asarray(global_g, jnp.float64),
                self.lam,
                self.l1,
            )
            self.central_seconds += time.perf_counter() - t1
            return beta_new

        return obj, make_beta_new

    def _round_fused(self, parts, points):
        """One fused jitted iteration (one dispatch + one host sync).

        X keeps the float64 payload: at protocol scale the f32-storage
        variant (``pack_partitions(..., dtype=jnp.float32)``, the TPU
        layout) lands right AT the fixed-point quantization boundary
        against the f64 loop path, while costing the same wall-clock here
        — the f64 gemvs are bandwidth-bound either way.  The pack is
        LRU-cached on the part buffers, so repeated rounds and
        straggler-shrunk cohorts don't re-pack.

        The fused graph has no host point between protect and reveal, so
        the mid-round hooks fire (and the reveal points re-derive) just
        before dispatch — an approximation that is exact for the revealed
        values, since reconstruction from any >= t points is the same
        field arithmetic wherever it happens.
        """
        packed = pack_partitions(parts)
        pts = self._post_protect_points(points)
        if pts is not None and len(pts) == self.agg.scheme.num_shares:
            # all centers live: the default first-t reveal secure_fit
            # always used (and the cache-friendliest static points value)
            pts = None
        self.key, sub = jax.random.split(self.key)
        beta_new, obj, grad_norm, step_norm = _fused_secure_iteration(
            self.beta, sub, packed.X, packed.X32, packed.y, packed.counts,
            self.lam, self.agg, self.protect, self.l1,
            self.agg.scheme.interpret, points=pts,
            summaries_backend=self.summaries_backend,
        )
        # host-sync: the one readback per fused iteration — objective plus
        # the PUBLIC in-graph metric leaves, one transfer
        obj, grad_norm, step_norm = jax.device_get(
            (obj, grad_norm, step_norm)
        )
        self._last_round_metrics = (float(grad_norm), float(step_norm))
        return float(obj), lambda: beta_new

    # -- scan-resident blocks ------------------------------------------------
    @_traced("newton")
    def step_block(self, num_rounds: int | None = None
                   ) -> list[RoundReport]:
        """Up to ``num_rounds`` secure rounds as ONE ``lax.scan`` dispatch.

        The whole block — protect, Algorithm 2 aggregation, reveal and
        Newton update for every round, with the rng folded in-graph and
        convergence freezing the carry — runs as a single jitted graph;
        the only host sync is the block's (objective, active) trace
        readback, from which the per-round ``RoundReport`` records are
        reconstructed.  Default block length: ``rounds_per_sync``, or the
        fit's whole remaining ``max_iter`` budget (one sync per fit).

        The cohort and the live reveal points are frozen for the block
        (liveness is a host-side notion; the graph never re-enters
        Python), so supervision treats one block as one round: mid-round
        hooks fire before dispatch, a below-threshold cohort raises with
        ALL fit state unmutated, and the supervised retry re-enters at
        this block with the same rng slots.
        """
        if self.rounds != "scan":
            raise RuntimeError("step_block requires rounds='scan'")
        from .scanfit import fit_scan_block

        cohort = self.cohort_indices()
        points = self.live_points()
        parts = [self.parts[j] for j in cohort]
        in_cohort = set(cohort)
        stragglers = [
            self.names[j] for j in range(len(self.parts))
            if self.online[j] and j not in in_cohort
        ]
        num_live = None if points is None else len(points)
        nbytes = self.agg.round_bytes(
            self.dim, len(parts), self.protect,
            num_live_centers=num_live,
        )
        if num_rounds is None:
            num_rounds = self.rounds_per_sync or max(
                self.max_iter - self.iteration, 1
            )
        packed = pack_partitions(parts)
        pts = self._post_protect_points(points)
        if pts is not None and len(pts) == self.agg.scheme.num_shares:
            pts = None  # the all-live first-t default (cache-friendly)
        carry, objs, actives, gnorms, snorms = fit_scan_block(
            self.beta,
            jnp.asarray(self._obj_prev, jnp.float64),
            jnp.asarray(self.converged),
            jnp.zeros((), jnp.int32),
            self.key,
            jnp.asarray(self._round_base, jnp.int32),
            packed.X, packed.X32, packed.y, packed.counts, self.lam,
            agg=self.agg, protect=self.protect, l1=self.l1,
            tol=float(self.tol), interpret=self.agg.scheme.interpret,
            points=pts, include_count=False,
            summaries_backend=self.summaries_backend,
            num_rounds=num_rounds, num_parts=len(parts),
            max_rounds=num_rounds,
        )
        # host-sync: the block's ONE readback — trace + metric leaves +
        # scalar carry in a single transfer (beta stays on device)
        objs, actives, gnorms, snorms, obj_prev_h, conv_h, base_h = \
            jax.device_get(
                (objs, actives, gnorms, snorms,
                 carry[1], carry[2], carry[4])
            )
        new_reports: list[RoundReport] = []
        for r in range(num_rounds):
            if not actives[r]:
                break
            self.iteration += 1
            self.trace.append(float(objs[r]))
            self.bytes_transmitted += nbytes
            report = RoundReport(
                self.iteration,
                [self.names[j] for j in cohort],
                stragglers,
                list(points or ()),
                float(objs[r]),
                nbytes,
                grad_norm=float(gnorms[r]),
                step_norm=float(snorms[r]),
            )
            self.reports.append(report)
            new_reports.append(report)
            _metrics.observe_round(
                "secure_fit_scan", nbytes, objective=report.objective,
                grad_norm=report.grad_norm, step_norm=report.step_norm,
            )
        self.beta = carry[0]
        self._obj_prev = float(obj_prev_h)
        self.converged = bool(conv_h)
        self._round_base = int(base_h)
        return new_reports

    def run(self, max_iter: int | None = None) -> FitResult:
        limit = self.max_iter if max_iter is None else max_iter
        t_total = time.perf_counter()
        while not self.converged and self.iteration < limit:
            if self.rounds == "scan":
                block = self.rounds_per_sync or (limit - self.iteration)
                self.step_block(min(block, limit - self.iteration))
            else:
                self.step()
        self.total_seconds += time.perf_counter() - t_total
        return self.result()

    def result(self) -> FitResult:
        # central_seconds stays 0.0 on the fused path: institution and
        # center phases live in one fused graph (the split remains
        # observable on the loop path and in protocol.StudyCoordinator)
        return FitResult(
            np.asarray(self.beta), self.iteration, self.converged,
            list(self.trace), central_seconds=self.central_seconds,
            total_seconds=self.total_seconds,
            bytes_transmitted=self.bytes_transmitted,
        )

    # -- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything needed to resume bit-identically after a crash."""
        return {
            "beta": np.asarray(self.beta),
            "iteration": np.asarray(self.iteration),
            "obj_prev": np.asarray(self._obj_prev),
            "trace": np.asarray(self.trace),
            "key": np.asarray(self.key),
            "converged": np.asarray(self.converged),
            "bytes": np.asarray(self.bytes_transmitted),
            "online": np.asarray(self.online),
            "latency": np.asarray(self.latency),
            "centers_online": np.asarray(self.centers_online),
            "round_base": np.asarray(self._round_base),
        }

    def load_state_dict(self, state: dict):
        self.beta = jnp.asarray(state["beta"])
        self.iteration = int(state["iteration"])
        self._obj_prev = float(state["obj_prev"])
        self.trace = [float(x) for x in state["trace"]]
        self.key = jnp.asarray(state["key"], dtype=jnp.uint32)
        self.converged = bool(state["converged"])
        self.bytes_transmitted = int(state.get("bytes", 0))
        if "online" in state:
            self.online = [bool(v) for v in state["online"]]
        if "latency" in state:
            self.latency = [float(v) for v in state["latency"]]
        if "centers_online" in state:
            self.centers_online = [bool(v) for v in state["centers_online"]]
        # pre-scan checkpoints: executed rounds and consumed rng slots
        # coincide in step mode, so iteration is the exact legacy value
        self._round_base = int(state.get("round_base", state["iteration"]))


def secure_fit(
    parts: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    lam: float = 1.0,
    tol: float = 1e-10,
    max_iter: int = 50,
    protect: str = "gradient",
    aggregator: SecureCollective | None = None,
    seed: int = 0,
    l1: float = 0.0,
    fused: bool | None = None,
    rounds: str = "step",
    rounds_per_sync: int | None = None,
    summaries_backend: str | None = None,
) -> FitResult:
    """Paper Algorithm 1 over S institutions' (X_j, y_j) partitions.

    ``protect`` selects the paper's pragmatic mode: known inference attacks
    need both H and g, so protecting either blocks them; "both" is the fully
    encrypted setting; "none" degrades to DataSHIELD-style plain exchange
    (the insecure baseline the paper improves on, kept for benchmarking).

    ``fused=None`` auto-selects: the pallas backend runs the jit-resident
    batched iteration (one kernel launch per phase, one host sync per
    iteration); the reference backend runs the per-institution Python loop
    (the oracle).  Pass ``fused=False`` to force the loop path on any
    backend — that is the pre-fusion baseline the e2e benchmark times.

    ``rounds="scan"`` runs the fit as scan-resident blocks of
    ``rounds_per_sync`` fused rounds (None: the WHOLE fit as one
    ``lax.scan`` — one host sync per fit); requires the fused path.

    This is the one-call form of ``SecureFitDriver`` (which adds stepwise
    execution, liveness hooks and ``state_dict`` crash-resume); a
    fault-free driver run is bit-identical to what this always produced.
    """
    driver = SecureFitDriver(
        parts, lam=lam, tol=tol, max_iter=max_iter, protect=protect,
        aggregator=aggregator, seed=seed, l1=l1, fused=fused,
        rounds=rounds, rounds_per_sync=rounds_per_sync,
        summaries_backend=summaries_backend,
    )
    return driver.run()
