"""Newton-Raphson drivers: centralized gold standard + secure distributed.

``centralized_fit`` is the oracle the paper compares against (Fig. 2's "gold
standard", i.e. what R's glmnet-style IRLS would produce).  ``secure_fit``
runs the paper's Algorithm 1: per-institution summaries -> Shamir protection
-> share-wise aggregation at the Computation Centers -> reconstruction of the
*global* aggregate only -> Newton update (Eq. 3) -> deviance-based
convergence check.  Both converge to the same beta (R^2 = 1.00, Fig. 2);
tests assert this to ~1e-6 which is far below the fixed-point quantization
we configure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .logreg import LocalSummaries, local_summaries, deviance
from .secure_agg import SecureAggregator

__all__ = ["FitResult", "newton_step", "prox_newton_step",
           "centralized_fit", "secure_fit"]

PROTECT_CHOICES = ("none", "gradient", "hessian", "both")


@dataclasses.dataclass
class FitResult:
    beta: np.ndarray
    iterations: int
    converged: bool
    deviance_trace: list
    # telemetry for Table 1 style reporting
    central_seconds: float = 0.0
    total_seconds: float = 0.0
    bytes_transmitted: int = 0


def newton_step(
    beta: jnp.ndarray,
    hessian: jnp.ndarray,
    gradient: jnp.ndarray,
    lam: float,
) -> jnp.ndarray:
    """Eq. 3: beta + (X^T W X + lam I)^{-1} (g - lam beta).

    Solved via Cholesky (the regularized Hessian is SPD); this is the
    "securely derive beta_new" step (Algorithm 1, line 15) which operates on
    *revealed global aggregates* plus public lambda/beta.
    """
    d = beta.shape[0]
    A = hessian + lam * jnp.eye(d, dtype=hessian.dtype)
    rhs = gradient - lam * beta
    L = jnp.linalg.cholesky(A)
    delta = jax.scipy.linalg.cho_solve((L, True), rhs)
    return beta + delta


def _soft_threshold(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def prox_newton_step(
    beta: jnp.ndarray,
    hessian: jnp.ndarray,
    gradient: jnp.ndarray,
    lam: float,
    l1: float,
    inner_steps: int = 200,
) -> jnp.ndarray:
    """Proximal Newton step for elastic-net logistic regression.

    The paper notes L1 support "is also possible" (Materials & Methods);
    crucially the *institution-side protocol is unchanged* — H_j and g_j
    are the same secret-shared summaries — only the Computation Centers'
    solver differs.  We minimize the local quadratic model

        m(b) = -g^T (b - beta) + 1/2 (b - beta)^T H (b - beta)
               + lam/2 ||b||^2 + l1 ||b||_1

    with FISTA (d x d problem, trivially cheap at the center; runs on
    *revealed global aggregates* only, like newton_step).  l1 = 0 reduces
    exactly to the L2 Newton step.
    """
    if l1 == 0.0:
        return newton_step(beta, hessian, gradient, lam)
    d = beta.shape[0]
    A = hessian + lam * jnp.eye(d, dtype=hessian.dtype)
    # Lipschitz constant of the quadratic part
    L = jnp.linalg.norm(A, 2) + 1e-12
    # gradient of the smooth part at b: A (b - beta) - g + lam*beta
    #   (expand: H(b-beta) + lam*b - g ... careful) — derive:
    #   m_smooth(b) = -g^T(b-beta) + .5 (b-beta)^T H (b-beta) + lam/2 b^T b
    #   grad = -g + H (b - beta) + lam b

    def grad_smooth(b):
        return -gradient + hessian @ (b - beta) + lam * b

    def fista(carry, _):
        b, z, t = carry
        b_new = _soft_threshold(z - grad_smooth(z) / L, l1 / L)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = b_new + ((t - 1.0) / t_new) * (b_new - b)
        return (b_new, z_new, t_new), None

    (b, _, _), _ = jax.lax.scan(
        fista, (beta, beta, jnp.asarray(1.0, beta.dtype)), None,
        length=inner_steps,
    )
    return b


def centralized_fit(
    X: jnp.ndarray,
    y: jnp.ndarray,
    lam: float = 1.0,
    tol: float = 1e-10,
    max_iter: int = 50,
) -> FitResult:
    """Gold-standard pooled IRLS (no privacy) for accuracy comparison."""
    d = X.shape[1]
    beta = jnp.zeros((d,), dtype=jnp.float64)
    dev_prev = np.inf
    trace: list[float] = []
    converged = False
    it = 0
    for it in range(1, max_iter + 1):
        s = local_summaries(beta, X, y)
        # regularized objective at the *current* beta (same ordering as the
        # secure protocol, where dev_j arrives with the summaries)
        obj = float(s.deviance) + lam * float(jnp.sum(beta**2))
        trace.append(obj)
        if abs(dev_prev - obj) < tol * (1.0 + abs(obj)):
            converged = True
            break
        dev_prev = obj
        beta = newton_step(beta, s.hessian, s.gradient, lam)
    return FitResult(np.asarray(beta), it, converged, trace)


def secure_fit(
    parts: Sequence[tuple[jnp.ndarray, jnp.ndarray]],
    lam: float = 1.0,
    tol: float = 1e-10,
    max_iter: int = 50,
    protect: str = "gradient",
    aggregator: SecureAggregator | None = None,
    seed: int = 0,
    l1: float = 0.0,
) -> FitResult:
    """Paper Algorithm 1 over S institutions' (X_j, y_j) partitions.

    ``protect`` selects the paper's pragmatic mode: known inference attacks
    need both H and g, so protecting either blocks them; "both" is the fully
    encrypted setting; "none" degrades to DataSHIELD-style plain exchange
    (the insecure baseline the paper improves on, kept for benchmarking).
    """
    if protect not in PROTECT_CHOICES:
        raise ValueError(f"protect must be one of {PROTECT_CHOICES}")
    agg = aggregator or SecureAggregator()
    key = jax.random.PRNGKey(seed)
    d = parts[0][0].shape[1]
    beta = jnp.zeros((d,), dtype=jnp.float64)
    dev_prev = np.inf
    trace: list[float] = []
    converged = False
    central_s = 0.0
    nbytes = 0
    t_total = time.perf_counter()
    it = 0
    for it in range(1, max_iter + 1):
        # ---- distributed phase (institution-local, Algorithm 1 steps 3-8)
        locals_: list[LocalSummaries] = [
            local_summaries(beta, Xj, yj) for Xj, yj in parts
        ]
        protected, plain = [], []
        for s in locals_:
            tree = {}
            if protect in ("gradient", "both"):
                tree["gradient"] = s.gradient
            if protect in ("hessian", "both"):
                tree["hessian"] = s.hessian
            if protect != "none":
                tree["deviance"] = s.deviance
            key, sub = jax.random.split(key)
            protected.append(agg.protect(sub, tree) if tree else {})
            plain.append(
                {
                    k: v
                    for k, v in s._asdict().items()
                    if k not in tree and k != "count"
                }
            )
            # telemetry: every share element is a uint64 per residue
            for leaf in jax.tree_util.tree_leaves(protected[-1]):
                nbytes += leaf.size * 8
            for leaf in jax.tree_util.tree_leaves(plain[-1]):
                nbytes += leaf.size * leaf.dtype.itemsize

        # ---- centralized phase (Computation Centers, steps 11-16)
        t0 = time.perf_counter()
        agg_protected = agg.aggregate(protected) if protect != "none" else {}
        revealed = agg.reveal(agg_protected) if agg_protected else {}
        summed_plain = {
            k: sum(pl[k] for pl in plain) for k in plain[0]
        } if plain[0] else {}
        global_h = revealed.get("hessian", summed_plain.get("hessian"))
        global_g = revealed.get("gradient", summed_plain.get("gradient"))
        global_dev = revealed.get("deviance", summed_plain.get("deviance"))
        # regularized objective at the current beta (summaries' beta)
        obj = float(global_dev) + lam * float(jnp.sum(beta**2)) \
            + 2.0 * l1 * float(jnp.sum(jnp.abs(beta)))
        trace.append(obj)
        # convergence threshold cannot be tighter than the fixed-point
        # quantization of the protected deviances (S institutions x 0.5 ulp)
        quant_floor = (len(parts) + 1) * 0.5 / agg.codec.scale
        if abs(dev_prev - obj) < max(tol * (1.0 + abs(obj)), quant_floor):
            central_s += time.perf_counter() - t0
            converged = True
            break
        dev_prev = obj
        beta = prox_newton_step(
            beta,
            jnp.asarray(global_h, jnp.float64),
            jnp.asarray(global_g, jnp.float64),
            lam,
            l1,
        )
        central_s += time.perf_counter() - t0
    total_s = time.perf_counter() - t_total
    return FitResult(
        np.asarray(beta), it, converged, trace,
        central_seconds=central_s, total_seconds=total_s,
        bytes_transmitted=nbytes,
    )
