"""The paper's technique as an LM-training feature: secret-shared gradient
aggregation across institutions (pods), with checkpoint/restart.

Four institutions co-train a small decoder LM; per-institution gradients
are fixed-point-encoded, Shamir-shared 2-of-3 and aggregated share-wise —
no institution's gradient is ever visible to the others or to any single
Computation Center (cross-silo federated learning with information-
theoretic aggregation, the LM-scale generalization of Algorithm 1's
H_j/g_j protection).  Mid-run we kill and restore from checkpoint.

  PYTHONPATH=src python examples/secure_lm_training.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train as train_mod

ckpt_dir = tempfile.mkdtemp(prefix="secure_lm_")
common = [
    "--arch", "deepseek_7b", "--smoke",
    "--batch", "8", "--seq-len", "64",
    "--secure-agg", "shamir", "--institutions", "4",
    "--checkpoint-dir", ckpt_dir, "--checkpoint-every", "5",
    "--log-every", "5",
]

print("=== phase 1: train 10 steps, checkpointing every 5 ===")
train_mod.main(common + ["--steps", "10"])

print("\n=== phase 2: 'crash', resume from step 10, train to 15 ===")
train_mod.main(common + ["--steps", "15", "--resume"])

shutil.rmtree(ckpt_dir, ignore_errors=True)
print("OK")
