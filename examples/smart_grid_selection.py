"""Smart-grid analytics with private model selection (paper §Applications).

Ten utility companies hold household smart-meter features (usage patterns,
peak-hour ratios, appliance signatures...) and want to jointly learn which
features predict supply-contract churn — without sharing household records,
per-utility summary statistics, or even per-utility *validation scores*
(all commercially sensitive).

Where the old version of this example hand-rolled a single elastic-net fit
at one guessed λ, the selection subsystem now runs the whole job the way a
real consortium would: a descending λ path, 5-fold cross-validation with
fold masks composed into the secure batched rounds (held-out deviance and
accuracy are revealed only as cohort aggregates, per λ per fold), the
1-SE-rule λ pick, and a warm-started full-data refit — all through the
same Algorithm-1 Shamir pipeline, batched and scan-resident.

  PYTHONPATH=src python examples/smart_grid_selection.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Institution
from repro.data.partition import partition_rows
from repro.selection import SelectionCoordinator

# --- synthesize: 24 features, only 6 truly predictive ------------------
key = jax.random.PRNGKey(11)
n, d, d_true = 12_000, 24, 6
k1, k2, k3 = jax.random.split(key, 3)
X = jnp.concatenate(
    [jnp.ones((n, 1)), jax.random.normal(k1, (n, d - 1))], axis=1
)
beta_true = jnp.zeros((d,)).at[:d_true + 1].set(
    jax.random.uniform(k2, (d_true + 1,), minval=0.6, maxval=1.4)
)
y = jax.random.bernoulli(k3, jax.nn.sigmoid(X @ beta_true)).astype(
    jnp.float64
)
parts = partition_rows(X.astype(jnp.float64), y, 10)  # 10 utilities
utilities = [
    Institution(f"utility{j:02d}", Xj, yj)
    for j, (Xj, yj) in enumerate(parts)
]

# --- secure cross-validated λ path across the 10 utilities -------------
# Descending L2 grid spanning clear underfit (λ ~ n/4) down to nearly
# unregularized; the L1 term is held fixed — feature selection comes from
# the prox-Newton solver at the centers, zero extra privacy surface.
lambdas = [3000.0, 1000.0, 300.0, 100.0, 30.0, 10.0, 3.0]
coord = SelectionCoordinator(
    utilities, lambdas, num_folds=5, l1=100.0, protect="gradient",
    seed=0,
)
report = coord.run_path()

print("secure 5-fold CV curve (all values are cohort aggregates —")
print("no utility's validation score was ever revealed):\n")
print("\n".join(report.summary_lines()))
print(f"\nbest λ = {report.lambda_best:g}, "
      f"1-SE pick λ = {report.lambda_1se:g}")
print(f"secure rounds: {report.rounds_total} "
      f"({report.bytes_per_round} wire bytes/round)")

# --- the selected model: full-data refit at the 1-SE λ -----------------
beta = np.asarray(report.beta)
selected = np.where(np.abs(beta) > 1e-6)[0]
truth = set(range(d_true + 1))
recovered = truth & set(selected.tolist())
spurious = set(selected.tolist()) - truth

print(f"\nselected features: {sorted(selected.tolist())}")
print(f"ground-truth features: {sorted(truth)}")
print(f"recovered {len(recovered)}/{len(truth)}; spurious: {len(spurious)}")
assert report.lambda_1se >= report.lambda_best  # 1-SE never under-regularizes
assert len(recovered) >= d_true  # all true signals kept
assert len(spurious) == 0        # penalty prunes all noise dims
# the under-fit end of the path must look worse than the pick on held-out
# data, i.e. the CV curve actually carried information
assert report.cv_mean[0] > report.cv_mean[report.one_se_index]
print("OK — λ chosen by secure cross-validation; joint feature selection "
      "without sharing a single household record, summary, or fold score")
