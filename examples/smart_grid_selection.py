"""Smart-grid analytics with private feature selection (paper §Applications).

Ten utility companies hold household smart-meter features (usage patterns,
peak-hour ratios, appliance signatures...) and want to jointly learn which
features predict supply-contract churn — without sharing household records
or even their per-utility summary statistics (commercially sensitive).

Elastic-net secure fit: the institutions run the *identical* Algorithm-1
protocol (summaries -> Shamir shares -> share-wise aggregation); only the
Computation Centers' solver uses the prox-Newton L1 step, so feature
selection comes at zero extra privacy surface.

  PYTHONPATH=src python examples/smart_grid_selection.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.newton import secure_fit
from repro.data.partition import partition_rows

# --- synthesize: 24 features, only 6 truly predictive ------------------
key = jax.random.PRNGKey(11)
n, d, d_true = 12_000, 24, 6
k1, k2, k3 = jax.random.split(key, 3)
X = jnp.concatenate(
    [jnp.ones((n, 1)), jax.random.normal(k1, (n, d - 1))], axis=1
)
beta_true = jnp.zeros((d,)).at[:d_true + 1].set(
    jax.random.uniform(k2, (d_true + 1,), minval=0.6, maxval=1.4)
)
y = jax.random.bernoulli(k3, jax.nn.sigmoid(X @ beta_true)).astype(
    jnp.float64
)
parts = partition_rows(X.astype(jnp.float64), y, 10)  # 10 utilities

# --- secure elastic-net across the 10 utilities ------------------------
res = secure_fit(parts, lam=0.5, l1=100.0, protect="gradient",
                 max_iter=60)
beta = np.asarray(res.beta)
selected = np.where(np.abs(beta) > 1e-6)[0]
truth = set(range(d_true + 1))

print(f"converged={res.converged} in {res.iterations} iterations")
print(f"selected features: {sorted(selected.tolist())}")
print(f"ground-truth features: {sorted(truth)}")
recovered = truth & set(selected.tolist())
spurious = set(selected.tolist()) - truth
print(f"recovered {len(recovered)}/{len(truth)}; spurious: {len(spurious)}")
assert len(recovered) >= d_true  # all true signals kept
assert len(spurious) == 0       # penalty prunes all noise dims
print("OK — joint feature selection without sharing a single household "
      "record or per-utility summary")
