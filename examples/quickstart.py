"""Quickstart: the paper's protocol in ~40 lines of public API.

Five hospitals jointly fit an L2-regularized logistic regression without
sharing records OR unprotected summary statistics, and verify the result
matches the pooled centralized fit exactly (paper Fig. 2).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.newton import centralized_fit, secure_fit
from repro.core.secure_agg import SecureAggregator
from repro.core.shamir import ShamirScheme
from repro.data.synthetic import generate_synthetic

# 1. Five institutions, 2k records each, 8 covariates (Algorithm 3).
study = generate_synthetic(
    jax.random.PRNGKey(0), num_institutions=5,
    records_per_institution=2_000, dim=8,
)

# 2. Secure fit: summaries are Shamir-shared 2-of-3 across Computation
#    Centers; only the *global* aggregates are ever reconstructed.
#    overflow_check arms the fixed-point headroom assert on every protect
#    (a ~1-3 ms/round callback; see benchmarks/fault_overhead.py): a
#    value past capacity raises instead of saturating into a
#    plausible-but-wrong reveal.
agg = SecureAggregator(scheme=ShamirScheme(threshold=2, num_shares=3),
                       overflow_check=True)
secure = secure_fit(list(study.parts), lam=1.0, protect="gradient",
                    aggregator=agg)

# 3. Gold standard: pooled IRLS on the concatenated data (no privacy).
gold = centralized_fit(*study.pooled(), lam=1.0)

r2 = float(np.corrcoef(secure.beta, gold.beta)[0, 1] ** 2)
print(f"secure fit:    {secure.iterations} iterations, "
      f"converged={secure.converged}")
print(f"gold standard: {gold.iterations} iterations")
print(f"R^2(secure, gold) = {r2:.10f}   (paper Fig 2: 1.00)")
print(f"max |beta_sec - beta_gold| = "
      f"{np.max(np.abs(secure.beta - gold.beta)):.2e}")
print(f"bytes transmitted: {secure.bytes_transmitted:,}")
assert r2 > 0.999999
assert secure.iterations <= 10  # paper Fig 3: 6-8
print("OK")
