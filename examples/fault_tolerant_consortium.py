"""A research consortium surviving stragglers, center loss and churn.

Demonstrates the supervised protocol: a ``RoundSupervisor`` drives the
deployment-shaped ``StudyCoordinator`` (fused cohort rounds) through a
deterministic ``FailureInjector`` chaos schedule while

  * hospital-7 is a chronic straggler (always misses the round deadline),
  * hospital-3 flaps for 2.5 simulated seconds at round 3 (stops
    heartbeating, self-heals, rejoins without losing its data),
  * Computation Center 2 dies BETWEEN protect and reveal at round 2
    (2-of-4 Shamir absorbs it: the survivors' points reconstruct the
    identical aggregate; nothing is re-run),
  * a replacement center is provisioned at round 5 on the consortium's
    SPARE evaluation point 4 — a point whose share slice the dead node
    never held — restoring full redundancy,
  * a new institution joins between Newton iterations (elastic
    membership: the supervisor admits it into the heartbeat fleet, the
    cohort repacks, the LRU pack cache keeps both cohorts resident),

and the study still converges to the responding cohort's centralized
beta, with a per-round ``SupervisedRound`` audit trail (retries, backoff,
degraded flags, suspected-dead lists).  The whole thing runs on the
FUSED cohort-level round: one jitted graph per attempt, with the
fixed-point overflow assert armed (``overflow_check=True`` — a value
past headroom raises instead of saturating into a plausible reveal).

  PYTHONPATH=src python examples/fault_tolerant_consortium.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.newton import centralized_fit
from repro.core.protocol import Institution, StudyCoordinator
from repro.core.secure_agg import SecureAggregator
from repro.core.shamir import ShamirScheme
from repro.data.synthetic import generate_synthetic
from repro.obs import trace
from repro.runtime import FailureInjector, FaultPolicy, RoundSupervisor

# record round/newton/retry/protect spans for the end-of-run summary
# table; disabled tracing is the default and costs one branch per span
trace.enable()

study = generate_synthetic(
    jax.random.PRNGKey(3), num_institutions=9,
    records_per_institution=1_500, dim=10,
)
parts = list(study.parts)

insts = [Institution(f"hospital-{j}", X, y, latency=0.5)
         for j, (X, y) in enumerate(parts[:8])]
insts[7].latency = 99.0  # chronic straggler: always misses the deadline

# 2-of-4 Shamir with only 3 centers stood up: evaluation point 4 is the
# consortium's spare, held back for re-provisioning after a center loss
coord = StudyCoordinator(
    insts, lam=1.0, protect="gradient",
    deadline=2.0, min_responders=4, num_centers=3,
    aggregator=SecureAggregator(
        scheme=ShamirScheme(threshold=2, num_shares=4, backend="pallas"),
        overflow_check=True,
    ),
    fused=True,
)

schedule = {
    2: [("center_midround", 2)],        # dies between protect and reveal
    3: [("flap", "hospital-3", 2.5)],   # transient outage, self-heals
    5: [("provision_center", 4)],       # replacement at the spare point
}
sup = RoundSupervisor(
    coord,
    policy=FaultPolicy(max_retries=3, round_seconds=1.0,
                       heartbeat_timeout=5.0, reprovision_after=0),
    injector=FailureInjector(schedule),
)

for _ in range(30):
    if coord.converged:
        break
    if sup.round_no + 1 == 4:
        X9, y9 = parts[8]
        coord.add_institution(
            Institution("hospital-8(new)", X9, y9, latency=0.5)
        )
        print(">> hospital-8 JOINED mid-study")
    rec = sup.step()
    rep = rec.report
    flags = []
    if rec.events:
        flags.append("events=" + ",".join(e[0] for e in rec.events))
    if rec.retries:
        flags.append(f"retries={rec.retries} "
                     f"backoff={rec.backoff_seconds:.0f}s")
    if rec.suspected_dead:
        flags.append(f"suspected_dead={rec.suspected_dead}")
    print(f"round {rec.round_no:2d}: obj={rep.objective:.6f} "
          f"|g|={rep.grad_norm:.2e} "
          f"responders={len(rep.responders)} stragglers={rep.stragglers} "
          f"centers={rep.centers_used} "
          f"degraded={'Y' if rec.degraded else 'n'}"
          + (" | " + " ".join(flags) if flags else ""))

beta = np.asarray(coord.beta)
# the final cohort = hospitals 0-6 + hospital-8 (7 never responds; 3's
# flap healed before convergence, so its data is fully represented)
cohort_parts = parts[:7] + [parts[8]]
X = np.concatenate([p[0] for p in cohort_parts])
y = np.concatenate([p[1] for p in cohort_parts])
gold = centralized_fit(X, y, lam=1.0)
r2 = float(np.corrcoef(beta, gold.beta)[0, 1] ** 2)
degraded = sum(1 for r in sup.rounds if r.degraded)
print(f"\nconverged={coord.converged} after {coord.iteration} rounds "
      f"({degraded} degraded, {sup.total_retries} retries, "
      f"{sup.total_backoff:.0f}s simulated backoff)")
print(f"centers now at points "
      f"{sorted(c.index for c in coord.centers if c.online)} "
      f"(spare point 4 in service)")
print(f"R^2 vs centralized-fit-on-responding-cohort = {r2:.8f}")

tracer = trace.disable()
print("\nper-round span summary (repro.obs.trace):")
for line in tracer.summary_lines():
    print("  " + line)

assert coord.converged and r2 > 0.999
print("OK")
