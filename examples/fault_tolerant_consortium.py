"""A research consortium surviving stragglers, center loss and churn.

Demonstrates the deployment-shaped protocol (core.protocol): 8 institutions
and 3 Computation Centers run Algorithm 1 while
  * institution 7 is a straggler (misses the round deadline),
  * Computation Center 2 goes down mid-study (t-of-w Shamir absorbs it:
    the fused round reveals from the surviving centers' actual points),
  * a new institution joins between Newton iterations (elastic membership;
    the cohort repacks, the LRU pack cache keeps both cohorts resident),
and the study still converges, with a per-round audit trail.  The whole
thing runs on the FUSED cohort-level round (``fused=True``): each round is
one jitted graph — batched summaries, one encode+share launch, one uint64
reduction, reveal, Newton step — with per-round parity to the
per-institution loop within fixed-point quantization.

  PYTHONPATH=src python examples/fault_tolerant_consortium.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.newton import centralized_fit
from repro.core.protocol import Institution, StudyCoordinator
from repro.core.secure_agg import SecureAggregator
from repro.data.synthetic import generate_synthetic

study = generate_synthetic(
    jax.random.PRNGKey(3), num_institutions=9,
    records_per_institution=1_500, dim=10,
)
parts = list(study.parts)

insts = [Institution(f"hospital-{j}", X, y, latency=0.5)
         for j, (X, y) in enumerate(parts[:8])]
insts[7].latency = 99.0  # chronic straggler: always misses the deadline

coord = StudyCoordinator(insts, lam=1.0, protect="gradient",
                         deadline=2.0, min_responders=4,
                         aggregator=SecureAggregator(backend="pallas"),
                         fused=True)

for round_no in range(1, 30):
    if coord.converged:
        break
    if round_no == 2:
        coord.centers[1].online = False  # lose a Computation Center
        print(">> center 2 DOWN (Shamir 2-of-3: study continues)")
    if round_no == 3:
        X9, y9 = parts[8]
        coord.add_institution(Institution("hospital-8(new)", X9, y9))
        print(">> hospital-8 JOINED mid-study")
    rep = coord.step()
    print(f"round {rep.iteration:2d}: obj={rep.objective:.6f} "
          f"responders={len(rep.responders)} stragglers={rep.stragglers} "
          f"centers={rep.centers_used}")

beta = np.asarray(coord.beta)
# the final cohort = hospitals 0-6 + hospital-8 (7 never responds)
cohort_parts = parts[:7] + [parts[8]]
X = np.concatenate([p[0] for p in cohort_parts])
y = np.concatenate([p[1] for p in cohort_parts])
gold = centralized_fit(X, y, lam=1.0)
r2 = float(np.corrcoef(beta, gold.beta)[0, 1] ** 2)
print(f"\nconverged={coord.converged} after {coord.iteration} rounds")
print(f"R^2 vs centralized-fit-on-responding-cohort = {r2:.8f}")
assert coord.converged and r2 > 0.999
print("OK")
