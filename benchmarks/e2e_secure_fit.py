"""End-to-end secure Newton: fused jit-resident iteration vs pre-fusion loop.

Measures the full ``secure_fit`` wall clock (packing included) at the
paper's protocol scale — S institutions, d features, N total records —
for three execution shapes:

* ``loop_reference`` — the pre-fusion baseline: Python loop over
  institutions, one ``local_summaries`` + one protect dispatch per
  institution per iteration, reference (uint64 jnp) protocol backend.
  This is what a pre-fusion caller got from ``secure_fit(parts)`` with
  default arguments (cf. ``benchmarks/runtime.py``).
* ``loop_pallas`` — the same Python loop with the PR-1 fused
  protect/reveal kernels, isolating how much of the win comes from the
  batched/jit-resident iteration itself rather than the protocol kernels.
* ``fused`` — this PR: one batched fused-IRLS summaries launch over all
  institutions, one batched protect, streaming aggregation, reveal and
  Newton update in a single jitted graph; one host sync per iteration.

Every run must converge to the *same* beta: the fused path is checked
against both baselines (tolerance: fixed-point quantization, (S+1)/scale)
and against the pooled ``centralized_fit`` gold standard (paper Fig. 2,
R^2 = 1).  Timing is min-of-repeats after an untimed warmup fit that
triggers all trace/compile work AND the fused path's one-per-study
partition packing (memoized like the jit cache), so the numbers compare
steady-state pipelines, not XLA compilation or data staging.

Interpret-mode caveat: on this CPU container the Pallas protocol kernels
run through the interpreter and the fused-IRLS kernel runs as its XLA
functional simulation (same numerics contract — f32 Gram accumulation,
payload-dtype gradient/deviance; see ``kernels/fused_irls.py``).  On TPU
(``interpret=False``) the blocked kernels compile natively and the f32
path is simply the hardware dtype.  Machine-readable rows land in
BENCH_e2e_secure_fit.json (``--quick`` is the bench_smoke gate size).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Institution,
    SecureAggregator,
    StudyCoordinator,
    centralized_fit,
    secure_fit,
)
from repro.core.field import fsum
from repro.core.logreg import local_summaries
from repro.data import generate_synthetic


def _pre_pr_secure_fit(parts, lam=1.0, tol=1e-10, max_iter=50,
                       protect="both", aggregator=None, seed=0):
    """Frozen replica of the pre-fusion ``secure_fit`` — the benchmark's
    baseline, kept verbatim-in-behavior so later library changes cannot
    silently speed the comparator: Python loop over institutions, one
    ``local_summaries`` + one protect per institution per iteration,
    eager ``jnp.stack`` share aggregation, per-leaf byte telemetry inside
    the loop, and the Cholesky/cho_solve Newton step.
    """
    agg = aggregator or SecureAggregator()
    key = jax.random.PRNGKey(seed)
    d = parts[0][0].shape[1]
    beta = jnp.zeros((d,), dtype=jnp.float64)
    dev_prev, trace, it, nbytes = np.inf, [], 0, 0
    converged = False
    for it in range(1, max_iter + 1):
        locals_ = [local_summaries(beta, Xj, yj) for Xj, yj in parts]
        protected, plain = [], []
        for s in locals_:
            tree = {}
            if protect in ("gradient", "both"):
                tree["gradient"] = s.gradient
            if protect in ("hessian", "both"):
                tree["hessian"] = s.hessian
            if protect != "none":
                tree["deviance"] = s.deviance
            key, sub = jax.random.split(key)
            protected.append(agg.protect(sub, tree) if tree else {})
            plain.append({k: v for k, v in s._asdict().items()
                          if k not in tree and k != "count"})
            for leaf in jax.tree_util.tree_leaves(protected[-1]):
                nbytes += leaf.size * 8
            for leaf in jax.tree_util.tree_leaves(plain[-1]):
                nbytes += leaf.size * leaf.dtype.itemsize
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *protected
        )
        summed = jax.tree_util.tree_map(
            lambda s: fsum(s, agg.scheme.field, axis=0, residue_axis=1),
            stacked,
        )
        revealed = agg.reveal(summed) if protect != "none" else {}
        summed_plain = {k: sum(pl[k] for pl in plain) for k in plain[0]} \
            if plain[0] else {}
        gh = revealed.get("hessian", summed_plain.get("hessian"))
        gg = revealed.get("gradient", summed_plain.get("gradient"))
        gdev = revealed.get("deviance", summed_plain.get("deviance"))
        obj = float(gdev) + lam * float(jnp.sum(beta**2))
        trace.append(obj)
        quant_floor = (len(parts) + 1) * 0.5 / agg.codec.scale
        if abs(dev_prev - obj) < max(tol * (1.0 + abs(obj)), quant_floor):
            converged = True
            break
        dev_prev = obj
        A = jnp.asarray(gh, jnp.float64) + lam * jnp.eye(d)
        rhs = jnp.asarray(gg, jnp.float64) - lam * beta
        L = jnp.linalg.cholesky(A)
        beta = beta + jax.scipy.linalg.cho_solve((L, True), rhs)
    return dataclasses.make_dataclass(
        "PrePRFit", ["beta", "iterations", "converged", "bytes_transmitted"]
    )(np.asarray(beta), it, converged, nbytes)


def _ragged_sizes(total: int, s: int) -> list[int]:
    """Mildly uneven split (the paper's random horizontal partitioning is
    near-even at these sizes): +-5% linear ramp around the mean.  The
    fused path pads every institution to N_max, so the ramp width is the
    padding overhead it pays relative to the loop baselines."""
    base = total // s
    sizes = [base + int(base * 0.05 * (2 * j / max(s - 1, 1) - 1))
             for j in range(s)]
    sizes[-1] += total - sum(sizes)
    return sizes


def _make_parts(key, total: int, s: int, d: int):
    study = generate_synthetic(
        key, num_institutions=1, records_per_institution=total, dim=d,
    )
    X, y = study.pooled()
    parts, off = [], 0
    for sz in _ragged_sizes(total, s):
        parts.append((X[off:off + sz], y[off:off + sz]))
        off += sz
    return parts, (X, y)


def _timed_fit(fit_fn, parts, repeats: int, **kw):
    fit_fn(parts, max_iter=2, **kw)  # warmup: trace + compile
    best, res = 1e30, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fit_fn(parts, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, res


def run(num_institutions: int = 8, dim: int = 128, records: int = 200_000,
        protect: str = "both", lam: float = 1.0, repeats: int = 3,
        seed: int = 0):
    parts, (X, y) = _make_parts(
        jax.random.PRNGKey(seed), records, num_institutions, dim
    )
    gold = centralized_fit(X, y, lam=lam)
    ref_agg = SecureAggregator(backend="reference")
    pal_agg = SecureAggregator(backend="pallas")
    quant_tol = (num_institutions + 1) / pal_agg.codec.scale

    runs = {
        # the acceptance baseline: the pre-fusion loop as it shipped
        # (reference-backend protocol, the pre-PR default aggregator)
        "pre_pr_loop": (_pre_pr_secure_fit, dict(aggregator=ref_agg)),
        # the same Python loop on the PR-1 kernels: isolates how much of
        # the win is the fused/batched iteration vs the protocol kernels
        "loop_pallas": (secure_fit,
                        dict(aggregator=pal_agg, fused=False)),
        "fused": (secure_fit, dict(aggregator=pal_agg, fused=True)),
    }
    rows, results = [], {}
    for name, (fit_fn, kw) in runs.items():
        secs, res = _timed_fit(fit_fn, parts, repeats, lam=lam,
                               protect=protect, **kw)
        results[name] = (secs, res)
        err_gold = float(np.abs(res.beta - gold.beta).max())
        r2 = float(np.corrcoef(res.beta, gold.beta)[0, 1] ** 2)
        rows.append({
            "path": name,
            "institutions": num_institutions,
            "dim": dim,
            "records": records,
            "protect": protect,
            "seconds": secs,
            "seconds_per_iter": secs / res.iterations,
            "iterations": res.iterations,
            "converged": res.converged,
            "bytes_transmitted": res.bytes_transmitted,
            "max_abs_err_vs_centralized": err_gold,
            "r2_vs_centralized": r2,
            "pass": res.converged and r2 > 0.999999,
        })

    fused_s, fused_res = results["fused"]
    for base in ("pre_pr_loop", "loop_pallas"):
        base_s, base_res = results[base]
        err = float(np.abs(fused_res.beta - base_res.beta).max())
        row = {
            "check": f"fused speedup vs {base}",
            "protect": protect,
            "baseline_seconds": base_s,
            "fused_seconds": fused_s,
            "speedup": base_s / max(fused_s, 1e-12),
            "max_abs_err_vs_baseline": err,
            "quantization_tol": quant_tol,
            "beta_identical_within_quantization": err <= quant_tol,
        }
        # the headline acceptance gate: >= 3x over the pre-fusion path
        # at identical beta; the loop_pallas row is informational
        if base == "pre_pr_loop":
            row["pass"] = row["speedup"] >= 3.0 and err <= quant_tol
        rows.append(row)
    return rows


def run_coordinator(num_institutions: int = 8, dim: int = 128,
                    records: int = 200_000, protect: str = "both",
                    lam: float = 1.0, repeats: int = 3, seed: int = 0,
                    full_gate: bool = True):
    """Coordinator-driver rows: the deployment-shaped StudyCoordinator on
    the fused cohort round vs its per-institution loop oracle.

    All drivers use the SAME pallas aggregator (the loop already enjoys
    the PR-1 protocol kernels), so the measured win is exactly what the
    cohort-level batched step buys.  Both rungs of the fused round's
    precision ladder are measured:

    * ``coordinator_fused`` — the default f64 ("reference") summaries:
      per-ROUND beta parity with the loop oracle (checked in lockstep,
      every round, against quantization tolerance).  Its speedup is the
      dispatch/protocol fusion win only — the f64 Gram flops are shared
      with the loop, so the ratio compresses toward 1 as N grows.
    * ``coordinator_fused_f32`` — ``summaries_backend="pallas"`` (the
      TPU-dtype Gram, same contract as fused ``secure_fit``): the
      headline round-time win at production N, with CONVERGED-beta
      parity (the mid-run Newton transient amplifies the f32 Hessian
      perturbation past per-round tolerance; the fixed point, set by the
      f64 gradient, is immune).
    """
    parts, (X, y) = _make_parts(
        jax.random.PRNGKey(seed), records, num_institutions, dim
    )
    gold = centralized_fit(X, y, lam=lam)
    agg = SecureAggregator(backend="pallas")
    quant_tol = (num_institutions + 1) / agg.codec.scale

    def make(fused, summaries_backend=None):
        insts = [
            Institution(f"inst{j}", Xj, yj)
            for j, (Xj, yj) in enumerate(parts)
        ]
        return StudyCoordinator(insts, lam=lam, protect=protect,
                                aggregator=agg, seed=seed, fused=fused,
                                summaries_backend=summaries_backend)

    # ---- lockstep per-round parity (also the trace/compile/pack warmup)
    loop, fus = make(False), make(True)
    fus32 = make(True, summaries_backend="pallas")
    max_round_err, max_round_err32 = 0.0, 0.0
    while not (loop.converged or fus.converged) and loop.iteration < 60:
        loop.step()
        fus.step()
        max_round_err = max(max_round_err, float(
            np.abs(np.asarray(loop.beta) - np.asarray(fus.beta)).max()
        ))
        # per-round comparison is only defined while both trajectories
        # are still moving: once either side converges its beta freezes
        # and the difference measures convergence timing, not the Newton
        # transient (same_iterations in the check row catches divergent
        # round counts)
        if not fus32.converged:
            fus32.step()
            if not (loop.converged or fus32.converged):
                max_round_err32 = max(max_round_err32, float(
                    np.abs(np.asarray(loop.beta)
                           - np.asarray(fus32.beta)).max()
                ))
    parity_ok = (loop.converged == fus.converged
                 and loop.iteration == fus.iteration
                 and max_round_err <= quant_tol)

    rows, results = [], {}
    for name, kw in (("coordinator_loop", dict(fused=False)),
                     ("coordinator_fused", dict(fused=True)),
                     ("coordinator_fused_f32",
                      dict(fused=True, summaries_backend="pallas"))):
        best, coord = 1e30, None
        for _ in range(repeats):
            coord = make(**kw)
            t0 = time.perf_counter()
            coord.run()
            best = min(best, time.perf_counter() - t0)
        beta = np.asarray(coord.beta)
        results[name] = (best, coord)
        r2 = float(np.corrcoef(beta, gold.beta)[0, 1] ** 2)
        rows.append({
            "path": name,
            "institutions": num_institutions,
            "dim": dim,
            "records": records,
            "protect": protect,
            "seconds": best,
            "seconds_per_iter": best / coord.iteration,
            "iterations": coord.iteration,
            "converged": bool(coord.converged),
            "bytes_transmitted": int(
                sum(r.bytes_transmitted for r in coord.reports)
            ),
            "max_abs_err_vs_centralized": float(
                np.abs(beta - gold.beta).max()
            ),
            "r2_vs_centralized": r2,
            "pass": bool(coord.converged) and r2 > 0.999999,
        })

    loop_s, loop_c = results["coordinator_loop"]
    round_loop = loop_s / loop_c.iteration
    fus_s, fus_c = results["coordinator_fused"]
    round_fus = fus_s / fus_c.iteration
    rows.append({
        "check": "coordinator fused parity vs loop",
        "protect": protect,
        "seconds_per_round_loop": round_loop,
        "seconds_per_round_fused": round_fus,
        "round_speedup": round_loop / max(round_fus, 1e-12),
        "max_round_beta_err": max_round_err,
        "quantization_tol": quant_tol,
        "per_round_parity_within_quantization": parity_ok,
        # the parity rung's gate: every round within quantization, and
        # the fused round not meaningfully slower than the loop.  At the
        # full config both are bound by the same f64 Gram flops, so the
        # ratio sits at ~1.0 and the quick config (where dispatch
        # dominates and the fusion win is real, ~1.5x) carries the
        # strict not-slower assertion; here we only exclude regressions
        # beyond timer noise.
        "pass": parity_ok and round_loop / max(round_fus, 1e-12) >= (
            0.9 if full_gate else 1.0
        ),
    })
    f32_s, f32_c = results["coordinator_fused_f32"]
    round_f32 = f32_s / f32_c.iteration
    # converged-beta parity measured between the TIMED runs (both driven
    # to their own convergence — the lockstep loop exits when the f64
    # pair converges, which may precede fus32's last round)
    final_err32 = float(
        np.abs(np.asarray(loop_c.beta) - np.asarray(f32_c.beta)).max()
    )
    rows.append({
        "check": "coordinator fused speedup vs loop",
        "protect": protect,
        "baseline_seconds": loop_s,
        "fused_seconds": f32_s,
        "speedup": loop_s / max(f32_s, 1e-12),
        "seconds_per_round_loop": round_loop,
        "seconds_per_round_fused": round_f32,
        "round_speedup": round_loop / max(round_f32, 1e-12),
        "max_round_beta_err": max_round_err32,
        "final_beta_err_vs_loop": final_err32,
        "quantization_tol": quant_tol,
        "final_beta_within_quantization": final_err32 <= quant_tol,
        "same_iterations": loop_c.iteration == f32_c.iteration,
        # the speed rung's gate: >= 2x ROUND time at the full config
        # (>= 1x under --quick) at converged-beta parity over the same
        # number of rounds
        "pass": final_err32 <= quant_tol
        and loop_c.iteration == f32_c.iteration
        and (
            round_loop / max(round_f32, 1e-12) >= (2.0 if full_gate else 1.0)
        ),
    })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--institutions", type=int, default=8)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--records", type=int, default=200_000,
                    help="total N across all institutions")
    ap.add_argument("--protect", default="both",
                    choices=("none", "gradient", "hessian", "both"))
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="small config for the bench_smoke gate "
                         "(S=4, d=32, N=20000, 1 repeat; the 3x/2x "
                         "headline gates apply to the full config only)")
    ap.add_argument("--driver", default="both",
                    choices=("both", "secure_fit", "coordinator"),
                    help="which execution driver(s) to benchmark: the "
                         "in-process secure_fit paths, the deployment-"
                         "shaped StudyCoordinator (fused vs loop rounds), "
                         "or both")
    ap.add_argument("--json", default="BENCH_e2e_secure_fit.json",
                    help="machine-readable output path ('' to skip)")
    args = ap.parse_args(argv)

    kw = dict(num_institutions=args.institutions, dim=args.dim,
              records=args.records, protect=args.protect, lam=args.lam,
              repeats=args.repeats)
    if args.quick:
        kw.update(num_institutions=4, dim=32, records=20_000, repeats=1)
    rows = []
    if args.driver in ("both", "secure_fit"):
        rows += run(**kw)
    if args.driver in ("both", "coordinator"):
        rows += run_coordinator(full_gate=not args.quick, **kw)
    rows.append({"config": "quick" if args.quick else "full",
                 "driver": args.driver, **{
        k: kw[k] for k in ("num_institutions", "dim", "records", "protect")
    }})

    out = json.dumps(rows, indent=2)
    print(out)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
