"""Secure-aggregation overhead: Shamir share-protect vs plain aggregation.

Extends the paper's efficiency story to LM-scale payloads: for gradient
pytrees from 1e4 to 1e7 parameters, measures protect (encode+share),
share-wise aggregate over S institutions, reveal (reconstruct+decode)
wall time and per-phase throughput, the bytes moved, and verifies
exactness of the revealed sum against the float sum.

Methodology: every phase is run once untimed to trigger trace/compile
(jit warmup) before the timed repeats — the numbers measure kernel
throughput, not Python dispatch or XLA compilation.  ``--backend pallas``
runs the fused flat-buffer pipeline (single kernel launch per phase,
uint32 shares); ``--backend reference`` runs the per-leaf uint64 jnp
oracle.  Throughput is reported as GB/s over the bytes each phase
actually touches (floats in + shares out for protect, S share stacks in
for aggregate, k slices in + floats out for reveal).

The structural claim being validated: protection cost is linear in the
payload and embarrassingly parallel (elementwise Horner), so the secure
path adds a constant small factor over plain aggregation — the LM-scale
analogue of the paper's "central phase is a small share of total time".

Machine-readable output lands in BENCH_secure_overhead.json for the perf
trajectory (see scripts/bench_smoke.sh for the standing regression gate).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secure_agg import SecureAggregator


def _timed(fn, repeats: int) -> tuple[float, object]:
    """min-of-repeats wall time with a jit-warmup iteration run first."""
    out = fn()
    jax.block_until_ready(out)  # warmup: trace + compile outside the clock
    best = 1e30
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(sizes=(10_000, 100_000, 1_000_000),
        num_institutions: int = 4, repeats: int = 3,
        backend: str = "reference"):
    agg = SecureAggregator(backend=backend)
    key = jax.random.PRNGKey(0)
    w = agg.scheme.num_shares
    R = agg.scheme.field.num_residues
    share_itemsize = 4 if backend == "pallas" else 8
    rows = []
    for n in sizes:
        keys = jax.random.split(key, num_institutions + 1)
        key = keys[0]
        grads = [
            0.01 * jax.random.normal(keys[j + 1], (n,), jnp.float32)
            for j in range(num_institutions)
        ]
        gold = np.sum(np.stack([np.asarray(g, np.float64) for g in grads]),
                      axis=0)

        t_protect, protected = _timed(
            lambda: [
                agg.protect(jax.random.fold_in(key, j), {"g": g})
                for j, g in enumerate(grads)
            ],
            repeats,
        )
        t_agg, summed = _timed(lambda: agg.aggregate(protected), repeats)
        t_reveal, revealed = _timed(lambda: agg.reveal(summed), repeats)

        err = float(np.max(np.abs(np.asarray(revealed["g"]) - gold)))
        share_bytes = n * w * R * share_itemsize  # one institution's stack
        gb = 1e9
        rows.append({
            "backend": backend,
            "params": n,
            "institutions": num_institutions,
            "protect_s": t_protect,
            "aggregate_s": t_agg,
            "reveal_s": t_reveal,
            "total_secure_s": t_protect + t_agg + t_reveal,
            "protect_gbps": num_institutions * (n * 4 + share_bytes)
                            / max(t_protect, 1e-12) / gb,
            "aggregate_gbps": num_institutions * share_bytes
                              / max(t_agg, 1e-12) / gb,
            "reveal_gbps": (share_bytes + n * 8)
                           / max(t_reveal, 1e-12) / gb,
            "bytes_secure_per_inst": share_bytes,
            "bytes_plain_per_inst": n * 4,
            "bandwidth_factor": w * R * share_itemsize / 4.0,
            "max_abs_err": err,
            "exact_within_codec": err < 1e-6,
            "pass": err < 1e-6,
        })
    # linearity check: 100x params should be < 300x time (no blowup)
    t_small = rows[0]["total_secure_s"]
    t_big = rows[-1]["total_secure_s"]
    ratio = t_big / max(t_small, 1e-9)
    size_ratio = rows[-1]["params"] / rows[0]["params"]
    rows.append({
        "check": "protection cost ~linear in payload",
        "backend": backend,
        "time_ratio": ratio,
        "size_ratio": size_ratio,
        "pass": ratio < 3 * size_ratio,
    })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("pallas", "reference"),
                    nargs="+", default=["reference", "pallas"],
                    help="secure-path backend(s) to measure")
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[10_000, 100_000, 1_000_000])
    ap.add_argument("--institutions", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default="BENCH_secure_overhead.json",
                    help="machine-readable output path ('' to skip)")
    args = ap.parse_args(argv)

    rows = []
    for backend in args.backend:
        rows += run(sizes=tuple(args.sizes),
                    num_institutions=args.institutions,
                    repeats=args.repeats, backend=backend)

    # cross-backend speedup at the largest payload (the headline number)
    by_backend = {}
    for r in rows:
        if "params" in r:
            by_backend.setdefault(r["backend"], {})[r["params"]] = r
    if {"pallas", "reference"} <= by_backend.keys():
        n = max(args.sizes)
        ref, pal = by_backend["reference"][n], by_backend["pallas"][n]
        ref_pr = ref["protect_s"] + ref["reveal_s"]
        pal_pr = pal["protect_s"] + pal["reveal_s"]
        rows.append({
            "check": f"pallas protect+reveal speedup at {n} params",
            "reference_protect_reveal_s": ref_pr,
            "pallas_protect_reveal_s": pal_pr,
            "speedup": ref_pr / max(pal_pr, 1e-12),
            "err_delta": abs(pal["max_abs_err"] - ref["max_abs_err"]),
            "pass": ref_pr / max(pal_pr, 1e-12) >= 3.0
                    and pal["pass"] and ref["pass"],
        })

    out = json.dumps(rows, indent=2)
    print(out)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
