"""Secure-aggregation overhead: Shamir share-protect vs plain aggregation.

Extends the paper's efficiency story to LM-scale payloads: for gradient
pytrees from 1e4 to 1e7 parameters, measures protect (encode+share),
share-wise aggregate over S institutions, reveal (reconstruct+decode)
wall time, the bytes moved (w shares x R residues x 8B vs 4B plain), and
verifies exactness of the revealed sum against the float sum.

The structural claim being validated: protection cost is linear in the
payload and embarrassingly parallel (elementwise Horner), so the secure
path adds a constant small factor over plain aggregation — the LM-scale
analogue of the paper's "central phase is a small share of total time".
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secure_agg import SecureAggregator


def run(sizes=(10_000, 100_000, 1_000_000, 10_000_000),
        num_institutions: int = 4, repeats: int = 3):
    agg = SecureAggregator()
    key = jax.random.PRNGKey(0)
    rows = []
    for n in sizes:
        keys = jax.random.split(key, num_institutions + 1)
        key = keys[0]
        grads = [
            0.01 * jax.random.normal(keys[j + 1], (n,), jnp.float32)
            for j in range(num_institutions)
        ]
        gold = np.sum(np.stack([np.asarray(g, np.float64) for g in grads]),
                      axis=0)

        t_protect = t_agg = t_reveal = 1e30
        for _ in range(repeats):
            t0 = time.perf_counter()
            protected = [
                agg.protect(jax.random.fold_in(key, j), {"g": g})
                for j, g in enumerate(grads)
            ]
            jax.block_until_ready(protected)
            t_protect = min(t_protect, time.perf_counter() - t0)

            t0 = time.perf_counter()
            summed = agg.aggregate(protected)
            jax.block_until_ready(summed)
            t_agg = min(t_agg, time.perf_counter() - t0)

            t0 = time.perf_counter()
            revealed = agg.reveal(summed)
            jax.block_until_ready(revealed)
            t_reveal = min(t_reveal, time.perf_counter() - t0)

        err = float(np.max(np.abs(np.asarray(revealed["g"]) - gold)))
        w = agg.scheme.num_shares
        R = agg.scheme.field.num_residues
        rows.append({
            "params": n,
            "institutions": num_institutions,
            "protect_s": t_protect,
            "aggregate_s": t_agg,
            "reveal_s": t_reveal,
            "total_secure_s": t_protect + t_agg + t_reveal,
            "bytes_secure_per_inst": n * w * R * 8,
            "bytes_plain_per_inst": n * 4,
            "bandwidth_factor": w * R * 2.0,
            "max_abs_err": err,
            "exact_within_codec": err < 1e-6,
            "pass": err < 1e-6,
        })
    # linearity check: 100x params should be < 300x time (no blowup)
    t_small = rows[0]["total_secure_s"]
    t_big = rows[-1]["total_secure_s"]
    ratio = t_big / max(t_small, 1e-9)
    size_ratio = rows[-1]["params"] / rows[0]["params"]
    rows.append({
        "check": "protection cost ~linear in payload",
        "time_ratio": ratio,
        "size_ratio": size_ratio,
        "pass": ratio < 3 * size_ratio,
    })
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
