"""Paper Fig. 3: deviance-trace convergence within 6-8 iterations.

Runs Algorithm 1 on all four studies at tol 1e-10 (the paper's criterion)
and reports the per-iteration objective trace plus the iteration count.
Paper claim: all studies converge in 6~8 iterations.
"""
from __future__ import annotations

from repro.core.newton import secure_fit
from repro.data.datasets import STUDIES, load_study


def run(scale: float = 0.1, protect: str = "gradient"):
    rows = []
    for name in STUDIES:
        study = load_study(name, scale=scale)
        res = secure_fit(study.parts, lam=study.lam, tol=1e-10,
                         protect=protect)
        rows.append({
            "study": name,
            "iterations": res.iterations,
            "converged": res.converged,
            "deviance_trace": [float(x) for x in res.deviance_trace],
            "paper_claim": "6-8 iterations at tol 1e-10 (Fig 3)",
            "pass": res.converged and res.iterations <= 10,
        })
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
