"""Multi-host secure rounds: scan residency + CPU-mesh round latency vs S.

Two measurements, one JSON:

**Part (a) — whole-fit scan residency.**  The per-round fused
``SecureFitDriver`` re-enters Python every round (one jit dispatch + one
host readback of the objective per round); ``rounds="scan"`` runs the
entire fit as ONE ``lax.scan`` with in-graph rng and reads the deviance
trace back once.  Measured at the e2e acceptance config (S=8, d=128,
N=2e5; ``--quick`` shrinks N): wall clock per path, host syncs per fit
(the scan's structural claim: 1 vs one-per-round), and beta parity vs
the per-round loop oracle (exactly 0 — revealed aggregates are
rng-independent, see ``core/scanfit.py``).  On this repo's single-core
CI host the fit is compute-bound (~190 ms/round of f32 Gram at the full
config vs ~1 ms/round of dispatch), so the wall-clock ratio sits near
1x; the JSON therefore also records *modeled* speedups at nominal
per-sync round-trip latencies (10/50/100 ms — the regime a multi-host
deployment actually occupies, where each per-round host sync crosses
the supervisor's network), computed from the measured compute time and
round count with zero extrapolation of the compute itself.  The CI gate
rides on the invariants: one host sync, beta parity, no wall-clock
regression; the 1.5x accelerator target is reported against both the
measured and the modeled ratios.

**Part (b) — CPU-mesh round latency vs S.**  One subprocess per S (the
forced host device count must be owned before jax initializes —
``distributed/xla_flags.mesh_env`` builds the child env; the GPU-only
latency-hiding flags stay off, CPU builds abort on unknown
``--xla_gpu_*`` flags) runs ``scan_secure_rounds`` over a 1D pod mesh at
S ∈ {8, 64, 256} and reports steady-state seconds/round for both wire
paths (replicated + sharded reveal) plus the static bytes/round/device
model.  Gate: round latency at S=256 ≤ 1.5x S=8 — secure-round cost
must be flat in the institution count, not linear.  A 2D
(pod x share) child validates the distributed Lagrange reveal
(``secure_psum_2d``) end to end and times its round.

``--real-kernels`` additionally emits the ``interpret=False`` block-size
knob validation rows (``kernels/tuning.py``): pure arithmetic VMEM
checks of the compiled-path blocking.  On the CPU CI mesh the kernels
still run interpreted — the flag changes nothing about execution there
(documented no-op), it only proves the knobs would compile.

Writes BENCH_multihost_rounds.json (smoke name under --quick).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MODELED_RTTS_MS = (10.0, 50.0, 100.0)


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--records", type=int, default=200_000,
                    help="total N for part (a) (acceptance: 2e5)")
    ap.add_argument("--institutions", type=int, default=8)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--devices-list", type=int, nargs="+",
                    default=[8, 64, 256],
                    help="pod-mesh sizes for part (b), one subprocess each")
    ap.add_argument("--rounds", type=int, default=2,
                    help="scanned rounds per part-(b) timing")
    ap.add_argument("--params", type=int, default=128,
                    help="per-round tree elements for part (b)")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--real-kernels", action="store_true",
                    help="emit interpret=False block-size knob validation "
                         "rows (no-op for execution on CPU CI)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale: N=8000, S list {8, 64}, smoke JSON")
    ap.add_argument("--json", default=None)
    # internal: subprocess entrypoints (one forced device count each)
    ap.add_argument("--child", choices=["1d", "2d"], default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--child-devices", type=int, default=0,
                    help=argparse.SUPPRESS)
    return ap.parse_args(argv)


# ---------------------------------------------------------------- part (a)

def _timed_driver(parts, lam, agg, repeats, **kw):
    import jax
    from repro.core.newton import SecureFitDriver

    def fit():
        drv = SecureFitDriver(parts, lam=lam, protect="both",
                              aggregator=agg, fused=True, **kw)
        drv.run()
        jax.block_until_ready(drv.beta)
        return drv

    drv = fit()  # warmup: trace + compile off the clock
    best = 1e30
    for _ in range(repeats):
        t0 = time.perf_counter()
        drv = fit()
        best = min(best, time.perf_counter() - t0)
    return best, drv


def run_fit_comparison(records, institutions, dim, repeats):
    import jax
    import numpy as np

    from repro.core import SecureAggregator
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    from e2e_secure_fit import _make_parts

    parts, _ = _make_parts(
        jax.random.PRNGKey(0), records, institutions, dim
    )
    agg = SecureAggregator(backend="pallas")
    quant_tol = (institutions + 1) / agg.codec.scale

    t_step, d_step = _timed_driver(parts, 1.0, agg, repeats)
    t_scan, d_scan = _timed_driver(parts, 1.0, agg, repeats,
                                   rounds="scan")
    err = float(np.max(np.abs(
        np.asarray(d_step.beta) - np.asarray(d_scan.beta)
    )))
    rounds = d_step.iteration
    speedup = t_step / max(t_scan, 1e-12)
    rows = [
        {"path": "fit_per_round", "records": records,
         "institutions": institutions, "dim": dim, "seconds": t_step,
         "rounds": rounds, "host_syncs": rounds,
         "converged": bool(d_step.converged)},
        {"path": "fit_scan", "records": records,
         "institutions": institutions, "dim": dim, "seconds": t_scan,
         "rounds": d_scan.iteration, "host_syncs": 1,
         "converged": bool(d_scan.converged)},
    ]
    # modeled multi-host ratio: every host sync costs one supervisor
    # round trip; compute time is the MEASURED scan time (no projection)
    modeled = {}
    for rtt_ms in MODELED_RTTS_MS:
        rtt = rtt_ms / 1e3
        modeled[f"modeled_speedup_at_{rtt_ms:.0f}ms_rtt"] = (
            (t_step + rounds * rtt) / (t_scan + rtt)
        )
    rows.append({
        "check": "scan residency vs per-round fused",
        "speedup": speedup,
        "host_syncs_per_round_path": rounds,
        "host_syncs_scan_path": 1,
        "max_abs_err_vs_loop_oracle": err,
        "quantization_tol": quant_tol,
        "target_accelerator_speedup": 1.5,
        "meets_target_measured": speedup >= 1.5,
        **modeled,
        # the CI gate: structural invariants that hold on any backend —
        # one sync per fit, oracle parity, and no wall-clock regression
        # (the 1.5x target is dispatch-bound; this host is compute-bound
        # on one core, see module docstring)
        "pass": (err <= quant_tol
                 and d_scan.iteration == rounds
                 and speedup >= 0.9),
    })
    return rows


# ---------------------------------------------------------------- part (b)

def _round_payload(params: int, devices: int, agg) -> dict:
    """Static per-device wire bytes for ONE secure round (ring model)."""
    from repro.core.flatbuf import LANES, ROW_ALIGN, _rows_for

    t = agg.scheme.threshold
    num_r = agg.scheme.field.num_residues
    ring = (devices - 1) / devices if devices > 1 else 1.0
    rows = _rows_for(params, ROW_ALIGN)
    rows_sh = _rows_for(params, math.lcm(ROW_ALIGN, devices))
    buf = num_r * rows * LANES * 4          # uint32 share wire
    buf_sh = num_r * rows_sh * LANES * 4
    return {
        "replicated": int(2 * t * buf * ring),
        "sharded": int((t * buf_sh + rows_sh * LANES * 4) * ring),
    }


def run_child_1d(devices: int, params: int, rounds: int, repeats: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.secure_agg import SecureAggregator
    from repro.distributed.multihost import run_scanned_rounds

    agg = SecureAggregator(backend="pallas")
    tree = {"g": 0.01 * jax.random.normal(jax.random.PRNGKey(1), (params,),
                                          jnp.float32)}
    out = {"devices": devices, "params": params, "rounds": rounds}
    for reveal in ("replicated", "sharded"):
        def go():
            final, trace = run_scanned_rounds(
                devices, tree, jax.random.PRNGKey(7), rounds,
                aggregator=agg, reveal=reveal,
            )
            jax.block_until_ready(trace)
            return final

        final = go()  # warmup
        best = 1e30
        for _ in range(repeats):
            t0 = time.perf_counter()
            final = go()
            best = min(best, time.perf_counter() - t0)
        # the mean-preserving chain: every round reveals sum then divides
        # by D, so the final tree must equal the input within quantization
        err = float(np.max(np.abs(
            np.asarray(final["g"]) - np.asarray(tree["g"])
        )))
        out[f"seconds_per_round_{reveal}"] = best / rounds
        out[f"max_abs_err_{reveal}"] = err
        out[f"ok_{reveal}"] = err <= rounds * (devices + 1) / agg.codec.scale
    out["bytes_per_round_per_device"] = _round_payload(params, devices, agg)
    return out


def run_child_2d(pods: int, params: int, repeats: int):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.secure_agg import SecureAggregator, secure_psum
    from repro.distributed.compat import shard_map
    from repro.distributed.multihost import pod_mesh, pod_share_mesh, \
        secure_psum_2d
    from repro.distributed.sharding import POD_AXIS

    agg = SecureAggregator(backend="pallas")
    t = agg.scheme.threshold
    tree = {"g": 0.01 * jax.random.normal(jax.random.PRNGKey(1), (params,),
                                          jnp.float32)}
    key = jax.random.PRNGKey(7)
    mesh2 = pod_share_mesh(pods, t)
    fn2 = jax.jit(shard_map(
        lambda: secure_psum_2d(tree, key, aggregator=agg),
        mesh=mesh2, in_specs=(), out_specs=P(), check_vma=False,
    ))
    out2 = fn2()
    jax.block_until_ready(out2)
    best = 1e30
    for _ in range(repeats):
        t0 = time.perf_counter()
        out2 = fn2()
        jax.block_until_ready(out2)
        best = min(best, time.perf_counter() - t0)
    # oracle: the 1D wire on a pods-sized mesh reveals the same field
    # encoding, so the decoded floats must agree BITWISE
    mesh1 = pod_mesh(pods)
    out1 = jax.jit(shard_map(
        lambda: secure_psum(tree, POD_AXIS, key, aggregator=agg),
        mesh=mesh1, in_specs=(), out_specs=P(), check_vma=False,
    ))()
    err = float(np.max(np.abs(
        np.asarray(out2["g"], np.float64) - np.asarray(out1["g"],
                                                       np.float64)
    )))
    return {"pods": pods, "share_devices": t, "params": params,
            "seconds_per_round": best, "max_abs_err_vs_1d_wire": err,
            "ok": err == 0.0}


def _spawn_child(mode: str, devices: int, pods: int, args) -> dict:
    """Run one forced-device-count measurement in a fresh process."""
    from repro.distributed.xla_flags import mesh_env

    # latency_hiding stays False: the --xla_gpu_* overlap flags abort
    # CPU-only XLA builds (unknown-flag check); GPU launches opt in
    env = mesh_env(host_device_count=devices, base=os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [sys.executable, os.path.abspath(__file__),
           "--child", mode, "--child-devices", str(pods),
           "--params", str(args.params), "--rounds", str(args.rounds),
           "--repeats", str(args.repeats)]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=560)
    if r.returncode != 0:
        raise RuntimeError(
            f"child {mode} S={devices} failed:\n{r.stderr[-2000:]}"
        )
    for line in r.stdout.splitlines():
        if line.startswith("CHILD_JSON: "):
            return json.loads(line[len("CHILD_JSON: "):])
    raise RuntimeError(f"child {mode} S={devices} emitted no JSON row")


def run_mesh_sweep(args) -> list:
    rows = []
    latencies = {}
    for s in args.devices_list:
        row = _spawn_child("1d", s, s, args)
        latencies[s] = row["seconds_per_round_replicated"]
        rows.append({"mesh": "pod_1d", **row})
    s_lo, s_hi = min(args.devices_list), max(args.devices_list)
    ratio = latencies[s_hi] / max(latencies[s_lo], 1e-12)
    rows.append({
        "check": "round latency flat in institutions",
        "s_low": s_lo, "s_high": s_hi,
        "seconds_per_round_low": latencies[s_lo],
        "seconds_per_round_high": latencies[s_hi],
        "latency_ratio": ratio,
        "gate": 1.5,
        "pass": ratio <= 1.5 and all(
            r.get("ok_replicated") and r.get("ok_sharded")
            for r in rows if "ok_replicated" in r
        ),
    })
    # 2D (pod x share) distributed-reveal datapoint at the smallest S:
    # pods * threshold forced devices
    from repro.core.secure_agg import SecureAggregator

    pods_2d = s_lo
    scheme_t = SecureAggregator().scheme.threshold
    row2 = _spawn_child("2d", pods_2d * scheme_t, pods_2d, args)
    rows.append({"mesh": "pod_share_2d", **row2,
                 "pass": bool(row2["ok"])})
    return rows


def run_knob_validation(dim: int) -> list:
    from repro.core.secure_agg import SecureAggregator
    from repro.kernels.tuning import validate_real_kernel_knobs

    agg = SecureAggregator(backend="pallas")
    reports = validate_real_kernel_knobs(
        d=dim,
        num_residues=agg.scheme.field.num_residues,
        threshold=agg.scheme.threshold,
        num_points=agg.scheme.threshold,
    )
    return [{"check": "real-kernel knobs", **r, "pass": r["ok"]} for r in
            reports]


def main(argv=None):
    args = parse_args(argv)
    if args.child:
        # forced device count already in XLA_FLAGS via mesh_env
        if args.child == "1d":
            row = run_child_1d(args.child_devices, args.params,
                               args.rounds, args.repeats)
        else:
            row = run_child_2d(args.child_devices, args.params,
                               args.repeats)
        print("CHILD_JSON: " + json.dumps(row))
        return row

    if args.quick:
        args.records = 8_000
        args.devices_list = [8, 64]

    rows = run_fit_comparison(args.records, args.institutions, args.dim,
                              args.repeats)
    rows += run_mesh_sweep(args)
    if args.real_kernels:
        rows += run_knob_validation(args.dim)

    out = json.dumps(rows, indent=2)
    print(out)
    path = args.json
    if path is None:
        path = ("BENCH_multihost_rounds_smoke.json" if args.quick
                else "BENCH_multihost_rounds.json")
    if path:
        with open(path, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
