"""Paper Fig. 2: secure beta vs. centralized gold standard (R^2 = 1.00).

For each of the four evaluation studies, fit with `secure_fit` (Algorithm 1,
Shamir-protected) and `centralized_fit` (pooled IRLS oracle) and report the
coefficient correlation + max abs error.  The paper claims R^2 = 1.00 across
all studies; we assert >= 0.999999 (fixed-point quantization at 2^-28 is the
only deviation source).
"""
from __future__ import annotations

import numpy as np

from repro.core.newton import centralized_fit, secure_fit
from repro.data.datasets import STUDIES, load_study


def run(scale: float = 0.1, protect: str = "gradient"):
    rows = []
    for name in STUDIES:
        study = load_study(name, scale=scale)
        sec = secure_fit(study.parts, lam=study.lam, protect=protect)
        gold = centralized_fit(*study.pooled(), lam=study.lam)
        r2 = float(np.corrcoef(sec.beta, gold.beta)[0, 1] ** 2)
        rows.append({
            "study": name,
            "samples": study.num_samples,
            "features": study.num_features,
            "r2": r2,
            "max_abs_err": float(np.max(np.abs(sec.beta - gold.beta))),
            "iterations": sec.iterations,
            "paper_claim": "R^2 = 1.00 (Fig 2)",
            "pass": r2 >= 0.999999,
        })
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
