"""Observability tax: traced vs untraced secure fits, bit-parity gated.

The span tracer (``repro.obs.trace``) claims ~zero cost when disabled
and "cheap enough to leave on" when enabled: every span is one
``perf_counter`` pair plus a deque append, all host-side Python around
jitted rounds.  This benchmark pins both claims per driver shape:

* ``loop`` — per-round reference driver (``fused=False``), the chattiest
  shape (most spans per unit work);
* ``fused`` — one jitted graph per round;
* ``scan`` — ``rounds="scan"`` blocks (fewest host transitions, so the
  per-ROUND span cost is amortized across a block).

Gates, per driver shape:

* **overhead** <= 2% per round at the full config (10% under
  ``--quick``, where rounds are too small for a tight timer gate);
* **bit-invisibility** — the traced fit's beta must be BIT-identical to
  the untraced fit's: the tracer may never perturb the protocol.  This
  holds by construction (the in-graph metric leaves are ALWAYS computed;
  tracing only observes host timestamps) and is asserted here.

Timing uses the interleaved-median protocol from fault_overhead.py:
untimed warmups compile everything, then traced/untraced samples run
interleaved with the order flipped every repeat, and the overhead is the
median of per-repeat pairwise ratios — shared-CPU timer drift cancels
instead of reading as fake overhead.

Machine-readable rows land in BENCH_obs_overhead.json (``--quick`` is
the bench_smoke gate size and writes BENCH_obs_overhead_smoke.json).
``--trace-out PREFIX`` additionally exports the final traced run as
PREFIX.jsonl (the run ledger ``results/show.py`` renders) and
PREFIX.trace.json (open in chrome://tracing or https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import SecureAggregator
from repro.core.newton import SecureFitDriver
from repro.data import generate_synthetic
from repro.obs import trace

VARIANTS = ("loop", "fused", "scan")


def _make_driver(parts, variant: str):
    if variant == "loop":
        return SecureFitDriver(parts, lam=1.0, protect="gradient",
                               fused=False)
    agg = SecureAggregator(backend="pallas")
    if variant == "fused":
        return SecureFitDriver(parts, lam=1.0, protect="gradient",
                               aggregator=agg, fused=True)
    return SecureFitDriver(parts, lam=1.0, protect="gradient",
                           aggregator=agg, fused=True, rounds="scan",
                           rounds_per_sync=4)


def _run_once(parts, variant: str):
    """One full fit; returns (seconds, driver)."""
    driver = _make_driver(parts, variant)
    t0 = time.perf_counter()
    driver.run(max_iter=60)
    return time.perf_counter() - t0, driver


def _sample(parts, variant: str, traced: bool):
    """Min-of-2 per-round seconds under the requested tracing state."""
    if traced:
        trace.enable(capacity=1 << 16)
    else:
        trace.disable()
    try:
        (s1, d1), (s2, _) = (_run_once(parts, variant),
                             _run_once(parts, variant))
        return min(s1, s2) / d1.iteration, d1
    finally:
        trace.disable()


def run(num_institutions: int = 4, dim: int = 64, records: int = 80_000,
        repeats: int = 5, seed: int = 0, full_gate: bool = True,
        trace_out: str | None = None):
    study = generate_synthetic(
        jax.random.PRNGKey(seed), num_institutions=num_institutions,
        records_per_institution=records // num_institutions, dim=dim,
    )
    parts = list(study.parts)
    gate = 2.0 if full_gate else 10.0
    rows = []

    for variant in VARIANTS:
        _run_once(parts, variant)  # warmup: trace + compile + packing
        off_rt, on_rt = [], []
        off_d = on_d = None
        for rep in range(repeats):
            order = (False, True) if rep % 2 == 0 else (True, False)
            for traced in order:
                rt, d = _sample(parts, variant, traced)
                (on_rt if traced else off_rt).append(rt)
                if traced:
                    on_d = d
                else:
                    off_d = d

        overhead_pct = (float(np.median(
            [t / b for t, b in zip(on_rt, off_rt)]
        )) - 1.0) * 100.0
        err = float(np.abs(np.asarray(on_d.beta)
                           - np.asarray(off_d.beta)).max())
        rows.append({
            "driver": variant,
            "institutions": num_institutions, "dim": dim,
            "records": records,
            "rounds": off_d.iteration,
            "seconds_per_round_untraced": min(off_rt),
            "seconds_per_round_traced": min(on_rt),
            "overhead_pct": overhead_pct,
            "gate_pct": gate,
            "beta_err_traced_vs_untraced": err,
            "beta_bit_identical": err == 0.0,
            "pass": overhead_pct <= gate and err == 0.0,
        })
        print(f"{variant:<6} untraced {min(off_rt) * 1e3:8.2f} ms/round  "
              f"traced {min(on_rt) * 1e3:8.2f} ms/round  "
              f"overhead {overhead_pct:+6.2f}% (gate {gate:g}%)  "
              f"bit-identical={err == 0.0}")

    if trace_out:
        # export the LOOP driver: its protect/aggregate/reveal happen as
        # host calls, so the trace shows the whole span taxonomy (the
        # fused/scan graphs keep those phases in-graph under one span)
        tracer = trace.enable(capacity=1 << 16)
        _run_once(parts, "loop")
        trace.disable()
        n = tracer.export_jsonl(f"{trace_out}.jsonl")
        tracer.export_chrome_trace(f"{trace_out}.trace.json")
        print(f"exported {n} spans -> {trace_out}.jsonl / "
              f"{trace_out}.trace.json")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--institutions", type=int, default=4)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--records", type=int, default=80_000,
                    help="total N across all institutions")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small config for the bench_smoke gate "
                         "(S=4, d=32, N=20000, 2 repeats; 10% gate)")
    ap.add_argument("--trace-out", default=None,
                    help="also export a traced fused run as "
                         "PREFIX.jsonl + PREFIX.trace.json")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' to skip; "
                         "default BENCH_obs_overhead[_smoke].json)")
    args = ap.parse_args(argv)

    kw = dict(num_institutions=args.institutions, dim=args.dim,
              records=args.records, repeats=args.repeats, seed=args.seed)
    if args.quick:
        kw.update(num_institutions=4, dim=32, records=20_000, repeats=2)
    rows = run(full_gate=not args.quick, trace_out=args.trace_out, **kw)
    rows.append({"config": "quick" if args.quick else "full", **{
        k: kw[k] for k in ("num_institutions", "dim", "records")
    }})

    out = json.dumps(rows, indent=2)
    print(out)
    path = args.json
    if path is None:
        path = ("BENCH_obs_overhead_smoke.json" if args.quick
                else "BENCH_obs_overhead.json")
    if path:
        with open(path, "w") as f:
            f.write(out + "\n")
    if not all(r.get("pass", True) for r in rows):
        raise SystemExit(1)
    return rows


if __name__ == "__main__":
    main()
