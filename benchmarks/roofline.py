"""Roofline analysis over the dry-run artifacts (TPU v5e constants).

Reads results/dryrun/<arch>__<shape>__<mesh>.json (written by
repro.launch.dryrun) and derives, per cell:

    compute_term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory_term     = HLO_bytes_per_device / HBM_bw            [s]
    collective_term = collective_bytes_per_device / link_bw    [s]

plus the dominant bottleneck, MODEL_FLOPS (6*N*D train / 2*N*D forward,
N = active params, D = tokens), and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.  Numbers come from the trip-count-aware HLO walk
(launch.hlo_analysis), not XLA's loop-unaware cost_analysis.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one link assumed per collective hop).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 4_096 * 256,
    "prefill_32k": 32_768 * 32,
    "decode_32k": 128,          # one token per slot per step
    "long_500k": 1,
}


def model_flops(shape: str, active_params: int) -> float:
    tokens = SHAPE_TOKENS[shape]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * active_params * tokens


def analyze_cell(rec: dict) -> dict:
    devices = rec["devices"]
    h = rec["hlo_analysis"]
    comp = h["flops_per_device"] / PEAK_FLOPS
    mem = h["bytes_per_device"] / HBM_BW
    coll_bytes = sum(h["collective_bytes_per_device"].values())
    coll = coll_bytes / LINK_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["shape"], rec["model"]["active_params"])
    hlo_total = h["flops_per_device"] * devices
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful compute time over the actual bottleneck time
    ideal_s = mf / devices / PEAK_FLOPS
    bound_s = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_compute_ratio": useful,
        "roofline_fraction": ideal_s / bound_s if bound_s else 0.0,
        "temp_gb_per_device": rec["memory"]["temp_bytes_per_device"] / 2**30,
        "collective_bytes": coll_bytes,
    }


def run(dryrun_dir: str = "results/dryrun", mesh: str = "singlepod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": rec["skipped"]})
            continue
        rows.append(analyze_cell(rec))
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | temp GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"N/A (skip) | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_compute_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | "
            f"{r['temp_gb_per_device']:.1f} |\n"
        )
    return "".join(out)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="singlepod")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = run(args.dir, args.mesh)
    if args.markdown:
        print(to_markdown(rows))
    else:
        print(json.dumps(rows, indent=2))
