"""Paper Table 1: runtime + central-phase share + bytes transmitted.

Reproduces the structure of Table 1 on the four studies: total runtime,
centralized (secure) phase runtime, its share of total, iteration count and
network bytes.  The paper's headline structural claim — the secure central
phase is a small fraction of total time (0.6%-13%) because the heavy
per-record work stays institution-local — is asserted as share < 0.5 even on
CPU-simulated hardware.  Absolute seconds are container-specific and are
reported, not asserted.
"""
from __future__ import annotations

from repro.core.newton import secure_fit
from repro.data.datasets import STUDIES, load_study

PAPER_TABLE1 = {
    "insurance": {"samples": 9_822, "iterations": 8, "central_s": 0.42,
                  "total_s": 3.77, "mb": 80},
    "parkinsons.motor": {"samples": 5_875, "iterations": 6,
                         "central_s": 0.264, "total_s": 2.017, "mb": 492},
    "parkinsons.total": {"samples": 5_875, "iterations": 6,
                         "central_s": 0.236, "total_s": 2.352, "mb": 492},
    "synthetic": {"samples": 1_000_000, "iterations": 6, "central_s": 0.076,
                  "total_s": 12.76, "mb": 612},
}


def run(scale: float = 0.1, protect: str = "gradient", repeats: int = 2):
    rows = []
    for name in STUDIES:
        study = load_study(name, scale=scale)
        best = None
        for _ in range(repeats):
            res = secure_fit(study.parts, lam=study.lam, protect=protect)
            if best is None or res.total_seconds < best.total_seconds:
                best = res
        share = best.central_seconds / max(best.total_seconds, 1e-12)
        rows.append({
            "study": name,
            "samples": study.num_samples,
            "features": study.num_features,
            "iterations": best.iterations,
            "central_seconds": best.central_seconds,
            "total_seconds": best.total_seconds,
            "central_share": share,
            "mb_transmitted": best.bytes_transmitted / 1e6,
            "paper_row": PAPER_TABLE1[name],
            "pass": share < 0.5 and best.converged,
        })
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
