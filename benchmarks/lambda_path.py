"""Secure λ-path cross-validation: batched scanned sweep vs sequential fits.

The selection subsystem's acceptance benchmark.  A consortium choosing λ
by K-fold CV over an L-point grid needs L*K regularized fits plus secure
held-out evaluation.  Pre-subsystem, that is L*K sequential ``secure_fit``
calls — each repacking/rescanning its train folds, re-dispatching the
protocol per iteration, converging from zero — plus one secure reveal of
the per-fold validation metrics per fit.  The subsystem
(``repro.selection.secure_cv_path``) runs the whole sweep as batched
multi-round secure graphs: fold masks composed onto the packed row masks
(one data pass per round, NO per-fold repacks), a leading config axis
through one protect/aggregate/reveal launch per phase per round,
``lax.scan``-resident rounds with in-graph rng, and warm starts down the
descending λ path (which collapse late-path Newton counts to 2-3 rounds).

Three execution shapes, all producing the same CV curve, the same 1-SE λ,
and per-(λ, fold) converged betas equal within fixed-point quantization:

* ``sequential_loop``  — the pre-subsystem baseline and the *oracle*:
  per-(λ, fold) ``secure_fit(fused=False)`` loop fits (per-institution
  dispatches over the PR-1 protocol kernels) + a secure validation-metric
  round per fit + a full-data refit at the picked λ.  The headline >= 3x
  gate is against this row.
* ``sequential_fused`` — the same L*K schedule on the fused jit-resident
  ``secure_fit`` (informational: isolates what the *sweep-level* batching
  and warm starts buy beyond single-fit fusion).
* ``batched``          — the subsystem sweep.

Interpret-mode caveat: as in ``e2e_secure_fit.py``, the protocol kernels
run through the Pallas interpreter and the CV summaries run as the XLA
functional simulation of ``fused_irls_cv_pallas`` (identical numerics
contract).  The CV curve is measured on the ``summaries_backend="pallas"``
rung — converged-beta parity within quantization (the ladder's f32-Gram
contract; see benchmarks/README.md).  Machine-readable rows land in
BENCH_lambda_path.json (``--quick`` is the bench_smoke gate size).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SecureAggregator, secure_fit
from repro.core.logreg import deviance as dev_fn
from repro.selection import assign_folds, one_se_rule, secure_cv_path

try:  # same data shapes as the e2e benchmark: one ragged-ramp helper
    from .e2e_secure_fit import _make_parts as _e2e_make_parts
except ImportError:  # run as a script: benchmarks/ is sys.path[0]
    from e2e_secure_fit import _make_parts as _e2e_make_parts


def _make_parts(key, total: int, s: int, d: int):
    parts, _pooled = _e2e_make_parts(key, total, s, d)
    return parts


def _lambda_grid(num: int) -> list[float]:
    """Descending log-spaced grid (glmnet direction)."""
    return list(np.logspace(1.5, -1.5, num))


def _secure_val_metrics(agg, key, beta, val_parts):
    """Secure reveal of cohort-aggregate held-out metrics at ``beta``.

    What a pre-subsystem consortium would bolt onto each fold fit: every
    institution protects its (val deviance, correct count, row count),
    shares aggregate, only the sums are revealed.
    """
    protected = []
    for j, (Xv, yv) in enumerate(val_parts):
        z = Xv @ beta
        tree = {
            "val_deviance": dev_fn(jnp.asarray(beta), Xv, yv),
            "val_correct": jnp.sum(
                jnp.where((z > 0.0) == (yv > 0.5), 1.0, 0.0)
            ),
            "val_count": jnp.asarray(float(Xv.shape[0])),
        }
        protected.append(agg.protect(jax.random.fold_in(key, j), tree))
    return agg.reveal(agg.aggregate(protected))


def _sequential_cv(parts, folds, lambdas, num_folds, protect, agg, lam_l1,
                   fused, tol=1e-10):
    """L*K sequential secure_fit calls + secure held-out rounds + refit.

    Fold-major order so each fold's train pack stays LRU-resident across
    the inner λ loop (the kindest schedule for the baseline).
    """
    L, K = len(lambdas), num_folds
    d = parts[0][0].shape[1]
    betas = np.zeros((L, K, d))
    vdev = np.zeros((L, K))
    vcorr = np.zeros((L, K))
    vcnt = np.zeros((L, K))
    iters = np.zeros((L, K), np.int32)
    key = jax.random.PRNGKey(123)
    for k in range(K):
        train_parts = [
            (X[f != k], y[f != k]) for (X, y), f in zip(parts, folds)
        ]
        val_parts = [
            (X[f == k], y[f == k]) for (X, y), f in zip(parts, folds)
        ]
        for li, lam in enumerate(lambdas):
            res = secure_fit(train_parts, lam=lam, l1=lam_l1, tol=tol,
                             protect=protect, aggregator=agg, fused=fused)
            betas[li, k] = res.beta
            iters[li, k] = res.iterations
            key, sub = jax.random.split(key)
            m = _secure_val_metrics(agg, sub, res.beta, val_parts)
            vdev[li, k] = float(m["val_deviance"])
            vcorr[li, k] = float(m["val_correct"])
            vcnt[li, k] = float(m["val_count"])
    per_rec = vdev / np.maximum(vcnt, 1.0)
    cv_mean = per_rec.mean(axis=1)
    cv_se = per_rec.std(axis=1, ddof=1) / np.sqrt(K)
    _, pick = one_se_rule(np.asarray(lambdas), cv_mean, cv_se)
    refit = secure_fit(parts, lam=lambdas[pick], l1=lam_l1, tol=tol,
                       protect=protect, aggregator=agg, fused=fused)
    return {
        "fold_betas": betas, "iters": iters, "cv_mean": cv_mean,
        "cv_se": cv_se, "pick": pick, "beta": np.asarray(refit.beta),
        "total_fit_iters": int(iters.sum()) + refit.iterations,
    }


def run(num_institutions: int = 8, dim: int = 128, records: int = 200_000,
        num_lambdas: int = 8, num_folds: int = 5, protect: str = "both",
        l1: float = 0.0, seed: int = 0, full_gate: bool = True):
    parts = _make_parts(
        jax.random.PRNGKey(seed), records, num_institutions, dim
    )
    lambdas = _lambda_grid(num_lambdas)
    agg = SecureAggregator(backend="pallas")
    quant_tol = (num_institutions + 1) / agg.codec.scale
    folds = [
        np.asarray(assign_folds(X.shape[0], num_folds, j, 0))
        for j, (X, _) in enumerate(parts)
    ]
    common = dict(num_institutions=num_institutions, dim=dim,
                  records=records, num_lambdas=num_lambdas,
                  num_folds=num_folds, protect=protect)

    # ---- batched scanned sweep (warmup: 1-λ path covers both jit traces)
    secure_cv_path(parts, lambdas[:1], num_folds=num_folds, l1=l1,
                   protect=protect, aggregator=agg, seed=seed)
    t0 = time.perf_counter()
    rep = secure_cv_path(parts, lambdas, num_folds=num_folds, l1=l1,
                         protect=protect, aggregator=agg, seed=seed)
    batched_s = time.perf_counter() - t0

    rows, results = [], {}
    rows.append({
        "path": "batched", **common,
        "seconds": batched_s,
        "secure_rounds": rep.rounds_total,
        "bytes_per_round": rep.bytes_per_round,
        "bytes_total": rep.bytes_total,
        "lambda_1se": rep.lambda_1se,
        "lambda_best": rep.lambda_best,
        "all_converged": bool(rep.fold_converged.all()),
        "summaries_backend": rep.summaries_backend,
        "pass": bool(rep.fold_converged.all()),
    })

    # ---- sequential baselines
    for name, fused in (("sequential_loop", False),
                        ("sequential_fused", True)):
        # warm every fold's traces outside the timed region, for BOTH
        # baselines: the fused path compiles one iteration graph per
        # fold pack shape, the loop path one local_summaries per
        # institution-fold shape (plus the shared protect/reveal and
        # val-metric graphs) — the timed region must measure the
        # steady-state schedule, not first-call jit
        for k in range(num_folds):
            train_k = [(X[f != k], y[f != k])
                       for (X, y), f in zip(parts, folds)]
            res = secure_fit(train_k, lam=lambdas[0], l1=l1,
                             protect=protect, aggregator=agg,
                             fused=fused, max_iter=2)
            _secure_val_metrics(
                agg, jax.random.PRNGKey(0), jnp.asarray(res.beta),
                [(X[f == k], y[f == k])
                 for (X, y), f in zip(parts, folds)],
            )
        t0 = time.perf_counter()
        seq = _sequential_cv(parts, folds, lambdas, num_folds, protect,
                             agg, l1, fused)
        secs = time.perf_counter() - t0
        results[name] = (secs, seq)
        rows.append({
            "path": name, **common,
            "seconds": secs,
            "fit_iterations_total": seq["total_fit_iters"],
            "lambda_1se": lambdas[seq["pick"]],
            "pass": True,
        })

    # ---- the acceptance check row: >= 3x over the sequential loop oracle
    # at the same selected λ and fold betas within quantization
    for base, gate in (("sequential_loop", 3.0 if full_gate else 1.0),
                       ("sequential_fused", None)):
        base_s, seq = results[base]
        beta_err = float(np.abs(rep.fold_betas - seq["fold_betas"]).max())
        refit_err = float(np.abs(rep.beta - seq["beta"]).max())
        row = {
            "check": f"batched sweep vs {base}",
            "protect": protect,
            "baseline_seconds": base_s,
            "batched_seconds": batched_s,
            "speedup": base_s / max(batched_s, 1e-12),
            "same_lambda_1se": bool(
                rep.lambda_1se == lambdas[seq["pick"]]
            ),
            "max_fold_beta_err": beta_err,
            "refit_beta_err": refit_err,
            "quantization_tol": quant_tol,
            "betas_within_quantization": bool(
                beta_err <= quant_tol and refit_err <= quant_tol
            ),
        }
        if gate is not None:
            row["pass"] = bool(
                row["speedup"] >= gate
                and row["same_lambda_1se"]
                and row["betas_within_quantization"]
            )
        rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--institutions", type=int, default=8)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--records", type=int, default=200_000,
                    help="total N across all institutions")
    ap.add_argument("--lambdas", type=int, default=8,
                    help="λ-grid length L (log-spaced, descending)")
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--protect", default="both",
                    choices=("none", "gradient", "hessian", "both"))
    ap.add_argument("--l1", type=float, default=0.0)
    ap.add_argument("--quick", action="store_true",
                    help="small config for the bench_smoke gate (S=4, "
                         "d=32, N=2e4, L=4, K=3; the 3x headline gate "
                         "applies to the full config only)")
    ap.add_argument("--json", default="BENCH_lambda_path.json",
                    help="machine-readable output path ('' to skip)")
    args = ap.parse_args(argv)

    kw = dict(num_institutions=args.institutions, dim=args.dim,
              records=args.records, num_lambdas=args.lambdas,
              num_folds=args.folds, protect=args.protect, l1=args.l1)
    if args.quick:
        kw.update(num_institutions=4, dim=32, records=20_000,
                  num_lambdas=4, num_folds=3)
    rows = run(full_gate=not args.quick, **kw)
    rows.append({"config": "quick" if args.quick else "full", **{
        k: kw[k] for k in ("num_institutions", "dim", "records",
                           "num_lambdas", "num_folds", "protect")
    }})

    out = json.dumps(rows, indent=2)
    print(out)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
