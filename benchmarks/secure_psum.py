"""In-SPMD secure_psum: flat-buffer sharded wire vs the old per-leaf tree.

Measures, for a gradient-sized float32 pytree all-reduced securely over a
D-device "pod" (institution) axis:

* **payload bytes per device, from static shapes alone** — the number the
  acceptance gate rides on.  Ring-collective accounting: an all-reduce of
  a B-byte buffer moves ``2 * B * (D-1)/D`` per device (reduce-scatter +
  all-gather phases), a lone reduce-scatter or all-gather ``B * (D-1)/D``.
  Share payloads are counted at their *wire dtype*: uint32 for the flat
  tile buffer (a deployment fabric reduces shares with per-hop modular
  adds, so reduced 31-bit residues travel in 4 bytes; the in-graph jax
  simulation widens to uint64 only because XLA's psum has no per-hop mod
  — see ``check_aggregation_headroom``), uint64 for the old per-leaf tree
  whose share tensors ARE uint64.
* **wall clock** — min-of-repeats of the jitted shard_map program on D
  forced host devices (one CPU underneath: structure, not fabric speed).
* **exactness** — every revealed aggregate vs the float64 sum.

Paths:

* ``plain``           — jax.lax.psum of the float tree (no privacy).
* ``per_leaf``        — frozen replica of the pre-PR secure_psum: per-leaf
                        reference protect, psum of the FULL (w, R, ...)
                        uint64 share tree, reconstruction from all w
                        points on every device.  The baseline the ISSUE
                        gate compares against (kept inline so library
                        changes cannot silently move it).
* ``flat_replicated`` — secure_psum on the flat-buffer wire: one packed
                        (rows, 128) buffer, fused encode+share of ONLY
                        the t reveal points, one uint32-wire psum, fused
                        Lagrange+CRT reveal on every device.
* ``flat_sharded``    — secure_psum(reveal="sharded"): reduce-scatter of
                        the share buffer over the pod axis (each device
                        holds 1/D of the distributed residues), local
                        reveal of the row tile, all-gather of the decoded
                        float aggregate.

Acceptance (ISSUE 5): at 1e6 params the sharded flat wire must transmit
<= 0.55x the per-leaf payload with revealed aggregates matching the
reference oracle within quantization tolerance.  Writes
BENCH_secure_psum.json (or BENCH_secure_psum_smoke.json under --quick;
scripts/bench_smoke.sh runs the quick gate standing).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--params", type=int, default=1_000_000,
                    help="elements in the gradient tree (acceptance: 1e6)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host device count = pod axis size")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale (1e5 params, 2 repeats) and the "
                         "smoke JSON filename")
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_secure_psum.json, "
                         "smoke name under --quick; '' to skip)")
    return ap.parse_args(argv)


def _payload_rows(params: int, devices: int, agg, dtype_bytes: int = 4):
    """Static per-device wire-byte model for every path (see module doc)."""
    from repro.core.flatbuf import LANES, ROW_ALIGN, _rows_for

    scheme = agg.scheme
    w, t = scheme.num_shares, scheme.threshold
    num_r = scheme.field.num_residues
    ring = (devices - 1) / devices if devices > 1 else 1.0
    rows = _rows_for(params, ROW_ALIGN)
    rows_sharded = _rows_for(params, math.lcm(ROW_ALIGN, devices))
    flat_buf = num_r * rows * LANES * 4  # uint32 wire, t slices travel
    flat_buf_sharded = num_r * rows_sharded * LANES * 4
    return {
        "plain": 2 * params * dtype_bytes * ring,
        "per_leaf": 2 * w * num_r * params * 8 * ring,  # uint64 share tree
        "flat_replicated": 2 * t * flat_buf * ring,
        "flat_sharded": (t * flat_buf_sharded  # reduce-scatter, one way
                         + rows_sharded * LANES * dtype_bytes) * ring,
    }


def run(params: int, devices: int, repeats: int):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.secure_agg import SecureAggregator, secure_psum
    from repro.distributed.compat import shard_map
    from repro.distributed.sharding import POD_AXIS

    agg_pal = SecureAggregator(backend="pallas")
    agg_ref = SecureAggregator(backend="reference")
    key = jax.random.PRNGKey(0)
    tree = {"g": 0.01 * jax.random.normal(key, (params,), jnp.float32)}
    gold = devices * np.asarray(tree["g"], np.float64)
    mesh = jax.make_mesh((devices,), (POD_AXIS,))
    psum_key = jax.random.PRNGKey(7)

    def spmd(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=(), out_specs=P(),
                                 check_vma=False))

    def per_leaf_frozen():
        """Pre-PR secure_psum, frozen: full uint64 tree, all-w reveal."""
        idx = jax.lax.axis_index(POD_AXIS)
        k = jax.random.fold_in(psum_key, idx)
        protected = agg_ref.protect(k, tree)

        def field_psum(shares):
            summed = jax.lax.psum(shares.astype(jnp.uint64), POD_AXIS)
            p = agg_ref.scheme.field.moduli_array().reshape(
                (1, agg_ref.scheme.field.num_residues)
                + (1,) * (shares.ndim - 2)
            )
            return (summed % p).astype(shares.dtype)

        aggregated = jax.tree_util.tree_map(field_psum, protected)
        w = agg_ref.scheme.num_shares
        recon = agg_ref.scheme.reconstruct_pytree(
            aggregated, list(range(1, w + 1))
        )
        return jax.tree_util.tree_map(
            lambda v: agg_ref.codec.decode(v, dtype=jnp.float32), recon
        )

    fns = {
        "plain": spmd(lambda: jax.lax.psum(tree, POD_AXIS)),
        "per_leaf": spmd(per_leaf_frozen),
        "flat_replicated": spmd(lambda: secure_psum(
            tree, POD_AXIS, psum_key, aggregator=agg_pal,
            reveal="replicated")),
        "flat_sharded": spmd(lambda: secure_psum(
            tree, POD_AXIS, psum_key, aggregator=agg_pal,
            reveal="sharded")),
    }
    payload = _payload_rows(params, devices, agg_pal)
    quant_tol = (devices + 1) * 0.5 / agg_pal.codec.scale

    rows = []
    timings, outs = {}, {}
    for name, fn in fns.items():
        out = fn()
        jax.block_until_ready(out)  # warmup: trace + compile off the clock
        best = 1e30
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        timings[name], outs[name] = best, out
        err = float(np.max(np.abs(np.asarray(out["g"], np.float64) - gold)))
        rows.append({
            "path": name,
            "params": params,
            "devices": devices,
            "seconds": best,
            "payload_bytes_per_device": int(payload[name]),
            "max_abs_err": err,
            "quantization_tol": quant_tol,
            "pass": err <= (1e-6 if name == "plain" else quant_tol),
        })

    # the secure paths must agree with each other bit-for-bit: same codec,
    # exact field arithmetic, only the wire differs
    flat_vs_oracle = float(np.max(np.abs(
        np.asarray(outs["flat_sharded"]["g"], np.float64)
        - np.asarray(outs["per_leaf"]["g"], np.float64)
    )))
    rows.append({
        "check": "sharded payload vs per_leaf",
        "per_leaf_payload_bytes": int(payload["per_leaf"]),
        "flat_replicated_payload_bytes": int(payload["flat_replicated"]),
        "flat_sharded_payload_bytes": int(payload["flat_sharded"]),
        "replicated_ratio": payload["flat_replicated"] / payload["per_leaf"],
        "sharded_ratio": payload["flat_sharded"] / payload["per_leaf"],
        "max_abs_err_vs_oracle": flat_vs_oracle,
        "pass": (payload["flat_sharded"] / payload["per_leaf"] <= 0.55
                 and flat_vs_oracle == 0.0),
    })
    rows.append({
        "check": "sharded wallclock vs per_leaf",
        "per_leaf_seconds": timings["per_leaf"],
        "flat_replicated_seconds": timings["flat_replicated"],
        "flat_sharded_seconds": timings["flat_sharded"],
        "plain_seconds": timings["plain"],
        "speedup": timings["per_leaf"] / max(timings["flat_sharded"], 1e-12),
        "secure_overhead_vs_plain": timings["flat_sharded"]
        / max(timings["plain"], 1e-12),
        "pass": timings["per_leaf"]
        / max(timings["flat_sharded"], 1e-12) >= 1.0,
    })
    return rows


def main(argv=None):
    args = parse_args(argv)
    # the forced device count must be owned before jax initializes
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={args.devices}"
    ).strip()
    if "jax" in sys.modules:
        raise SystemExit("secure_psum benchmark must own jax init "
                         "(run as a script, not after importing jax)")
    params = 100_000 if args.quick else args.params
    repeats = min(args.repeats, 2) if args.quick else args.repeats
    rows = run(params, args.devices, repeats)
    out = json.dumps(rows, indent=2)
    print(out)
    path = args.json
    if path is None:
        path = ("BENCH_secure_psum_smoke.json" if args.quick
                else "BENCH_secure_psum.json")
    if path:
        with open(path, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
