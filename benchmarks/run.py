"""Benchmark harness: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # all, CI-scaled
  PYTHONPATH=src python -m benchmarks.run --only accuracy runtime
  PYTHONPATH=src python -m benchmarks.run --scale 1.0  # paper-size rows

Outputs one JSON per benchmark under results/bench/ and a summary CSV of
``name,pass,seconds`` to stdout.  The roofline benchmark reads the dry-run
artifacts (results/dryrun) and is skipped when absent.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--scale", type=float, default=0.05,
                    help="study row-count scale (1.0 = paper size)")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from . import (accuracy, convergence, runtime, scalability, roofline,
                   secure_overhead)

    benches = {
        "accuracy": lambda: accuracy.run(scale=args.scale),
        "convergence": lambda: convergence.run(scale=args.scale),
        "runtime": lambda: runtime.run(scale=args.scale),
        "scalability": lambda: scalability.run(
            records_each=max(200, int(10_000 * args.scale))
        ),
        "secure_overhead": lambda: secure_overhead.run(
            sizes=(10_000, 100_000, 1_000_000)
        ),
        "roofline": lambda: roofline.run(),
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if k in args.only}

    print("name,pass,seconds,rows")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR({type(e).__name__}: {e}),"
                  f"{time.perf_counter() - t0:.2f},0")
            failures += 1
            continue
        dt = time.perf_counter() - t0
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=2)
        ok = all(r.get("pass", True) for r in rows if isinstance(r, dict))
        failures += 0 if ok else 1
        print(f"{name},{ok},{dt:.2f},{len(rows)}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
