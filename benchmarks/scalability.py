"""Paper Fig. 4: flat runtime scaling in the number of institutions.

Simulates studies of S = 5..100 institutions (10k records each in the
paper; scaled down here) and reports central + total runtime per S.  The
paper's claim is near-constant central-phase time because share-wise
aggregation is O(S) tiny uint64 adds while per-institution work runs in
parallel.  Our simulation executes institutions sequentially on one CPU, so
we report *central-phase* flatness (the secure part) and the per-institution
time (total/S), both of which should be ~flat.
"""
from __future__ import annotations

import jax

from repro.core.newton import secure_fit
from repro.data.synthetic import generate_synthetic


def run(institution_counts=(5, 10, 25, 50, 100), records_each: int = 1000,
        dim: int = 6, protect: str = "gradient"):
    rows = []
    for S in institution_counts:
        study = generate_synthetic(
            jax.random.PRNGKey(7), num_institutions=S,
            records_per_institution=records_each, dim=dim,
        )
        res = secure_fit(list(study.parts), lam=1.0, protect=protect)
        rows.append({
            "institutions": S,
            "records_total": S * records_each,
            "iterations": res.iterations,
            "central_seconds": res.central_seconds,
            "central_seconds_per_iter": res.central_seconds
            / max(res.iterations, 1),
            "per_institution_seconds": res.total_seconds / S,
            "total_seconds": res.total_seconds,
        })
    # flatness check: central time per iteration grows sub-linearly in S
    c5 = rows[0]["central_seconds_per_iter"]
    c100 = rows[-1]["central_seconds_per_iter"]
    s_ratio = rows[-1]["institutions"] / rows[0]["institutions"]
    rows.append({
        "check": "central phase sub-linear in S (paper: ~flat)",
        "central_ratio_100_vs_5": c100 / max(c5, 1e-12),
        "institution_ratio": s_ratio,
        "pass": c100 / max(c5, 1e-12) < s_ratio,
    })
    return rows


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))
