"""Fault-tolerance tax: supervised vs bare secure rounds + recovery latency.

Three question the supervisor PR must answer with numbers:

* ``supervision overhead`` — what does routing every fused coordinator
  round through ``RoundSupervisor`` (SimClock + HeartbeatMonitor beats +
  quorum/threshold preflight + telemetry stamping) cost when NOTHING
  fails?  The control plane is pure Python around one jitted round, so
  the acceptance gate is <= 2% per-round overhead at the full config —
  and the fault-free supervised beta must be BIT-identical to the bare
  run (supervision must not perturb the protocol).
* ``overflow_check overhead`` — the debug-mode fixed-point overflow
  assert (``SecureAggregator(overflow_check=True)``) rides a
  ``jax.debug.callback`` on every protect dispatch.  The cost is a
  FIXED per-round host callback (one ``protect_batched`` per fused
  round) — typically 1-3 ms, with multi-ms jitter from host-callback
  latency under load — so the row reports the absolute per-round cost
  and gates the arm-by-default recommendation (informationally) on
  <= 3.3 ms: 2% of the production fused round
  (BENCH_e2e_secure_fit full config: 165-465 ms/round).  At this
  benchmark's smaller rounds the same absolute cost reads as a much
  larger relative percent; the absolute number is the invariant one.
  Within the gate it is cheap enough to arm by default in the examples
  and the launch driver's secure paths (the alternative — silent
  saturation revealing a plausible-but-wrong aggregate — is the worst
  failure mode the protocol has).
* ``recovery latency`` — for three canned survivable chaos schedules
  (quorum-loss flap burst, center death between protect and reveal,
  loss of both spare centers), how many retries / how much simulated
  backoff / how many extra wall-clock seconds does the study pay, and
  does it still land on the fault-free oracle beta?  Center-fault rows
  must match the oracle EXACTLY (reveals are independent of the sharing
  randomness and of which >= t points reconstruct); institution-fault
  rows must match within fixed-point quantization.

Timing: untimed warmups trigger all trace/compile work and the
one-per-study partition packing (globally LRU-cached, so
bare/supervised/chaos runs all hit the same cache); the fault-free
variants then run INTERLEAVED and each overhead is the median of
per-repeat pairwise ratios, so shared-CPU timer drift cancels instead
of reading as fake overhead (see the comment in ``run``).
Machine-readable rows land in BENCH_fault_overhead.json (``--quick`` is
the bench_smoke gate size and writes BENCH_fault_overhead_smoke.json).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import Institution, SecureAggregator, StudyCoordinator
from repro.data import generate_synthetic
from repro.runtime import FailureInjector, FaultPolicy, RoundSupervisor


def _make_parts(seed: int, s: int, per_inst: int, d: int):
    study = generate_synthetic(
        jax.random.PRNGKey(seed), num_institutions=s,
        records_per_institution=per_inst, dim=d,
    )
    return list(study.parts)


def _make_coord(parts, agg, *, lam=1.0, protect="both", seed=0):
    insts = [Institution(f"i{j}", Xj, yj)
             for j, (Xj, yj) in enumerate(parts)]
    return StudyCoordinator(insts, lam=lam, protect=protect,
                            aggregator=agg, seed=seed, fused=True)


def _policy():
    # the benchmark's fixed control-plane knobs: deterministic, and the
    # flap schedule below is tuned so its parties heal under exactly this
    # backoff ladder (1 + 2 simulated seconds across two retries)
    return FaultPolicy(max_retries=4, backoff_base=1.0, backoff_factor=2.0,
                       round_seconds=1.0, heartbeat_timeout=5.0,
                       reprovision_after=1)


def _run_bare(parts, agg, repeats):
    best, coord = 1e30, None
    for _ in range(repeats):
        coord = _make_coord(parts, agg)
        t0 = time.perf_counter()
        while not coord.converged and coord.iteration < 60:
            coord.step()
        best = min(best, time.perf_counter() - t0)
    return best, coord


def _run_supervised(parts, agg, repeats, schedule=None):
    best, coord, sup = 1e30, None, None
    for _ in range(repeats):
        coord = _make_coord(parts, agg)
        sup = RoundSupervisor(coord, policy=_policy(),
                              injector=FailureInjector(schedule or {}))
        t0 = time.perf_counter()
        sup.run(max_rounds=60)
        best = min(best, time.perf_counter() - t0)
    return best, coord, sup


def run(num_institutions: int = 4, dim: int = 64, records: int = 80_000,
        repeats: int = 3, seed: int = 0, full_gate: bool = True):
    parts = _make_parts(seed, num_institutions, records // num_institutions,
                        dim)
    agg = SecureAggregator(backend="pallas")
    quant_tol = (num_institutions + 1) / agg.codec.scale
    rows = []

    # ---- supervision + overflow_check overhead (fault-free) ----------------
    # Measurement protocol: this container's shared-CPU timer drifts by
    # several percent over a benchmark run, which back-to-back timing
    # blocks absorb as fake overhead (and a 2% gate cannot survive).
    # So the three fault-free variants run INTERLEAVED — a min-of-2
    # sample of each per repeat, order flipped every repeat — and the
    # overheads are the MEDIAN of the per-repeat pairwise ratios, which
    # cancels drift (each ratio compares runs taken seconds apart) and
    # sheds outlier repeats.
    agg_chk = SecureAggregator(backend="pallas", overflow_check=True)
    _run_bare(parts, agg, 1)      # warmup: trace + compile + packing
    _run_supervised(parts, agg, 1)
    _run_bare(parts, agg_chk, 1)  # warmup the checked protect graph
    bare_rt, sup_rt, chk_rt, bare_tot, sup_tot = [], [], [], [], []
    bare = sup_c = chk = None
    for rep in range(repeats):
        # each sample is min-of-2 study runs; the variant order flips
        # every repeat so slow drift biases no variant systematically
        order = "bsc" if rep % 2 == 0 else "csb"
        for which in order:
            if which == "b":
                (s1, bare), (s2, _) = (_run_bare(parts, agg, 1),
                                       _run_bare(parts, agg, 1))
                bare_rt.append(min(s1, s2) / bare.iteration)
                bare_tot.append(min(s1, s2))
            elif which == "s":
                (s1, sup_c, sup), (s2, _, _) = (
                    _run_supervised(parts, agg, 1),
                    _run_supervised(parts, agg, 1))
                sup_rt.append(min(s1, s2) / sup_c.iteration)
                sup_tot.append(min(s1, s2))
            else:
                (s1, chk), (s2, _) = (_run_bare(parts, agg_chk, 1),
                                      _run_bare(parts, agg_chk, 1))
                chk_rt.append(min(s1, s2) / chk.iteration)
    bare_s, sup_s = min(bare_tot), min(sup_tot)
    oracle = np.asarray(bare.beta)
    for name, secs, rt, coord in (
            ("bare_fused_coordinator", bare_s, bare_rt, bare),
            ("supervised_fused_coordinator", sup_s, sup_rt, sup_c)):
        rows.append({
            "path": name,
            "institutions": num_institutions, "dim": dim, "records": records,
            "seconds": secs,
            "seconds_per_round": min(rt),
            "rounds": coord.iteration,
            "converged": bool(coord.converged),
        })
    overhead_pct = (float(np.median(
        [s / b for s, b in zip(sup_rt, bare_rt)]
    )) - 1.0) * 100.0
    sup_err = float(np.abs(np.asarray(sup_c.beta) - oracle).max())
    # the acceptance gate: <= 2% at the full config; the quick config's
    # rounds are small enough that timer noise dominates even the
    # interleaved medians, so it only excludes gross regressions
    gate = 2.0 if full_gate else 10.0
    rows.append({
        "check": "supervision overhead fault-free",
        "seconds_per_round_bare": min(bare_rt),
        "seconds_per_round_supervised": min(sup_rt),
        "overhead_pct": overhead_pct,
        "gate_pct": gate,
        "beta_err_vs_bare": sup_err,
        "beta_bit_identical": sup_err == 0.0,
        "pass": overhead_pct <= gate and sup_err == 0.0,
    })

    chk_err = float(np.abs(np.asarray(chk.beta) - oracle).max())
    chk_pct = (float(np.median(
        [c / b for c, b in zip(chk_rt, bare_rt)]
    )) - 1.0) * 100.0
    chk_ms = float(np.median(
        [(c - b) for c, b in zip(chk_rt, bare_rt)]
    )) * 1e3
    rows.append({
        "check": "overflow_check callback overhead",
        "seconds_per_round_unchecked": min(bare_rt),
        "seconds_per_round_checked": min(chk_rt),
        "overhead_pct": chk_pct,
        "overhead_ms_per_round": chk_ms,
        "beta_err_vs_unchecked": chk_err,
        # the arm-by-default recommendation (examples + launch secure
        # paths) holds while the fixed per-round callback cost stays
        # within 2% of the production fused round (~165 ms -> 3.3 ms)
        "within_arm_threshold": chk_ms <= 3.3,
        "pass": chk_err == 0.0,
    })

    # ---- recovery latency under canned survivable schedules ----------------
    # (t=2, w=3 throughout; schedule keys are ROUND numbers)
    schedules = {
        # 3 of 4 institutions flap together at round 2: quorum collapses
        # to 1/4 responding, the supervisor backs off 1 + 2 simulated
        # seconds while the flaps self-heal at t+3.0, then the full
        # cohort resumes -> oracle beta within quantization
        "flap_quorum_retry": {
            2: [("flap", "i1", 3.0), ("flap", "i2", 3.0),
                ("flap", "i3", 3.0)],
        },
        # both non-primary centers die BETWEEN protect and reveal: the
        # surviving single point < t, the round aborts (reveals nothing),
        # dead points are re-provisioned and the retry re-shares with
        # fresh polynomials -> bit-identical to the oracle
        "midround_abort_reshare": {
            2: [("center_midround", 2), ("center_midround", 3)],
        },
        # two centers crash cleanly before round 2: preflight fails
        # (1 < t), re-provisioning replaces them and the round proceeds
        # -> bit-identical to the oracle
        "center_loss_reprovision": {
            2: [("center_crash", 2), ("center_crash", 3)],
        },
    }
    center_only = {"midround_abort_reshare", "center_loss_reprovision"}
    for name, schedule in schedules.items():
        secs, coord, sup = _run_supervised(parts, agg, repeats, schedule)
        err = float(np.abs(np.asarray(coord.beta) - oracle).max())
        aborted = sum(r.aborted_attempts for r in sup.rounds)
        degraded = sum(1 for r in sup.rounds if r.degraded)
        tol = 0.0 if name in center_only else quant_tol
        rows.append({
            "schedule": name,
            "seconds": secs,
            "recovery_wall_seconds": secs - bare_s,
            "rounds": coord.iteration,
            "extra_rounds": coord.iteration - bare.iteration,
            "retries": sup.total_retries,
            "aborted_attempts": aborted,
            "degraded_rounds": degraded,
            "sim_backoff_seconds": sup.total_backoff,
            "converged": bool(coord.converged),
            "max_abs_err_vs_oracle": err,
            "oracle_tol": tol,
            "pass": bool(coord.converged) and err <= tol,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--institutions", type=int, default=4)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--records", type=int, default=80_000,
                    help="total N across all institutions")
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small config for the bench_smoke gate "
                         "(S=4, d=32, N=20000, 1 repeat; the 2% overhead "
                         "gate applies to the full config only)")
    ap.add_argument("--json", default=None,
                    help="machine-readable output path ('' to skip; "
                         "default BENCH_fault_overhead[_smoke].json)")
    args = ap.parse_args(argv)

    kw = dict(num_institutions=args.institutions, dim=args.dim,
              records=args.records, repeats=args.repeats, seed=args.seed)
    if args.quick:
        kw.update(num_institutions=4, dim=32, records=20_000, repeats=3)
    rows = run(full_gate=not args.quick, **kw)
    rows.append({"config": "quick" if args.quick else "full", **{
        k: kw[k] for k in ("num_institutions", "dim", "records")
    }})

    out = json.dumps(rows, indent=2)
    print(out)
    path = args.json
    if path is None:
        path = ("BENCH_fault_overhead_smoke.json" if args.quick
                else "BENCH_fault_overhead.json")
    if path:
        with open(path, "w") as f:
            f.write(out + "\n")
    return rows


if __name__ == "__main__":
    main()
