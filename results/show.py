"""Render result artifacts: roofline JSON, BENCH_*.json rows, span JSONL.

Usage: ``python results/show.py FILE [FILE ...]``

Dispatches on content:

* **roofline reports** (dicts with an ``hlo_analysis`` key) — the
  original per-device bytes/flops/collective summary with top byte
  buckets;
* **benchmark rows** (``BENCH_*.json``: a list of row dicts) — one
  aligned line per row, numeric trajectory columns auto-detected;
* **span run ledgers** (``*.jsonl`` written by
  ``repro.obs.trace.SpanTracer.export_jsonl``) — the per-kind wall-time
  summary table plus the slowest individual spans.

A missing or malformed file prints one ``error:`` line and moves on to
the remaining files; exit status is 1 if any file failed to render.
"""
import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
)


def show_roofline(r: dict):
    h = r["hlo_analysis"]
    coll = sum(h["collective_bytes_per_device"].values())
    print(
        f'{r["arch"]} {r["shape"]} [{r.get("variant")}] '
        "bytes %.3e mem %.1fs flops %.3e (%.2fs) coll %.3e (%.2fs) "
        "temp %.1fGB" % (
            h["bytes_per_device"], h["bytes_per_device"] / 819e9,
            h["flops_per_device"], h["flops_per_device"] / 197e12,
            coll, coll / 50e9,
            r["memory"]["temp_bytes_per_device"] / 2**30,
        )
    )
    for b in h.get("top_byte_buckets", [])[:5]:
        print("   %.3e  %s" % (b["bytes"], b["bucket"]))


def _fmt(v):
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def show_bench_rows(rows: list):
    """BENCH_*.json trajectory: aligned per-row lines, label first."""
    label_keys = ("label", "config", "mode", "kind", "name")
    for row in rows:
        if not isinstance(row, dict):
            print(_fmt(row))
            continue
        label = next((str(row[k]) for k in label_keys if k in row), "")
        rest = " ".join(
            f"{k}={_fmt(v)}" for k, v in row.items()
            if k not in label_keys and not isinstance(v, (list, dict))
        )
        print(f"  {label:<32} {rest}")


def show_span_ledger(path: str):
    """Span JSONL run ledger -> per-kind summary + slowest spans."""
    from repro.obs.trace import SpanTracer

    tracer = SpanTracer(capacity=1 << 20)
    spans = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                d = json.loads(line)
                tracer.record(d)
                spans.append(d)
    for line in tracer.summary_lines():
        print(line)
    slowest = sorted(spans, key=lambda d: -d["dur"])[:5]
    if slowest:
        print("slowest spans:")
        for d in slowest:
            attrs = " ".join(f"{k}={_fmt(v)}"
                             for k, v in d.get("attrs", {}).items())
            print(f"  {d['dur'] * 1e3:>9.3f} ms  {d['kind']}:{d['name']}"
                  + (f"  [{attrs}]" if attrs else ""))


def show(path: str) -> bool:
    print(f"== {path}")
    try:
        if path.endswith(".jsonl"):
            show_span_ledger(path)
            return True
        with open(path) as fh:
            data = json.load(fh)
        if isinstance(data, dict) and "hlo_analysis" in data:
            show_roofline(data)
        elif isinstance(data, list):
            show_bench_rows(data)
        else:
            print(json.dumps(data, indent=2))
        return True
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"error: {path}: {e}")
        return False


def main(paths) -> int:
    return 0 if all([show(f) for f in paths]) else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
