import json, sys
for f in sys.argv[1:]:
    r = json.load(open(f))
    h = r["hlo_analysis"]
    coll = sum(h["collective_bytes_per_device"].values())
    print(f'{r["arch"]} {r["shape"]} [{r.get("variant")}] bytes %.3e mem %.1fs flops %.3e (%.2fs) coll %.3e (%.2fs) temp %.1fGB' % (
        h["bytes_per_device"], h["bytes_per_device"]/819e9,
        h["flops_per_device"], h["flops_per_device"]/197e12,
        coll, coll/50e9, r["memory"]["temp_bytes_per_device"]/2**30))
    for b in h.get("top_byte_buckets", [])[:5]:
        print("   %.3e  %s" % (b["bytes"], b["bucket"]))
