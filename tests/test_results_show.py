"""results/show.py dispatch: the one renderer for every result artifact.

The script dispatches on content — span JSONL run ledgers, BENCH_*.json
row tables, roofline dicts — and must degrade gracefully on a broken
artifact (one ``error:`` line, nonzero exit, remaining files still
rendered) because it is pointed at whole results/ globs.
"""
import importlib.util
import json
import pathlib

import pytest

_SHOW_PY = pathlib.Path(__file__).resolve().parents[1] / "results" / "show.py"


@pytest.fixture(scope="module")
def show():
    spec = importlib.util.spec_from_file_location("results_show", _SHOW_PY)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_span_ledger_dispatch(show, tmp_path, capsys):
    """*.jsonl -> per-kind summary table + slowest spans."""
    ledger = tmp_path / "run.jsonl"
    spans = [
        {"kind": "secure_round", "name": "round", "t0": 0.0, "dur": 0.25},
        {"kind": "secure_round", "name": "round", "t0": 0.3, "dur": 0.05},
        {"kind": "protect", "name": "protect", "t0": 0.0, "dur": 0.01,
         "attrs": {"backend": "pallas"}},
    ]
    ledger.write_text("".join(json.dumps(s) + "\n" for s in spans))
    assert show.main([str(ledger)]) == 0
    out = capsys.readouterr().out
    assert f"== {ledger}" in out
    assert "secure_round" in out and "protect" in out
    assert "slowest spans:" in out
    assert "backend=pallas" in out


def test_bench_rows_dispatch(show, tmp_path, capsys):
    """A JSON list -> one aligned line per row, label column first."""
    bench = tmp_path / "BENCH_toy.json"
    bench.write_text(json.dumps([
        {"label": "fused", "seconds": 0.034, "bytes_transmitted": 98304},
        {"label": "loop", "seconds": 0.101, "bytes_transmitted": 98304,
         "trace": [1.0, 2.0]},  # list-valued columns are elided
    ]))
    assert show.main([str(bench)]) == 0
    out = capsys.readouterr().out
    assert "fused" in out and "seconds=0.034" in out
    assert "loop" in out and "trace" not in out


def test_roofline_dispatch(show, tmp_path, capsys):
    """A dict with hlo_analysis -> the roofline one-liner + buckets."""
    roof = tmp_path / "roofline.json"
    roof.write_text(json.dumps({
        "arch": "toy", "shape": "d128", "variant": "fused",
        "hlo_analysis": {
            "bytes_per_device": 1e9, "flops_per_device": 1e12,
            "collective_bytes_per_device": {"psum": 1e6},
            "top_byte_buckets": [{"bytes": 5e8, "bucket": "shares"}],
        },
        "memory": {"temp_bytes_per_device": 2 ** 30},
    }))
    assert show.main([str(roof)]) == 0
    out = capsys.readouterr().out
    assert "toy d128 [fused]" in out
    assert "shares" in out


def test_plain_dict_falls_back_to_json(show, tmp_path, capsys):
    other = tmp_path / "misc.json"
    other.write_text(json.dumps({"answer": 42}))
    assert show.main([str(other)]) == 0
    assert '"answer": 42' in capsys.readouterr().out


def test_malformed_file_is_one_error_line_not_a_crash(show, tmp_path,
                                                      capsys):
    """Broken artifacts: error line + exit 1, later files still render."""
    bad = tmp_path / "BENCH_broken.json"
    bad.write_text("{not json")
    missing = tmp_path / "never_written.jsonl"
    good = tmp_path / "BENCH_ok.json"
    good.write_text(json.dumps([{"label": "row", "v": 1}]))
    assert show.main([str(bad), str(missing), str(good)]) == 1
    out = capsys.readouterr().out
    assert f"error: {bad}" in out
    assert f"error: {missing}" in out
    assert "row" in out  # the good file after the broken ones rendered
