"""The unified SecureCollective: one chain, many consumers.

PR 10 folded the four protect -> aggregate -> reveal chains (secure_fit,
StudyCoordinator, the selection sweep, secure_psum/psum_2d) onto ONE
:class:`repro.core.collective.SecureCollective`.  The lockstep tests in
test_secure_pipeline / test_scan_rounds / test_selection / test_multihost
pin bit-parity of the existing consumers; this module pins the NEW
surface:

* the compat alias (``SecureAggregator`` IS ``SecureCollective`` — one
  class, one jit key-space),
* the one byte model behind every driver's telemetry,
* the first genuinely new consumer: slot-packed multi-study rounds
  (:mod:`repro.core.multistudy`) matching independent per-study fits to
  fixed-point quantization — including ragged studies entering via
  count=0 padding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SecureAggregator, SecureCollective
from repro.core.batched_summaries import batched_local_summaries, pack_partitions
from repro.core.multistudy import (
    fused_multistudy_iteration,
    run_multistudy_rounds,
    stack_studies,
)
from repro.core.newton import _fused_secure_iteration, _iteration_bytes
from repro.data import generate_synthetic

NUM_INST = 4
DIM = 5


@pytest.fixture(scope="module")
def agg():
    return SecureCollective(backend="pallas")


@pytest.fixture(scope="module")
def studies():
    """Two independent cohorts, same feature space, different data."""
    return [
        generate_synthetic(jax.random.PRNGKey(11), num_institutions=NUM_INST,
                           records_per_institution=120, dim=DIM),
        generate_synthetic(jax.random.PRNGKey(23), num_institutions=NUM_INST,
                           records_per_institution=120, dim=DIM),
    ]


def quant_tol(agg, num_parts=NUM_INST):
    return (num_parts + 1) / agg.codec.scale


# ------------------------------------------------------------- compat alias

def test_aggregator_is_collective_alias():
    """One class: the historical name must not fork the jit key-space."""
    assert SecureAggregator is SecureCollective


def test_round_bytes_is_the_one_model(agg):
    """The newton shim and the method agree — a single size model."""
    for protect in ("none", "gradient", "hessian", "both"):
        assert _iteration_bytes(DIM, NUM_INST, protect, agg) \
            == agg.round_bytes(DIM, NUM_INST, protect)
    # the coordinator/selection variants are the same model, parameterized
    # (row alignment may absorb the extra count scalar, hence >=)
    assert agg.round_bytes(DIM, NUM_INST, "both", include_count=True) \
        >= agg.round_bytes(DIM, NUM_INST, "both")
    assert agg.round_bytes(DIM, NUM_INST, "both", num_configs=3) \
        == 3 * agg.round_bytes(DIM, NUM_INST, "both")


# ------------------------------------------- multiconfig wire: slot parity

def test_multiconfig_round_slots_bit_equal_per_study(agg, studies):
    """Each slot of the ONE multiconfig reveal is bit-equal to that
    study's own batched round: Shamir reconstruction cancels the sharing
    polynomials exactly, and slots are independent payload lanes."""
    key = jax.random.PRNGKey(0)
    trees = []
    for study in studies:
        packed = pack_partitions(study.parts)
        beta0 = jnp.zeros((DIM,), jnp.float64)
        sm = batched_local_summaries(beta0, packed, backend="pallas",
                                     interpret=True)
        trees.append({"gradient": sm.gradient, "hessian": sm.hessian,
                      "deviance": sm.deviance})
    stacked = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *trees)  # (M, S, ...)
    multi = agg.secure_round_multiconfig(key, stacked)
    for m, tree in enumerate(trees):
        solo = agg.secure_round_batched(jax.random.fold_in(key, m), tree)
        for leaf in tree:
            np.testing.assert_array_equal(
                np.asarray(multi[leaf][m]), np.asarray(solo[leaf]),
                err_msg=f"slot {m} leaf {leaf}")


# ------------------------------------------------- multi-study == M x solo

@pytest.mark.parametrize("protect", ["none", "gradient", "both"])
def test_multistudy_iteration_matches_independent(agg, studies, protect):
    """One slot-packed round == two independent fused rounds, per study,
    to fixed-point quantization (revealed aggregates are bit-equal; the
    batched Newton tail may differ in low-order solve bits)."""
    key = jax.random.PRNGKey(7)
    lams = (1.0, 0.3)
    packed = stack_studies([s.parts for s in studies])
    betas0 = jnp.zeros((len(studies), DIM), jnp.float64)
    betas, objs, gnorms, snorms = fused_multistudy_iteration(
        betas0, key, packed.X, packed.X32, packed.y, packed.counts,
        jnp.asarray(lams, jnp.float64), agg, protect, 0.0, True,
    )
    tol = quant_tol(agg)
    for m, study in enumerate(studies):
        p = pack_partitions(study.parts)
        b_ref, obj_ref, g_ref, s_ref = _fused_secure_iteration(
            betas0[m], jax.random.fold_in(key, m), p.X, p.X32, p.y,
            p.counts, lams[m], agg, protect, 0.0, True,
        )
        assert np.abs(np.asarray(betas[m]) - np.asarray(b_ref)).max() <= tol
        assert abs(float(objs[m]) - float(obj_ref)) <= tol * NUM_INST
        assert abs(float(gnorms[m]) - float(g_ref)) <= tol * DIM
        assert abs(float(snorms[m]) - float(s_ref)) <= tol * DIM


def test_multistudy_rounds_track_independent_fits(agg, studies):
    """Three slot-packed rounds track three per-study fused rounds: the
    packed trajectory stays within quantization of the solo trajectory
    at every round, for every study."""
    lams = (1.0, 0.3)
    num_rounds = 3
    betas, trace = run_multistudy_rounds(
        [s.parts for s in studies], lams, num_rounds, aggregator=agg,
        protect="both",
    )
    assert trace.shape == (num_rounds, len(studies))
    tol = quant_tol(agg)
    key = jax.random.PRNGKey(0)
    for m, study in enumerate(studies):
        p = pack_partitions(study.parts)
        beta = jnp.zeros((DIM,), jnp.float64)
        for r in range(num_rounds):
            beta, obj, _, _ = _fused_secure_iteration(
                beta, jax.random.fold_in(key, r), p.X, p.X32, p.y,
                p.counts, lams[m], agg, "both", 0.0, True,
            )
            # per-round quantization errors can compound through the
            # Newton updates; allow one tol per elapsed round
            assert abs(float(trace[r, m]) - float(obj)) \
                <= tol * NUM_INST * (r + 1)
        assert np.abs(np.asarray(betas[m]) - np.asarray(beta)).max() \
            <= tol * num_rounds


def test_ragged_studies_pad_with_silent_institutions(agg):
    """A narrower cohort enters the packed round via count=0 padding and
    still matches its own independent round: zero-count institutions
    encode to the zero field element and vanish from every aggregate."""
    wide = generate_synthetic(jax.random.PRNGKey(3), num_institutions=4,
                              records_per_institution=100, dim=DIM)
    slim = generate_synthetic(jax.random.PRNGKey(5), num_institutions=2,
                              records_per_institution=60, dim=DIM)
    packed = stack_studies([wide.parts, slim.parts])
    assert packed.X.shape[:2] == (2, 4)  # padded to the widest cohort
    key = jax.random.PRNGKey(9)
    betas0 = jnp.zeros((2, DIM), jnp.float64)
    betas, _, _, _ = fused_multistudy_iteration(
        betas0, key, packed.X, packed.X32, packed.y, packed.counts,
        jnp.asarray([0.5, 0.5], jnp.float64), agg, "both", 0.0, True,
    )
    tol = quant_tol(agg)
    for m, study in enumerate((wide, slim)):
        p = pack_partitions(study.parts)
        b_ref, *_ = _fused_secure_iteration(
            betas0[m], jax.random.fold_in(key, m), p.X, p.X32, p.y,
            p.counts, 0.5, agg, "both", 0.0, True,
        )
        assert np.abs(np.asarray(betas[m]) - np.asarray(b_ref)).max() <= tol
