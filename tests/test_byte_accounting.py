"""ONE shared pin for every byte-accounting surface.

Four places report wire/collective bytes: the HLO roofline walker
(``launch/hlo_analysis.py``), the round drivers' ``RoundReport``
telemetry (``core/newton._iteration_bytes`` / ``core/protocol``), the
selection sweep's ``PathReport``, and the obs metrics gauges.  They must
all speak the same conventions — defined ONCE in ``repro.obs.metrics``:
all-reduce = 2x result bytes (ring RS + AG phases), reduce-scatter =
1x OPERAND bytes, all-gather = 1x result bytes, so RS + AG over a
logical buffer == the all-reduce figure exactly.
"""
import jax
import numpy as np
import pytest

from repro.core.newton import SecureFitDriver
from repro.core.protocol import Institution, StudyCoordinator
from repro.core.secure_agg import SecureAggregator
from repro.data import generate_synthetic
from repro.launch.hlo_analysis import analyze_hlo
from repro.obs import metrics
from repro.selection import SelectionCoordinator


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(11), num_institutions=3,
        records_per_institution=120, dim=6,
    )


@pytest.fixture(autouse=True)
def _fresh_registry():
    metrics.reset()
    yield
    metrics.reset()


# ---------------------------------------------------- the conventions pin

def test_rs_plus_ag_equals_all_reduce_factorwise():
    """The factor identity itself: decomposing an AR into its RS + AG
    phases must not change the byte total, for ANY buffer size."""
    for nbytes in (4096, 7 * 4, 10**9):
        assert (metrics.reduce_scatter_bytes(nbytes)
                + metrics.all_gather_bytes(nbytes)
                ) == metrics.all_reduce_bytes(nbytes)


_RS_AG_HLO = """
HloModule rs_ag

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %rs = f32[256]{0} reduce-scatter(%p0), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%rs), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""

_AR_HLO = """
HloModule ar

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_hlo_walker_uses_the_shared_factors():
    """hlo_analysis collective bytes == obs.metrics helpers, term by term
    — the walker imports the factors, this test pins that they reach the
    arithmetic."""
    buf = 1024 * 4  # the logical f32[1024] buffer
    pair = analyze_hlo(_RS_AG_HLO)
    ar = analyze_hlo(_AR_HLO)
    assert ar.collective_bytes["all-reduce"] == metrics.all_reduce_bytes(buf)
    assert pair.collective_bytes["reduce-scatter"] == \
        metrics.reduce_scatter_bytes(buf)
    assert pair.collective_bytes["all-gather"] == \
        metrics.all_gather_bytes(buf)
    assert (pair.collective_bytes["reduce-scatter"]
            + pair.collective_bytes["all-gather"]
            ) == ar.collective_bytes["all-reduce"]


# ------------------------------------------- RoundReport <-> obs gauges

def test_secure_fit_round_bytes_match_gauge(study):
    driver = SecureFitDriver(
        study.parts, lam=1.0, protect="gradient",
        aggregator=SecureAggregator(backend="pallas"), fused=True,
    )
    reports = [driver.step() for _ in range(2)]
    assert reports[0].bytes_transmitted == reports[1].bytes_transmitted > 0
    assert metrics.get("repro_round_bytes", driver="secure_fit") == \
        reports[-1].bytes_transmitted
    assert metrics.get("repro_bytes_total", driver="secure_fit") == \
        sum(r.bytes_transmitted for r in reports)
    assert metrics.get("repro_rounds_total", driver="secure_fit") == 2


def test_coordinator_round_bytes_match_gauge(study):
    insts = [Institution(f"inst{j}", X, y)
             for j, (X, y) in enumerate(study.parts)]
    coord = StudyCoordinator(insts, lam=1.0, protect="gradient", seed=0)
    reports = [coord.step() for _ in range(2)]
    assert metrics.get("repro_round_bytes", driver="coordinator") == \
        reports[-1].bytes_transmitted
    assert metrics.get("repro_bytes_total", driver="coordinator") == \
        sum(r.bytes_transmitted for r in reports)


# ------------------------------------------- PathReport <-> obs counters

def test_selection_path_bytes_consistent_with_counters(study):
    insts = [Institution(f"inst{j}", X, y)
             for j, (X, y) in enumerate(study.parts)]
    coord = SelectionCoordinator(
        insts, lambdas=[3.0, 0.3], num_folds=2, protect="gradient",
        max_rounds=12, seed=1, refit=False,
    )
    report = coord.run_path()
    # the report's own invariant: totals factor through the static
    # per-round size model (refit=False — the refit tail is a 1-config
    # chunk with its own smaller per-round figure)
    assert report.bytes_total == report.rounds_total * report.bytes_per_round
    # and the obs registry saw exactly the same accounting
    assert metrics.get("repro_round_bytes", driver="selection_path") == \
        report.bytes_per_round
    assert metrics.get("repro_bytes_total", driver="selection_path") == \
        pytest.approx(report.bytes_total)
    assert metrics.get("repro_rounds_total", driver="selection_path") == \
        report.rounds_total


# ------------------------------------------------- exposition round-trip

def test_prometheus_export_carries_byte_series(tmp_path, study):
    driver = SecureFitDriver(
        study.parts, lam=1.0, protect="gradient",
        aggregator=SecureAggregator(backend="pallas"), fused=True,
    )
    report = driver.step()
    text = metrics.export_textfile(tmp_path / "obs.prom")
    assert f'repro_round_bytes{{driver="secure_fit"}} ' \
           f'{report.bytes_transmitted:g}' in text
    assert "# TYPE repro_bytes_total counter" in text
    assert (tmp_path / "obs.prom").read_text() == text
