"""Property tests: fold masks composed onto the packed ragged row masks.

The selection subsystem's correctness keystone — for arbitrary uneven
partitions and fold assignments, the fold∘row-masked summaries over the
padded (S, N_max, d) batch must reproduce what ``local_summaries`` says
about the physically-sliced per-fold partitions, on both rungs of the
summaries ladder ("reference" f64 exact; "pallas" f32-Gram to operand
tolerance), and the held-out metrics must mirror plain evaluation of the
held-out slices exactly (deviance to float roundoff; correct/count as
exact integers).

Runs under real hypothesis when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    batched_cv_summaries,
    batched_local_summaries,
    local_summaries,
    pack_partitions,
)
from repro.core.logreg import deviance as deviance_fn
from repro.selection import assign_folds, pack_fold_ids


def _random_study(rng_seed, sizes, d):
    key = jax.random.PRNGKey(rng_seed)
    parts = []
    for j, n in enumerate(sizes):
        k1, k2 = jax.random.split(jax.random.fold_in(key, j))
        Xj = jax.random.normal(k1, (n, d), dtype=jnp.float64)
        yj = jax.random.bernoulli(k2, 0.55, (n,)).astype(jnp.float64)
        parts.append((Xj, yj))
    return parts


def _cv_setup(sizes, d, num_folds, fold_seed):
    parts = _random_study(fold_seed + 17, sizes, d)
    folds = [
        assign_folds(n, num_folds, f"inst{j}", fold_seed)
        for j, n in enumerate(sizes)
    ]
    packed = pack_partitions(parts)
    fold_ids = pack_fold_ids(folds, packed.X.shape[1])
    return parts, folds, packed, fold_ids


def _check_fold_masks_vs_local_summaries(backend, sizes, num_folds,
                                         fold_seed, d=5):
    """Shared property body: fold∘row masks over the packed batch ==
    local_summaries on the unpacked per-fold partitions."""
    sizes = [max(s, num_folds) for s in sizes]
    parts, folds, packed, fold_ids = _cv_setup(
        sizes, d, num_folds, fold_seed
    )
    betas = jnp.stack([
        0.07 * (c + 1) * jnp.arange(d, dtype=jnp.float64) - 0.1
        for c in range(num_folds)
    ])
    fold_of = jnp.arange(num_folds, dtype=jnp.int32)
    sm = batched_cv_summaries(
        betas, packed, fold_ids, fold_of, backend=backend
    )
    h_tol = dict(rtol=1e-9, atol=1e-9) if backend == "reference" else \
        dict(rtol=2e-4, atol=2e-4)
    for c in range(num_folds):
        for s, ((Xj, yj), f) in enumerate(zip(parts, folds)):
            tr = np.asarray(f) != c
            want = local_summaries(betas[c], Xj[tr], yj[tr])
            np.testing.assert_allclose(
                sm.gradient[c, s], want.gradient, rtol=1e-9, atol=1e-9
            )
            np.testing.assert_allclose(
                sm.deviance[c, s], want.deviance, rtol=1e-12, atol=1e-9
            )
            np.testing.assert_allclose(
                sm.hessian[c, s], want.hessian, **h_tol
            )
            assert int(sm.count[c, s]) == int(tr.sum())


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@settings(max_examples=3, deadline=None)
@given(
    sizes=st.lists(st.integers(4, 90), min_size=2, max_size=5),
    num_folds=st.integers(2, 4),
    fold_seed=st.integers(0, 2**16),
)
def test_fold_masks_reproduce_per_fold_local_summaries(
    backend, sizes, num_folds, fold_seed
):
    """Both summaries_backend rungs, a few drawn shapes (tier-1 size;
    the exhaustive sweep is the `slow`-marked variant below)."""
    _check_fold_masks_vs_local_summaries(backend, sizes, num_folds,
                                         fold_seed)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["reference", "pallas", "mixed"])
@settings(max_examples=20, deadline=None)
@given(
    sizes=st.lists(st.integers(4, 300), min_size=2, max_size=7),
    num_folds=st.integers(2, 6),
    fold_seed=st.integers(0, 2**20),
)
def test_fold_masks_property_exhaustive(backend, sizes, num_folds,
                                        fold_seed):
    """The wide sweep (all three rungs, larger/raggeder partitions);
    excluded from tier-1 by the `slow` marker — run with -m slow."""
    _check_fold_masks_vs_local_summaries(backend, sizes, num_folds,
                                         fold_seed, d=6)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@settings(max_examples=3, deadline=None)
@given(
    sizes=st.lists(st.integers(6, 80), min_size=2, max_size=4),
    num_folds=st.integers(2, 5),
    fold_seed=st.integers(0, 2**16),
)
def test_heldout_metrics_match_plain_evaluation(
    backend, sizes, num_folds, fold_seed
):
    """val deviance == plain deviance of the held-out slice; correct and
    count are exact integers matching plain thresholded predictions."""
    sizes = [max(s, num_folds) for s in sizes]
    d = 4
    parts, folds, packed, fold_ids = _cv_setup(
        sizes, d, num_folds, fold_seed
    )
    beta = 0.3 - 0.05 * jnp.arange(d, dtype=jnp.float64)
    fold_of = jnp.arange(num_folds, dtype=jnp.int32)
    sm = batched_cv_summaries(
        jnp.tile(beta[None], (num_folds, 1)), packed, fold_ids, fold_of,
        backend=backend,
    )
    for c in range(num_folds):
        for s, ((Xj, yj), f) in enumerate(zip(parts, folds)):
            va = np.asarray(f) == c
            assert int(sm.val_count[c, s]) == int(va.sum())
            if not va.any():
                assert float(sm.val_deviance[c, s]) == 0.0
                assert float(sm.val_correct[c, s]) == 0.0
                continue
            np.testing.assert_allclose(
                sm.val_deviance[c, s],
                deviance_fn(beta, Xj[va], yj[va]),
                rtol=1e-12, atol=1e-9,
            )
            z = np.asarray(Xj[va] @ beta)
            correct = int(((z > 0) == (np.asarray(yj[va]) > 0.5)).sum())
            assert int(sm.val_correct[c, s]) == correct


@pytest.mark.parametrize("backend", ["reference", "pallas", "mixed"])
def test_train_plus_heldout_partitions_the_full_summaries(backend):
    """Row partition invariant: train deviance + held-out deviance ==
    full deviance, and a fold_of == -1 config == the non-CV batched
    summaries (full-data fit sharing the launch)."""
    sizes = (23, 57, 11)
    d, K = 6, 3
    parts, folds, packed, fold_ids = _cv_setup(list(sizes), d, K, 9)
    beta = 0.11 * jnp.arange(d, dtype=jnp.float64)
    betas = jnp.tile(beta[None], (K + 1, 1))
    fold_of = jnp.asarray(list(range(K)) + [-1], jnp.int32)
    sm = batched_cv_summaries(betas, packed, fold_ids, fold_of,
                              backend=backend)
    full = batched_local_summaries(
        beta, packed, backend="reference"
    )
    for c in range(K):
        np.testing.assert_allclose(
            np.asarray(sm.deviance[c]) + np.asarray(sm.val_deviance[c]),
            np.asarray(full.deviance), rtol=1e-12,
        )
        np.testing.assert_array_equal(
            np.asarray(sm.count[c]) + np.asarray(sm.val_count[c]),
            np.asarray(packed.counts).astype(np.float64),
        )
    # the full-data config: empty held-out masks, train == everything
    np.testing.assert_allclose(sm.deviance[K], full.deviance, rtol=1e-12)
    np.testing.assert_allclose(sm.gradient[K], full.gradient,
                               rtol=1e-9, atol=1e-9)
    assert float(np.asarray(sm.val_count[K]).sum()) == 0.0
    h_tol = dict(rtol=1e-9) if backend == "reference" else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sm.hessian[K], full.hessian, **h_tol)


def test_cv_kernel_matches_simulation():
    """The blocked Pallas CV kernel (interpreted) == the XLA functional
    simulation, with an f64 payload where their accumulation contracts
    coincide — the same pinning the non-CV fused_irls kernel has."""
    from repro.kernels import ops

    sizes = (3, 170, 64)
    d, K = 5, 3
    parts, folds, packed, fold_ids = _cv_setup(list(sizes), d, K, 4)
    betas = jnp.stack([
        0.05 * (c + 1) * jnp.arange(d, dtype=jnp.float64)
        for c in range(K + 1)
    ])
    fold_of = jnp.asarray(list(range(K)) + [-1], jnp.int32)
    kw = dict(counts=packed.counts, interpret=True,
              mxu_operand=packed.X32)
    out_kernel = ops.fused_irls_cv(
        betas, packed.X, packed.y, fold_ids, fold_of, simulate=False, **kw
    )
    out_sim = ops.fused_irls_cv(
        betas, packed.X, packed.y, fold_ids, fold_of, simulate=True, **kw
    )
    names = ("hessian", "gradient", "dev_train", "dev_val", "correct",
             "count_val")
    for a, b, name in zip(out_kernel, out_sim, names):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=1e-6 if name == "hessian" else 1e-11,
            atol=1e-6 if name == "hessian" else 1e-11,
            err_msg=name,
        )


def test_churn_safe_fold_assignment():
    """Folds are a pure function of (name, seed): stable across cohort
    composition, balanced within an institution, deterministic."""
    a = np.asarray(assign_folds(103, 5, "hospital-a", fold_seed=3))
    b = np.asarray(assign_folds(103, 5, "hospital-a", fold_seed=3))
    np.testing.assert_array_equal(a, b)
    # balanced: sizes differ by at most one
    counts = np.bincount(a, minlength=5)
    assert counts.max() - counts.min() <= 1
    # another institution draws a different permutation
    c = np.asarray(assign_folds(103, 5, "hospital-b", fold_seed=3))
    assert (a != c).any()
    # different seed reshuffles
    d = np.asarray(assign_folds(103, 5, "hospital-a", fold_seed=4))
    assert (a != d).any()
    with pytest.raises(ValueError, match="folds"):
        assign_folds(3, 5, "tiny")
    with pytest.raises(ValueError, match="at least 2"):
        assign_folds(10, 1, "x")
