"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite uses a small slice of the hypothesis API (``given``,
``settings``, ``strategies.integers/lists/floats/sampled_from/data``).  The
container image does not ship hypothesis and the no-new-deps rule forbids
installing it, so ``conftest.py`` registers this module under the name
``hypothesis`` when the real package is missing.  It draws from a seeded
``random.Random`` so the property tests still *run* (deterministically),
rather than being skipped wholesale.  With the real package installed
(``pip install -r requirements-dev.txt``) this module is never imported.

Not implemented: shrinking, the example database, ``assume``, stateful
testing.  Tests here only need plain randomized example generation.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

__version__ = "0.0-fallback"

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A draw function wrapped so strategies compose (lists of integers)."""

    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def example(self, rng):
        return self._draw(rng)

    def __repr__(self):
        return f"_Strategy({self._label})"


class _DataObject:
    """Mirror of hypothesis' ``st.data()`` draw handle."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng), "data")


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def floats(min_value, max_value, allow_nan=False, allow_infinity=False,
           width=64):
    def draw(rng):
        v = rng.uniform(min_value, max_value)
        if width == 32:
            import struct

            v = struct.unpack("f", struct.pack("f", v))[0]
            # float32 rounding can step just past the bounds; clamp back
            v = min(max(v, min_value), max_value)
        return v

    return _Strategy(draw, f"floats({min_value}, {max_value})")


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        k = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(k)]

    return _Strategy(draw, f"lists(..., {min_size}, {max_size})")


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq), f"sampled_from({seq!r})")


def data():
    return _DataStrategy()


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator: attach example-count config to a test function."""

    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return apply


def given(**strategy_kwargs):
    """Run the test ``max_examples`` times with freshly drawn examples.

    The wrapper's signature hides the strategy parameters from pytest (so it
    does not look for fixtures named after them) while keeping any
    ``parametrize`` / fixture parameters visible.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        passthrough = [
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs
        ]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(
                wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES
            )
            # deterministic per-test seed: crc32 is salt-free (unlike
            # hash(), which PYTHONHASHSEED randomizes per process), so
            # draws reproduce across runs and workers
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()
            )
            rng = random.Random(seed)
            for _ in range(max_examples):
                drawn = {
                    name: strat.example(rng)
                    for name, strat in strategy_kwargs.items()
                }
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=passthrough)
        return wrapper

    return decorate


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)
    sampled_from = staticmethod(sampled_from)
    data = staticmethod(data)


strategies = _StrategiesModule()
