"""Unit tests: int8 error-feedback compression, checkpoint manager.

(The in-SPMD secure_psum coverage moved to tests/test_secure_psum.py,
parametrized over wire format, reveal mode and device counts.)"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.distributed.compat import shard_map
from repro.optim.compression import compressed_psum, init_error_feedback


# ----------------------------------------------------------- compression
def test_compressed_psum_error_feedback_converges(rng_key):
    """Repeated compression of the same gradient: error feedback makes the
    running mean of dequantized values converge to the true value."""
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": 0.1 * jax.random.normal(rng_key, (512,), jnp.float32)}
    e = init_error_feedback(g)

    def step(e):
        return shard_map(
            lambda ee: compressed_psum(g, "pod", ee),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
            check_vma=False,
        )(e)

    acc = jnp.zeros((512,))
    n = 20
    for _ in range(n):
        mean, e = step(e)
        acc = acc + mean["w"]
    np.testing.assert_allclose(acc / n, g["w"], atol=2e-4)


def test_compressed_psum_quantization_bounded(rng_key):
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(rng_key, (1024,), jnp.float32)}
    e = init_error_feedback(g)
    mean, e2 = shard_map(
        lambda ee: compressed_psum(g, "pod", ee),
        mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        check_vma=False,
    )(e)
    absmax = float(jnp.max(jnp.abs(g["w"])))
    # one-shot error bounded by one quantization step
    assert float(jnp.max(jnp.abs(mean["w"] - g["w"]))) <= absmax / 127 + 1e-6
    # residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(e2["w"]),
                               np.asarray(g["w"] - mean["w"]), atol=1e-6)


# ------------------------------------------------------------ checkpoint
def _tree(key):
    return {
        "a": jax.random.normal(key, (8, 4), jnp.float32),
        "b": {"c": jnp.arange(6, dtype=jnp.int32),
              "d": jax.random.normal(jax.random.fold_in(key, 1), (3,),
                                     jnp.bfloat16)},
    }


def test_save_load_roundtrip_bf16(tmp_path, rng_key):
    tree = _tree(rng_key)
    path = str(tmp_path / "t.npz")
    save_pytree(tree, path)
    out = load_pytree(tree, path)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_manager_retention_and_restore(tmp_path, rng_key):
    mgr = CheckpointManager(str(tmp_path), retain=2)
    trees = {}
    for step in (1, 2, 3, 4):
        t = _tree(jax.random.fold_in(rng_key, step))
        trees[step] = t
        mgr.save(step, t)
    assert mgr.steps() == [3, 4]  # retain-2 GC
    restored, step = mgr.restore(trees[4])
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(trees[4]["a"]))


def test_checkpoint_manager_async_writes(tmp_path, rng_key):
    mgr = CheckpointManager(str(tmp_path), retain=3, async_writes=True)
    t = _tree(rng_key)
    mgr.save(7, t)
    mgr.close()  # drains the writer thread
    restored, step = mgr.restore(t)
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t["a"]))


