"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting shapes + finiteness.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.distributed import MeshRules
from repro.models import transformer as T
from repro.models.config import SHAPES, block_kinds, segments

LM_ARCHS = [a for a in ARCH_IDS if a != "logreg_paper"]
RULES = MeshRules(mesh=None)
B, S = 2, 32


def make_batch(cfg, key):
    kt, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    if cfg.frontend == "embeddings":
        return {
            "embeds": jax.random.normal(kt, (B, S, cfg.d_model),
                                        jnp.float32),
            "labels": labels,
        }
    return {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": labels,
    }


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_and_loss(arch):
    cfg = smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = T.forward(params, cfg, RULES,
                            tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    loss, metrics = T.loss_fn(params, batch, cfg, RULES)
    assert np.isfinite(float(loss))
    # one gradient step must produce finite grads
    g = jax.grad(lambda p: T.loss_fn(p, batch, cfg, RULES)[0])(params)
    finite = jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda x: bool(jnp.isfinite(x).all()), g)
    )
    assert finite, arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_then_decode(arch):
    cfg = smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, cache, length = T.prefill(
        params, cfg, RULES,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        cache_len=S + 4,
    )
    assert logits.shape == (B, cfg.vocab_size)
    assert int(length) == S
    if cfg.frontend == "embeddings":
        step_in = {"embeds": jnp.ones((B, cfg.d_model), jnp.float32)}
    else:
        step_in = {"tokens": jnp.zeros((B,), jnp.int32)}
    logits2, cache2, length2 = T.decode_step(
        params, cache, length, cfg, RULES, **step_in
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), arch
    assert int(length2) == S + 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_prefill_continuation(arch):
    """KV-cache correctness: decoding token t yields the same logits as a
    fresh prefill over the first t+1 tokens (teacher forcing)."""
    cfg = smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    key = jax.random.PRNGKey(3)
    S0 = 8
    if cfg.frontend == "embeddings":
        full = jax.random.normal(key, (B, S0 + 1, cfg.d_model), jnp.float32)
        pre = {"embeds": full[:, :S0]}
        step = {"embeds": full[:, S0]}
        pre2 = {"embeds": full}
    else:
        full = jax.random.randint(key, (B, S0 + 1), 0, cfg.vocab_size,
                                  dtype=jnp.int32)
        pre = {"tokens": full[:, :S0]}
        step = {"tokens": full[:, S0]}
        pre2 = {"tokens": full}
    _, cache, length = T.prefill(params, cfg, RULES, cache_len=S0 + 4, **pre)
    dec_logits, _, _ = T.decode_step(params, cache, length, cfg, RULES,
                                     **step)
    ref_logits, _, _ = T.prefill(params, cfg, RULES, cache_len=S0 + 5,
                                 **pre2)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ref_logits, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_long500k_skip_list_matches_design():
    """Sub-quadratic archs (and only those) accept the long_500k cell."""
    expect_runs = {"h2o_danube3_4b", "rwkv6_3b", "recurrentgemma_9b"}
    runs = {a for a in LM_ARCHS if get_config(a).sub_quadratic}
    assert runs == expect_runs


def test_block_kind_patterns():
    rg = get_config("recurrentgemma_9b")
    kinds = block_kinds(rg)
    assert kinds[0][0] == "rglru" and kinds[2][0] == "local"
    assert sum(1 for k in kinds if k[0] == "local") == 12
    dsl = get_config("deepseek_v2_lite")
    kinds = block_kinds(dsl)
    assert kinds[0] == ("mla", "dense_big")
    assert all(k == ("mla", "moe") for k in kinds[1:])
    assert len(segments(dsl)) == 2
