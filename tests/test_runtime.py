"""Runtime managers: heartbeats, stragglers, failure injection, remesh."""
import pytest

from repro.runtime import (
    FailureInjector,
    HeartbeatMonitor,
    SimClock,
    StragglerPolicy,
    plan_remesh,
)


def test_heartbeat_death_and_recovery():
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    for w in ("a", "b", "c"):
        mon.register(w)
    clock.advance(5.0)
    mon.beat("a")
    clock.advance(7.0)  # b, c last beat 12s ago; a 7s ago
    assert mon.alive() == ["a"]
    assert mon.dead() == ["b", "c"]
    mon.beat("a")
    with pytest.raises(KeyError):
        mon.beat("zz")


def test_straggler_policy_split_and_quorum():
    pol = StragglerPolicy(deadline=3.0, quorum_fraction=0.5)
    arrivals = {"w0": 1.0, "w1": 2.5, "w2": 9.0, "w3": 3.0}
    resp, lag = pol.split(arrivals, round_start=0.0)
    assert resp == ["w0", "w1", "w3"] and lag == ["w2"]
    assert pol.quorum_met(3, 4)
    assert not pol.quorum_met(1, 4)
    assert pol.quorum_met(1, 1)


def test_failure_injector_kill_and_recover():
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    for w in ("a", "b"):
        mon.register(w)
    inj = FailureInjector({3: ["b"], 5: [("recover", "b")]})
    assert inj.apply(1, mon) == []
    assert inj.apply(3, mon) == ["b"]
    assert mon.alive() == ["a"]
    assert inj.apply(5, mon) == ["b"]
    assert mon.alive() == ["a", "b"]


def test_plan_remesh_preserves_tp():
    plan = plan_remesh(512, tp=16)
    assert (plan.dp, plan.tp, plan.devices) == (32, 16, 512)
    # lose 17 devices -> dp shrinks, tp preserved, 15 idle
    plan = plan_remesh(495, tp=16)
    assert plan.tp == 16 and plan.dp == 30 and plan.dropped_workers == 15
    with pytest.raises(RuntimeError):
        plan_remesh(7, tp=16)
