"""Runtime managers: heartbeats, stragglers, failure injection, remesh."""
import pytest

from repro.runtime import (
    FailureInjector,
    HeartbeatMonitor,
    SimClock,
    StragglerPolicy,
    plan_remesh,
)


def test_heartbeat_death_and_recovery():
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    for w in ("a", "b", "c"):
        mon.register(w)
    clock.advance(5.0)
    mon.beat("a")
    clock.advance(7.0)  # b, c last beat 12s ago; a 7s ago
    assert mon.alive() == ["a"]
    assert mon.dead() == ["b", "c"]
    assert mon.beat("a") is True
    assert mon.beat("zz") is False  # unknown worker: dropped, not an error


def test_beat_racing_deregister_does_not_resurrect():
    """An in-flight heartbeat arriving after deregister must be dropped:
    the worker stays out until it explicitly re-registers."""
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    mon.register("a")
    mon.deregister("a")
    assert mon.beat("a") is False
    assert mon.alive() == [] and mon.dead() == []
    mon.register("a")
    assert mon.beat("a") is True
    assert mon.alive() == ["a"]


def test_alive_dead_timeout_equality_boundary():
    """Exactly-at-timeout is alive (<=); alive/dead always partition."""
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    mon.register("a")
    clock.advance(10.0)
    assert mon.alive() == ["a"] and mon.dead() == []
    clock.advance(1e-9)
    assert mon.alive() == [] and mon.dead() == ["a"]


def test_straggler_policy_split_and_quorum():
    pol = StragglerPolicy(deadline=3.0, quorum_fraction=0.5)
    arrivals = {"w0": 1.0, "w1": 2.5, "w2": 9.0, "w3": 3.0}
    resp, lag = pol.split(arrivals, round_start=0.0)
    assert resp == ["w0", "w1", "w3"] and lag == ["w2"]
    assert pol.quorum_met(3, 4)
    assert not pol.quorum_met(1, 4)
    assert pol.quorum_met(1, 1)


def test_failure_injector_kill_and_recover():
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    for w in ("a", "b"):
        mon.register(w)
    inj = FailureInjector({3: ["b"], 5: [("recover", "b")]})
    assert inj.apply(1, mon) == []
    assert inj.apply(3, mon) == ["b"]
    assert mon.alive() == ["a"]
    assert inj.apply(5, mon) == ["b"]
    assert mon.alive() == ["a", "b"]


def test_recover_of_never_registered_worker_joins():
    """``recover`` of a name the monitor has never seen is a JOIN — that
    is how a replacement node enters the fleet mid-run."""
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    mon.register("a")
    inj = FailureInjector({1: [("recover", "newbie")]})
    assert inj.apply(1, mon) == ["newbie"]
    assert mon.alive() == ["a", "newbie"]


def test_failure_injector_kill_of_unknown_worker_is_noop():
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    mon.register("a")
    inj = FailureInjector({1: ["ghost"]})
    assert inj.apply(1, mon) == ["ghost"]  # reported, but nothing to drop
    assert mon.alive() == ["a"]


def test_failure_injector_normalize_vocabulary():
    assert FailureInjector.normalize("a") == ("crash", "a")
    assert FailureInjector.normalize(("recover", "a")) == ("recover", "a")
    assert FailureInjector.normalize(["flap", "a", 2.0]) == ("flap", "a", 2.0)
    assert FailureInjector.normalize(("center_midround", 2)) == \
        ("center_midround", 2)
    with pytest.raises(ValueError, match="unknown chaos event"):
        FailureInjector.normalize(("explode", "a"))
    with pytest.raises(ValueError, match="unknown chaos event"):
        FailureInjector.normalize(())


def test_failure_injector_flap_degrades_to_crash_in_lm_loop():
    """The LM loop has no latency model, so a flap is a deregister until
    its recover; center events are no-ops against a bare monitor."""
    clock = SimClock()
    mon = HeartbeatMonitor(clock, timeout=10.0)
    mon.register("a")
    mon.register("b")
    inj = FailureInjector({
        1: [("flap", "b", 2.0), ("center_crash", 1)],
        2: [("recover", "b")],
    })
    assert inj.apply(1, mon) == ["b"]
    assert mon.alive() == ["a"]
    assert inj.apply(2, mon) == ["b"]
    assert mon.alive() == ["a", "b"]


def test_plan_remesh_preserves_tp():
    plan = plan_remesh(512, tp=16)
    assert (plan.dp, plan.tp, plan.devices) == (32, 16, 512)
    # lose 17 devices -> dp shrinks, tp preserved, 15 idle
    plan = plan_remesh(495, tp=16)
    assert plan.tp == 16 and plan.dp == 30 and plan.dropped_workers == 15
    with pytest.raises(RuntimeError):
        plan_remesh(7, tp=16)
