"""Tile-slicing coverage for the flat-buffer codec at awkward shapes.

``tile_slices`` / ``unpack_pytree_tile`` carry the sharded
``secure_psum`` wire (``reveal="sharded"``: the rows axis reduce-scatters
into per-device tiles), so their static fragment table is pinned here at
the shapes that historically go wrong: a dimension not divisible by the
device count, single-element leaves straddling nothing, tiles that are
pure zero-pad tail, and reassembly equivalence with ``unpack_pytree``.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flatbuf import (LANES, ROW_ALIGN, pack_pytree, tile_slices,
                                unpack_pytree, unpack_pytree_tile)


def _tree(d: int):
    return {
        "gradient": jnp.arange(d, dtype=jnp.float64) - d / 2,
        "hessian": jnp.arange(d * d, dtype=jnp.float64).reshape(d, d) * 0.5,
        "deviance": jnp.asarray(3.25, jnp.float64).reshape(()),
    }


def _reassemble(buf, layout, num_tiles):
    """Stitch every tile's fragments back into full raveled leaves."""
    rows = layout.rows // num_tiles
    parts = {i: {} for i in range(len(layout.shapes))}
    for t in range(num_tiles):
        tile = buf[t * rows:(t + 1) * rows]
        for leaf, (start, stop, frag) in unpack_pytree_tile(
            tile, layout, t, num_tiles
        ).items():
            parts[leaf][start] = (stop, frag)
    leaves = []
    for i, shape in enumerate(layout.shapes):
        n = int(np.prod(shape, dtype=np.int64))
        flat = np.zeros(n)
        covered = 0
        for start in sorted(parts[i]):
            stop, frag = parts[i][start]
            flat[start:stop] = np.asarray(frag)
            covered += stop - start
        assert covered == n, f"leaf {i} fragments do not tile the leaf"
        leaves.append(flat.reshape(shape))
    return leaves


def test_rows_not_divisible_raises():
    # d=4: gradient 4 + hessian 16 + scalar = 21 elements -> 8 rows
    _, layout = pack_pytree(_tree(4))
    assert layout.rows == ROW_ALIGN
    with pytest.raises(ValueError, match="does not split"):
        tile_slices(layout, 3)


def test_lcm_row_align_makes_awkward_counts_divisible():
    """d=5 over 3 devices: 31 elements never aligns at row_align=8, but
    the lcm(8, 3) alignment the sharded wire uses always does."""
    num_tiles = 3
    buf, layout = pack_pytree(_tree(5),
                              row_align=math.lcm(ROW_ALIGN, num_tiles))
    assert layout.rows % num_tiles == 0
    leaves = _reassemble(buf, layout, num_tiles)
    np.testing.assert_array_equal(leaves[1], np.arange(5) - 2.5)


def test_fragment_table_is_static_and_covers_leaves():
    num_tiles = 4
    buf, layout = pack_pytree(_tree(7),
                              row_align=math.lcm(ROW_ALIGN, num_tiles))
    table = tile_slices(layout, num_tiles)
    assert len(table) == num_tiles
    # fragments are plain ints (compile-time constants for jitted code)
    for frags in table:
        for f in frags:
            assert all(isinstance(v, int)
                       for v in (f.leaf, f.leaf_start, f.leaf_stop,
                                 f.tile_offset))
    # per-leaf coverage: fragment extents partition [0, n) exactly
    for i, shape in enumerate(layout.shapes):
        n = int(np.prod(shape, dtype=np.int64))
        spans = sorted(
            (f.leaf_start, f.leaf_stop)
            for frags in table for f in frags if f.leaf == i
        )
        assert spans[0][0] == 0 and spans[-1][1] == n
        assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))


def test_single_row_leaves_and_empty_tail_tiles():
    """Tiny leaves land whole in tile 0; trailing tiles that are pure
    zero-pad carry NO fragments (the pad belongs to nobody)."""
    tree = {"a": jnp.asarray([1.0, 2.0]), "b": jnp.asarray(7.0).reshape(())}
    num_tiles = 8
    buf, layout = pack_pytree(tree, row_align=num_tiles)
    table = tile_slices(layout, num_tiles)
    first = unpack_pytree_tile(buf[:layout.rows // num_tiles], layout, 0,
                               num_tiles)
    assert set(first) == {0, 1}
    np.testing.assert_array_equal(np.asarray(first[0][2]), [1.0, 2.0])
    assert first[1] == (0, 1, first[1][2])
    assert float(first[1][2][0]) == 7.0
    # 3 elements in a (8, 128) buffer: every tile past the first is pad
    for t in range(1, num_tiles):
        assert table[t] == ()
        assert unpack_pytree_tile(
            buf[t * (layout.rows // num_tiles):
                (t + 1) * (layout.rows // num_tiles)],
            layout, t, num_tiles,
        ) == {}


def test_tile_reassembly_matches_unpack_pytree():
    num_tiles = 6
    tree = _tree(9)
    buf, layout = pack_pytree(tree,
                              row_align=math.lcm(ROW_ALIGN, num_tiles))
    whole = unpack_pytree(buf, layout)
    leaves = _reassemble(buf, layout, num_tiles)
    np.testing.assert_array_equal(leaves[1], np.asarray(whole["gradient"]))
    np.testing.assert_array_equal(leaves[2], np.asarray(whole["hessian"]))
    np.testing.assert_array_equal(
        leaves[0].reshape(()), np.asarray(whole["deviance"])
    )


def test_leaf_straddles_tile_boundary():
    """A leaf bigger than one tile splits into per-tile fragments whose
    tile_offsets are where the fragment starts inside each tile."""
    num_tiles = 2
    d = 40  # hessian d*d = 1600 elements > one (8, 128) = 1024-elem tile
    buf, layout = pack_pytree(_tree(d), row_align=ROW_ALIGN * num_tiles)
    table = tile_slices(layout, num_tiles)
    hess_frags = [f for frags in table for f in frags if f.leaf == 2]
    assert len(hess_frags) == 2
    leaves = _reassemble(buf, layout, num_tiles)
    np.testing.assert_array_equal(
        leaves[2], np.arange(d * d).reshape(d, d) * 0.5
    )
