"""Per-kernel allclose vs pure-jnp oracle, shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.shamir_poly import mulmod31, addmod

P31 = 2**31 - 1
P31B = 2**31 - 19


# ---------------------------------------------------------------- gram_hessian
@pytest.mark.parametrize("n", [8, 100, 512, 1000])
@pytest.mark.parametrize("d", [3, 84, 128, 200])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_gram_hessian_matches_ref(n, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 1000 + d))
    X = jax.random.normal(k1, (n, d), dtype=dtype)
    w = jax.random.uniform(k2, (n,), dtype=dtype, minval=0.0, maxval=0.25)
    got = ops.gram_hessian(X, w)
    want = ref.gram_hessian(X, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_gram_hessian_block_sweep():
    X = jax.random.normal(jax.random.PRNGKey(0), (777, 84))
    w = jax.random.uniform(jax.random.PRNGKey(1), (777,))
    want = ref.gram_hessian(X, w)
    for bn in (64, 128, 512):
        got = ops.gram_hessian(X, w, block_n=bn)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ fused_irls
@pytest.mark.parametrize("counts", [
    (512, 512), (300, 512), (3, 1111, 40), (1, 1)
], ids=lambda c: "x".join(map(str, c)))
@pytest.mark.parametrize("d", [6, 84, 128])
def test_fused_irls_matches_ref_ragged(counts, d):
    """One launch over ragged institutions == masked batched oracle."""
    s_dim, n_max = len(counts), max(counts)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n_max + d), 3)
    X = jax.random.normal(k1, (s_dim, n_max, d), dtype=jnp.float64)
    y = jax.random.bernoulli(k2, 0.4, (s_dim, n_max)).astype(jnp.float64)
    beta = 0.3 * jax.random.normal(k3, (d,), dtype=jnp.float64)
    cnt = jnp.asarray(counts, jnp.int32)
    H_r, g_r, dev_r = ref.fused_irls(beta, X, y, cnt)
    for simulate in (False, True):  # real interpreted kernel + XLA sim
        H, g, dev = ops.fused_irls(beta, X, y, cnt, simulate=simulate)
        np.testing.assert_allclose(H, H_r, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(g, g_r, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(dev, dev_r, rtol=1e-12)


def test_fused_irls_block_sweep_masks_exactly():
    """Blocked accumulation + masking is invariant to block size, including
    blocks larger than the smallest institution."""
    counts = (7, 530, 64)
    X = jax.random.normal(jax.random.PRNGKey(0), (3, 530, 12), jnp.float64)
    y = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (3, 530)).astype(
        jnp.float64
    )
    beta = 0.1 * jnp.ones((12,), jnp.float64)
    cnt = jnp.asarray(counts, jnp.int32)
    _, g_want, dev_want = ref.fused_irls(beta, X, y, cnt)
    for bn in (8, 64, 512):
        H, g, dev = ops.fused_irls(beta, X, y, cnt, block_n=bn,
                                   simulate=False)
        np.testing.assert_allclose(g, g_want, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(dev, dev_want, rtol=1e-12)


def test_fused_irls_kernel_matches_simulation():
    """The blocked Pallas kernel and its XLA functional simulation obey
    the same numerics contract: identical g/dev (payload-dtype math) and
    f32-tolerance-identical Gram."""
    counts = (100, 512)
    X = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 84), jnp.float64)
    y = jax.random.bernoulli(jax.random.PRNGKey(3), 0.3, (2, 512)).astype(
        jnp.float64
    )
    beta = 0.2 * jax.random.normal(jax.random.PRNGKey(4), (84,), jnp.float64)
    cnt = jnp.asarray(counts, jnp.int32)
    Hk, gk, devk = ops.fused_irls(beta, X, y, cnt, block_n=128,
                                  simulate=False)
    Hs, gs, devs = ops.fused_irls(beta, X, y, cnt, simulate=True)
    np.testing.assert_allclose(Hk, Hs, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gk, gs, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(devk, devs, rtol=1e-12)


def test_fused_irls_agrees_with_core_summaries():
    """Kernel path == the jnp path used by core.logreg, per institution."""
    from repro.core.logreg import local_summaries

    X = jax.random.normal(jax.random.PRNGKey(5), (2, 400, 20))
    y = jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (2, 400)).astype(
        jnp.float64
    )
    beta = jnp.zeros((20,), dtype=jnp.float64)
    H, g, dev = ops.fused_irls(beta, X, y)
    for j in range(2):
        s = local_summaries(beta, X[j], y[j])
        np.testing.assert_allclose(g[j], s.gradient, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(dev[j], s.deviance, rtol=1e-12)
        np.testing.assert_allclose(H[j], s.hessian, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- shamir_poly
@pytest.mark.parametrize("p", [P31, P31B])
@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_mulmod31_limb_decomposition(p, data):
    a = data.draw(st.integers(0, p - 1))
    b = data.draw(st.integers(0, p - 1))
    got = mulmod31(jnp.uint32(a), jnp.uint32(b), p)
    assert int(got) == (a * b) % p


@pytest.mark.parametrize("p", [P31, P31B])
def test_mulmod31_edge_cases(p):
    edges = [0, 1, 2, 0xFFFF, 0x10000, p - 1, p // 2, 2**30, 2**30 + 1]
    for a in edges:
        for b in edges:
            got = int(mulmod31(jnp.uint32(a), jnp.uint32(b), p))
            assert got == (a * b) % p, (a, b, p)


@pytest.mark.parametrize("p", [P31, P31B])
def test_mulmod31_adversarial_vs_bigint(p):
    """Vectorized sweep of limb-boundary / near-modulus operand pairs
    against Python big-int ground truth (the oracle the limb decomposition
    must reproduce exactly)."""
    corners = [
        0, 1, 2, 3,
        0x7FFF, 0x8000, 0x8001,            # 2**15 boundary (shl16 split)
        0xFFFF, 0x10000, 0x10001,          # 2**16 limb boundary
        0xFFFF_FFFF % p, (2**30 - 1), 2**30, 2**30 + 1,
        p - 1, p - 2, p - 19, p - 20,      # near the modulus
        (p - 1) // 2, (p + 1) // 2,
    ]
    a = np.asarray([x for x in corners for _ in corners], dtype=np.uint32)
    b = np.asarray(corners * len(corners), dtype=np.uint32)
    got = np.asarray(mulmod31(jnp.asarray(a), jnp.asarray(b), p))
    want = (a.astype(object) * b.astype(object)) % p  # big-int, no overflow
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@pytest.mark.parametrize("p", [P31, P31B])
def test_addmod_adversarial_vs_bigint(p):
    """addmod needs reduced inputs; sweep sums that straddle p exactly."""
    corners = [0, 1, 2, 0xFFFF, 0x10000, 2**30, p // 2, p // 2 + 1,
               p - 2, p - 1]
    a = np.asarray([x for x in corners for _ in corners], dtype=np.uint32)
    b = np.asarray(corners * len(corners), dtype=np.uint32)
    got = np.asarray(addmod(jnp.asarray(a), jnp.asarray(b), p))
    want = (a.astype(object) + b.astype(object)) % p
    np.testing.assert_array_equal(got, want.astype(np.uint32))


# ----------------------------------------------------------- shamir_reconstruct
@pytest.mark.parametrize("p", [P31, P31B])
@pytest.mark.parametrize("t,w", [(2, 3), (3, 5)])
def test_shamir_reconstruct_kernel_inverts_shares(p, t, w):
    """Kernel Lagrange reconstruction inverts the share kernel exactly,
    including from non-contiguous point subsets."""
    n = 513
    k1, k2 = jax.random.split(jax.random.PRNGKey(w * 10 + t))
    secret = jax.random.randint(k1, (n,), 0, p, dtype=jnp.int64).astype(
        jnp.uint64
    )
    coeffs = jax.random.randint(
        k2, (t - 1, n), 0, p, dtype=jnp.int64
    ).astype(jnp.uint64)
    shares = ops.shamir_shares(secret, coeffs, w, p)
    subsets = [list(range(1, t + 1)), [1] + list(range(w - t + 2, w + 1))]
    for pts in subsets:
        sub = shares[jnp.asarray([q - 1 for q in pts])]
        rec = ops.shamir_reconstruct(sub, pts, p)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(secret))


def test_shamir_reveal_flat_garner_matches_codec():
    """Fused reconstruct+CRT-decode == FixedPointCodec.decode, FIELD_WIDE."""
    from repro.core.field import FIELD_WIDE
    from repro.core.fixed_point import FixedPointCodec
    from repro.core.shamir import ShamirScheme

    codec = FixedPointCodec()
    sch = ShamirScheme(threshold=2, num_shares=3, field=FIELD_WIDE)
    rows = 8
    x = 100.0 * jax.random.normal(
        jax.random.PRNGKey(3), (rows, 128), jnp.float64
    )
    enc = codec.encode(x)  # (R, rows, 128)
    shares = sch.share(jax.random.PRNGKey(4), enc)  # (w, R, rows, 128)
    got = ops.shamir_reveal_flat(
        shares.astype(jnp.uint32), (1, 2, 3), FIELD_WIDE.moduli,
        codec.frac_bits,
    )
    want = codec.decode(sch.reconstruct(shares))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("p", [P31, P31B])
@pytest.mark.parametrize("t,w", [(2, 3), (3, 5), (5, 9)])
@pytest.mark.parametrize("n", [1, 100, 4096])
def test_shamir_kernel_matches_ref(p, t, w, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(t * 100 + n))
    secret = jax.random.randint(k1, (n,), 0, p, dtype=jnp.int64).astype(
        jnp.uint64
    )
    coeffs = jax.random.randint(
        k2, (t - 1, n), 0, p, dtype=jnp.int64
    ).astype(jnp.uint64)
    got = ops.shamir_shares(secret, coeffs, w, p)
    want = ref.shamir_shares(secret, coeffs, w, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shamir_kernel_shares_reconstruct_via_core():
    """Kernel-produced shares must reconstruct through core.shamir."""
    from repro.core.field import FIELD31, lift_signed
    from repro.core.shamir import ShamirScheme

    p = P31
    n = 257
    vals = jnp.arange(-128, 129, dtype=jnp.int64)
    secret = lift_signed(vals, FIELD31)[0]  # (n,) uint64
    coeffs = jax.random.randint(
        jax.random.PRNGKey(9), (1, n), 0, p, dtype=jnp.int64
    ).astype(jnp.uint64)
    shares = ops.shamir_shares(secret, coeffs, 3, p)  # (3, n)
    sch = ShamirScheme(threshold=2, num_shares=3, field=FIELD31)
    rec = sch.reconstruct(shares[:, None, :], points=[1, 2, 3])
    assert (rec[0] == secret).all()
