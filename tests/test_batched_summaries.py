"""Batched ragged-institution summaries + the fused secure Newton path.

Pins the tentpole contracts: (a) one batched launch over padded ragged
partitions reproduces the per-institution ``local_summaries`` oracle
exactly (g/dev) / to f32-Gram tolerance (H); (b) the jit-resident fused
``secure_fit`` matches ``centralized_fit`` (paper Fig. 2, R^2 = 1) and the
pre-fusion loop path bit-for-bit up to fixed-point quantization, across
protect modes, backends, and uneven partitions including an institution
smaller than one kernel block; (c) the streaming aggregation path equals
the stacked-reduction oracle it replaced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SecureAggregator,
    batched_local_summaries,
    centralized_fit,
    local_summaries,
    pack_cache_clear,
    pack_cache_evict,
    pack_cache_len,
    pack_partitions,
    secure_fit,
)
from repro.core import batched_summaries as bs_mod
from repro.core.field import fsum
from repro.data import generate_synthetic


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(7), num_institutions=4,
        records_per_institution=300, dim=10,
    )


def _uneven_parts(study, sizes=(3, 170, 512, 515)):
    """Re-split the pooled study into deliberately ragged partitions.

    3 rows < any kernel block; the rest straddle block boundaries.
    """
    X, y = study.pooled()
    assert sum(sizes) == X.shape[0]
    parts, off = [], 0
    for s in sizes:
        parts.append((X[off:off + s], y[off:off + s]))
        off += s
    return parts


# ------------------------------------------------- batched summaries oracle
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_batched_matches_per_institution_oracle(study, backend):
    parts = _uneven_parts(study)
    packed = pack_partitions(parts)
    beta = 0.1 * jnp.arange(10, dtype=jnp.float64)
    out = batched_local_summaries(beta, packed, backend=backend)
    for j, (Xj, yj) in enumerate(parts):
        want = local_summaries(beta, Xj, yj)
        np.testing.assert_allclose(out.gradient[j], want.gradient,
                                   rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(out.deviance[j], want.deviance,
                                   rtol=1e-12)
        tol = dict(rtol=1e-9) if backend == "reference" else \
            dict(rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(out.hessian[j], want.hessian, **tol)
        assert int(out.count[j]) == Xj.shape[0]


def test_pack_partitions_memoized_per_study(study):
    """Same part arrays -> same packed object; new arrays -> fresh pack."""
    parts = _uneven_parts(study)
    p1 = pack_partitions(parts)
    p2 = pack_partitions(parts)
    assert p1 is p2
    assert pack_partitions(parts, dtype=jnp.float32) is not p1
    fresh = [(Xj + 0.0, yj) for Xj, yj in parts]  # new buffers, same values
    p3 = pack_partitions(fresh)
    assert p3 is not p1
    np.testing.assert_array_equal(np.asarray(p3.X), np.asarray(p1.X))


def test_pack_cache_serves_alternating_studies(study):
    """The LRU holds several studies at once: alternating between two
    part sets (the single-slot memo's thrash case) hits both ways."""
    parts_a = _uneven_parts(study)
    parts_b = [(Xj + 0.0, yj + 0.0) for Xj, yj in parts_a]
    pa, pb = pack_partitions(parts_a), pack_partitions(parts_b)
    assert pack_partitions(parts_a) is pa  # not evicted by study b
    assert pack_partitions(parts_b) is pb
    assert pack_partitions(parts_a) is pa


def test_pack_cache_bounded_lru():
    pack_cache_clear()
    keep = []
    for k in range(bs_mod._PACK_CACHE_SIZE + 3):
        parts = [(jnp.full((4, 3), float(k)), jnp.ones(4))]
        keep.append(parts)  # hold buffers so entries die only by LRU
        pack_partitions(parts)
    assert pack_cache_len() == bs_mod._PACK_CACHE_SIZE
    # oldest evicted, newest resident
    newest = pack_partitions(keep[-1])
    assert pack_partitions(keep[-1]) is newest


def test_pack_cache_entry_dies_with_its_buffers():
    """Evict-on-collect: when a part buffer is garbage collected the
    entry goes too, so a recycled id can never alias a stale pack."""
    import gc

    pack_cache_clear()
    parts = [(jnp.ones((4, 3)), jnp.ones(4))]
    pack_partitions(parts)
    assert pack_cache_len() == 1
    del parts
    gc.collect()
    assert pack_cache_len() == 0


def test_pack_cache_evict_on_churn(study):
    """pack_cache_evict drops every entry containing a churned buffer
    (the coordinator's add/remove_institution hook)."""
    pack_cache_clear()
    parts = _uneven_parts(study)
    p1 = pack_partitions(parts)
    assert pack_cache_len() == 1
    pack_cache_evict([parts[0]])
    assert pack_cache_len() == 0
    assert pack_partitions(parts) is not p1  # repacked, not resurrected


def test_pack_partitions_validates():
    X = jnp.ones((4, 3))
    with pytest.raises(ValueError, match="at least one"):
        pack_partitions([])
    with pytest.raises(ValueError, match="feature dimension"):
        pack_partitions([(X, jnp.ones(4)), (jnp.ones((2, 5)), jnp.ones(2))])
    packed = pack_partitions([(X, jnp.ones(4)), (2 * jnp.ones((1, 3)),
                                                 jnp.zeros(1))])
    assert packed.X.shape == (2, 4, 3)
    assert packed.total_records == 5
    assert packed.X32.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(packed.counts), [4, 1])
    # padding rows are zero (masking makes them inert either way)
    np.testing.assert_array_equal(np.asarray(packed.X[1, 1:]), 0.0)


# ----------------------------------------------------- secure_fit parity
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_secure_fit_uneven_partitions_match_gold(study, backend):
    """Fig. 2 on ragged partitions: R^2 = 1 vs the pooled gold standard,
    on both backends (reference -> loop path, pallas -> fused path)."""
    parts = _uneven_parts(study)
    gold = centralized_fit(*study.pooled(), lam=1.0)
    agg = SecureAggregator(backend=backend)
    sec = secure_fit(parts, lam=1.0, protect="both", aggregator=agg)
    assert sec.converged and gold.converged
    np.testing.assert_allclose(sec.beta, gold.beta, atol=1e-6)
    r2 = np.corrcoef(sec.beta, gold.beta)[0, 1] ** 2
    assert r2 > 0.999999


@pytest.mark.parametrize("protect", ["none", "gradient", "hessian", "both"])
def test_fused_matches_loop_within_quantization(study, protect):
    """The jit-resident fused iteration and the pre-fusion Python loop
    converge to the same beta well inside fixed-point quantization."""
    parts = _uneven_parts(study)
    agg = SecureAggregator(backend="pallas")
    loop = secure_fit(parts, protect=protect, aggregator=agg, fused=False)
    fus = secure_fit(parts, protect=protect, aggregator=agg, fused=True)
    quant = (len(parts) + 1) / agg.codec.scale
    assert fus.converged and loop.converged
    assert np.abs(fus.beta - loop.beta).max() <= quant
    assert fus.iterations == loop.iterations
    # telemetry comes from static shapes and must agree across paths
    assert fus.bytes_transmitted == loop.bytes_transmitted


def test_fused_requires_pallas_backend(study):
    with pytest.raises(ValueError, match="pallas"):
        secure_fit(study.parts, aggregator=SecureAggregator(), fused=True)


def test_fused_l1_prox_path(study):
    """Elastic-net solve goes through the same fused iteration."""
    parts = _uneven_parts(study)
    agg = SecureAggregator(backend="pallas")
    loop = secure_fit(parts, protect="gradient", aggregator=agg,
                      fused=False, l1=0.05)
    fus = secure_fit(parts, protect="gradient", aggregator=agg,
                     fused=True, l1=0.05)
    np.testing.assert_allclose(fus.beta, loop.beta, atol=1e-7)


# ------------------------------------------------- streaming aggregation
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_streaming_aggregate_equals_stacked_oracle(backend, rng_key):
    """The accumulator fold == the stacked single-reduction it replaced,
    element-exact in the field."""
    agg = SecureAggregator(backend=backend)
    tree = {"g": jnp.asarray([1.5, -2.25, 3.0]), "d": jnp.asarray(0.125)}
    prot = [agg.protect(jax.random.fold_in(rng_key, j), tree)
            for j in range(5)]
    got = agg.aggregate(prot)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *prot
    )
    want = jax.tree_util.tree_map(
        lambda s: fsum(s, agg.scheme.field, axis=0, residue_axis=1), stacked
    )
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    out = agg.reveal(got)
    np.testing.assert_allclose(np.asarray(out["g"]),
                               5 * np.asarray(tree["g"]), atol=1e-6)


def test_protect_batched_roundtrip(rng_key):
    """protect_batched + aggregate_batched == sum of the S inputs."""
    agg = SecureAggregator(backend="pallas")
    tree = {
        "h": jnp.arange(24, dtype=jnp.float64).reshape(3, 2, 4),
        "dev": jnp.asarray([0.5, -1.5, 2.0]),
    }
    prot = agg.protect_batched(rng_key, tree)
    assert prot.buf.shape[2] == 3  # S axis
    agg_b = agg.aggregate_batched(prot)
    out = agg.reveal(agg_b)
    np.testing.assert_allclose(
        np.asarray(out["h"]), np.asarray(jnp.sum(tree["h"], axis=0)),
        atol=3 * 0.5 / agg.codec.scale,
    )
    np.testing.assert_allclose(
        np.asarray(out["dev"]), float(jnp.sum(tree["dev"])),
        atol=3 * 0.5 / agg.codec.scale,
    )
    with pytest.raises(ValueError, match="pallas"):
        SecureAggregator().protect_batched(rng_key, tree)
