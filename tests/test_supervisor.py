"""Fault-tolerant round supervision: the deterministic chaos matrix.

The invariant under test (see ``runtime/supervisor.py``): any survivable
``FailureInjector`` schedule converges to the fault-free oracle's beta
within fixed-point quantization, on all three secure drivers; genuinely
unsurvivable schedules surface the driver's exact ``RuntimeError``.
Center-fault schedules are *bit*-identical (any >= t evaluation points
reconstruct the same field element); institution faults are oracle-exact
when they heal before convergence (the Newton fixed point doesn't move).
"""
import jax
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    SecureFitDriver,
    ShamirScheme,
    StudyCoordinator,
)
from repro.data import generate_synthetic
from repro.runtime import (
    FailureInjector,
    FaultPolicy,
    RoundSupervisor,
    StragglerPolicy,
)

NUM_INST = 4
NAMES = [f"i{k}" for k in range(NUM_INST)]


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(3), num_institutions=NUM_INST,
        records_per_institution=150, dim=5,
    )


def make_insts(study):
    return [
        Institution(n, X, y) for n, (X, y) in zip(NAMES, study.parts)
    ]


def make_driver(kind, study, **kw):
    if kind == "coordinator":
        return StudyCoordinator(
            make_insts(study), lam=1.0, protect="gradient", **kw
        )
    if kind == "coordinator-fused":
        return StudyCoordinator(
            make_insts(study), lam=1.0, protect="gradient", fused=True,
            aggregator=SecureAggregator(backend="pallas"), **kw
        )
    if kind == "secure_fit":
        return SecureFitDriver(
            study.parts, lam=1.0, protect="gradient", names=NAMES,
            fused=False, **kw
        )
    if kind == "secure_fit-fused":
        return SecureFitDriver(
            study.parts, lam=1.0, protect="gradient", names=NAMES,
            aggregator=SecureAggregator(backend="pallas"), fused=True, **kw
        )
    raise ValueError(kind)


def final_beta(driver):
    return np.asarray(driver.beta)


def quantization(study, driver):
    return (len(study.parts) + 1) * 0.5 / driver.agg.codec.scale


@pytest.fixture(scope="module")
def oracle_betas(study):
    """Fault-free converged beta per driver kind (the chaos oracle)."""
    out = {}
    for kind in ("coordinator", "coordinator-fused", "secure_fit",
                 "secure_fit-fused"):
        drv = make_driver(kind, study)
        RoundSupervisor(drv, policy=FaultPolicy()).run(max_rounds=50)
        out[kind] = final_beta(drv)
    return out


def policy(**kw):
    kw.setdefault("max_retries", 4)
    kw.setdefault("heartbeat_timeout", 3.0)
    kw.setdefault(
        "straggler", StragglerPolicy(deadline=2.0, quorum_fraction=0.5)
    )
    return FaultPolicy(**kw)


# every schedule here is survivable and heals before convergence; the
# round numbers land inside the ~6-9 round fit
SURVIVABLE = {
    # flap heals at t=4 (round 5), well before the ~6-9 round fit converges
    "flap": {2: [("flap", "i1", 3.0)]},
    "straggle_burst": {2: [("straggle", "i2", 9.0, 2.0)]},
    "crash_recover": {2: [("crash", "i0")], 4: [("recover", "i0")]},
    "center_crash_recover": {
        2: [("center_crash", 2)], 4: [("center_recover", 2)],
    },
    "center_midround": {3: [("center_midround", 1)]},
    "mixed": {
        2: [("flap", "i1", 5.0)],
        3: [("center_crash", 2)],
        4: [("recover", "i1")],
        5: [("center_midround", 1)],
    },
}

TIER1_KINDS = ("coordinator", "secure_fit-fused")
SLOW_KINDS = ("coordinator-fused", "secure_fit")


def run_chaos(kind, study, schedule, oracle_betas, **pol_kw):
    drv = make_driver(kind, study)
    sup = RoundSupervisor(
        drv, policy=policy(**pol_kw), injector=FailureInjector(schedule)
    )
    sup.run(max_rounds=60)
    assert drv.converged
    err = np.abs(final_beta(drv) - oracle_betas[kind]).max()
    assert err <= quantization(study, drv), (kind, err)
    return sup


@pytest.mark.parametrize("schedule", sorted(SURVIVABLE))
@pytest.mark.parametrize("kind", TIER1_KINDS)
def test_survivable_schedule_matches_oracle(kind, schedule, study,
                                            oracle_betas):
    run_chaos(kind, study, SURVIVABLE[schedule], oracle_betas)


@pytest.mark.slow
@pytest.mark.parametrize("schedule", sorted(SURVIVABLE))
@pytest.mark.parametrize("kind", SLOW_KINDS)
def test_survivable_schedule_matches_oracle_full_matrix(kind, schedule,
                                                        study,
                                                        oracle_betas):
    run_chaos(kind, study, SURVIVABLE[schedule], oracle_betas)


def test_degraded_round_telemetry(study, oracle_betas):
    sup = run_chaos("coordinator", study, SURVIVABLE["mixed"], oracle_betas)
    flagged = [r for r in sup.rounds if r.degraded]
    assert flagged, "chaos run must produce degraded rounds"
    # the RoundReport mirrors the supervisor record
    for rec in sup.rounds:
        if rec.report is None:
            continue
        assert rec.report.retries == rec.retries
        assert rec.report.backoff_seconds == rec.backoff_seconds
        assert rec.report.degraded == rec.degraded
    # a fault-free supervised run reports all-default telemetry
    drv = make_driver("coordinator", study)
    sup0 = RoundSupervisor(drv, policy=policy())
    sup0.run(max_rounds=50)
    assert sup0.total_retries == 0 and sup0.total_backoff == 0.0
    assert all(not r.degraded for r in sup0.rounds)
    assert all(r.report.retries == 0 and not r.report.degraded
               for r in sup0.rounds)


def test_midround_below_threshold_aborts_and_reshares(study, oracle_betas):
    """Both centers of a t=2 reveal die between protect and reveal: the
    round aborts (reveals nothing), the supervisor re-provisions and the
    retry re-shares with fresh polynomials — converging to the oracle."""
    schedule = {2: [("center_midround", 1), ("center_midround", 2)]}
    drv = make_driver("coordinator", study)
    sup = RoundSupervisor(
        drv, policy=policy(), injector=FailureInjector(schedule)
    )
    sup.run(max_rounds=60)
    assert drv.converged
    rec = sup.rounds[1]  # round 2
    assert rec.aborted_attempts == 1 and rec.retries >= 1
    assert rec.report.aborted_attempts == 1
    err = np.abs(final_beta(drv) - oracle_betas["coordinator"]).max()
    assert err <= quantization(study, drv)


def test_center_reprovision_uses_fresh_point(study, oracle_betas):
    """w=4 scheme run with 3 centers: the spare evaluation point is the
    replacement's fresh identity after a crash."""
    agg = SecureAggregator(scheme=ShamirScheme(threshold=2, num_shares=4))
    drv = StudyCoordinator(
        make_insts(study), lam=1.0, protect="gradient", aggregator=agg,
        num_centers=3,
    )
    schedule = {2: [("center_crash", 1), ("center_crash", 2)]}
    sup = RoundSupervisor(
        drv, policy=policy(), injector=FailureInjector(schedule)
    )
    sup.run(max_rounds=60)
    assert drv.converged
    points = {c.index for c in drv.centers if c.online}
    assert 4 in points  # the spare point was provisioned
    err = np.abs(final_beta(drv) - oracle_betas["coordinator"]).max()
    assert err <= quantization(study, drv)


@pytest.mark.parametrize("kind", ("coordinator", "secure_fit"))
def test_unsurvivable_center_loss_raises_exact_error(kind, study):
    drv = make_driver(kind, study)
    sup = RoundSupervisor(
        drv, policy=policy(max_retries=2, reprovision_after=0),
        injector=FailureInjector(
            {1: [("center_crash", 1), ("center_crash", 2)]}
        ),
    )
    with pytest.raises(RuntimeError,
                       match="aggregate unrecoverable this round"):
        sup.step()
    assert drv.iteration == 0  # failed rounds leave state untouched


@pytest.mark.parametrize("kind", ("coordinator", "secure_fit"))
def test_unsurvivable_quorum_raises_exact_error(kind, study):
    drv = make_driver(
        kind, study, min_responders=2,
    )
    sup = RoundSupervisor(
        drv, policy=policy(max_retries=2),
        injector=FailureInjector({1: [("crash", n) for n in NAMES]}),
    )
    with pytest.raises(RuntimeError, match="responders < min"):
        sup.step()
    assert drv.iteration == 0


# -- the selection driver -----------------------------------------------------

SEL_KW = dict(lambdas=(4.0, 1.0, 0.25), num_folds=3, rounds_per_sync=4,
              max_rounds=12, seed=0)


@pytest.fixture(scope="module")
def sel_study():
    return generate_synthetic(
        jax.random.PRNGKey(5), num_institutions=NUM_INST,
        records_per_institution=120, dim=4,
    )


def make_selection(sel_study):
    from repro.selection import SelectionCoordinator

    return SelectionCoordinator(
        [Institution(n, X, y) for n, (X, y) in zip(NAMES, sel_study.parts)],
        **SEL_KW,
    )


@pytest.fixture(scope="module")
def sel_oracle(sel_study):
    return make_selection(sel_study).run_path()


def test_selection_center_faults_bit_identical(sel_study, sel_oracle):
    """Center-only chaos on the λ sweep: crash, mid-chunk death below
    threshold (abort + re-provision + re-share), recover — the selected
    λ and every beta are BIT-identical to the fault-free sweep (any >= t
    points reconstruct the same field element)."""
    schedule = {
        1: [("center_crash", 2)],
        3: [("center_midround", 1)],
        4: [("center_recover", 2)],
    }
    sel = make_selection(sel_study)
    sup = RoundSupervisor(
        sel, policy=policy(), injector=FailureInjector(schedule)
    )
    report = sup.run(max_rounds=40)
    assert report.lambda_1se == sel_oracle.lambda_1se
    assert np.array_equal(np.asarray(report.beta),
                          np.asarray(sel_oracle.beta))
    aborted = [r for r in sup.rounds if r.aborted_attempts]
    assert aborted and aborted[0].round_no == 3


def test_selection_flap_healing_between_chunks(sel_study, sel_oracle):
    """An institution flap that heals between chunks: the affected chunk's
    CV sums are over the responders (by design), and with the cohort whole
    again for the remaining chunks the sweep selects the same λ and the
    full-cohort refit lands on the oracle beta."""
    sel = make_selection(sel_study)
    sup = RoundSupervisor(
        sel, policy=policy(),
        injector=FailureInjector({2: [("flap", "i3", 0.5)]}),
    )
    report = sup.run(max_rounds=40)
    assert report.lambda_1se == sel_oracle.lambda_1se
    err = np.abs(np.asarray(report.beta)
                 - np.asarray(sel_oracle.beta)).max()
    assert err <= (len(sel_study.parts) + 1) * 0.5 / sel.study.agg.codec.scale


@pytest.mark.parametrize("failure", ("centers", "quorum"))
def test_selection_unsurvivable_raises_exact_error(sel_study, failure):
    sel = make_selection(sel_study)
    if failure == "centers":
        schedule = {1: [("center_crash", 1), ("center_crash", 2)]}
        match = "aggregate unrecoverable this round"
    else:
        sel.study.min_responders = 2
        schedule = {1: [("crash", n) for n in NAMES]}
        match = "responders < min"
    sup = RoundSupervisor(
        sel, policy=policy(max_retries=2, reprovision_after=0),
        injector=FailureInjector(schedule),
    )
    with pytest.raises(RuntimeError, match=match):
        sup.step()
    assert sel.next_chunk == 0  # the failed chunk never ran


# -- crash-resume -------------------------------------------------------------

def test_secure_fit_resumes_bit_identically(study):
    """The acceptance pin: a SecureFitDriver killed after k rounds and
    rebuilt from state_dict() replays the rest of the fit bit-identically
    (same rng stream, same trace floats, same beta)."""
    a = make_driver("secure_fit", study)
    res_a = a.run()
    b = make_driver("secure_fit", study)
    for _ in range(3):
        b.step()
    state = {k: np.array(v) for k, v in b.state_dict().items()}
    c = make_driver("secure_fit", study)
    c.load_state_dict(state)
    res_c = c.run()
    assert res_c.deviance_trace == res_a.deviance_trace
    assert np.array_equal(res_c.beta, res_a.beta)
    assert res_c.iterations == res_a.iterations
    assert res_c.bytes_transmitted == res_a.bytes_transmitted


def test_supervised_resume_replays_schedule(study):
    """Crash the coordinator process mid-chaos: a fresh supervisor over a
    state_dict-restored driver continues the SAME schedule (round numbers
    keep their absolute meaning) and lands on the uninterrupted beta."""
    schedule = {2: [("center_midround", 2)], 5: [("center_recover", 2)]}
    a = make_driver("secure_fit", study)
    sup_a = RoundSupervisor(
        a, policy=policy(reprovision_after=0),
        injector=FailureInjector(schedule),
    )
    res_a = sup_a.run(max_rounds=60)

    b = make_driver("secure_fit", study)
    sup_b = RoundSupervisor(
        b, policy=policy(reprovision_after=0),
        injector=FailureInjector(schedule),
    )
    for _ in range(3):
        sup_b.step()
    state = {k: np.array(v) for k, v in b.state_dict().items()}

    c = make_driver("secure_fit", study)
    c.load_state_dict(state)
    assert not c.centers_online[1]  # the mid-round death survived the crash
    sup_c = RoundSupervisor(
        c, policy=policy(reprovision_after=0),
        injector=FailureInjector(schedule),
    )
    assert sup_c.round_no == 3
    res_c = sup_c.run(max_rounds=60)
    assert res_c.deviance_trace == res_a.deviance_trace
    assert np.array_equal(res_c.beta, res_a.beta)


def test_coordinator_failed_round_is_invisible_to_resume(study):
    """Satellite bugfix pin: a failed round must not advance iteration —
    the trace of (2 rounds, failed round, 2 rounds) equals 4 clean rounds,
    and a checkpoint taken after the failure resumes without off-by-one."""
    insts = make_insts(study)
    co = StudyCoordinator(insts, lam=1.0, protect="gradient",
                          min_responders=NUM_INST)
    co.step()
    co.step()
    key_before = np.array(co.key)
    insts[0].online = False
    with pytest.raises(RuntimeError, match="responders < min"):
        co.step()
    insts[0].online = True
    assert co.iteration == 2 and len(co.trace) == 2
    assert np.array_equal(np.array(co.key), key_before)

    clean = StudyCoordinator(make_insts(study), lam=1.0,
                             protect="gradient")
    state = {k: np.array(v) for k, v in co.state_dict().items()}
    resumed = StudyCoordinator(make_insts(study), lam=1.0,
                               protect="gradient")
    resumed.load_state_dict(state)
    beta_clean = clean.run()
    beta_failed = co.run()
    beta_resumed = resumed.run()
    assert clean.trace == co.trace == resumed.trace
    assert np.array_equal(beta_clean, beta_failed)
    assert np.array_equal(beta_clean, beta_resumed)


# -- provisioning semantics ---------------------------------------------------

def test_provision_center_semantics(study):
    agg = SecureAggregator(scheme=ShamirScheme(threshold=2, num_shares=4))
    co = StudyCoordinator(make_insts(study), lam=1.0, protect="gradient",
                          aggregator=agg, num_centers=3)
    co.centers[0].online = False
    fresh = co.provision_center()
    assert fresh.index == 4  # fresh point preferred over in-place swap
    replaced = co.provision_center()
    assert replaced.index == 1 and replaced.online  # in-place, lowest dead
    with pytest.raises(RuntimeError, match="still online"):
        co.provision_center(2)
    with pytest.raises(RuntimeError, match="no free evaluation point"):
        co.provision_center()
    with pytest.raises(ValueError, match="must be in 1..4"):
        co.provision_center(9)


def test_num_centers_bounds(study):
    agg = SecureAggregator(scheme=ShamirScheme(threshold=2, num_shares=3))
    with pytest.raises(ValueError, match="num_centers must lie in"):
        StudyCoordinator(make_insts(study), aggregator=agg, num_centers=1)
    with pytest.raises(ValueError, match="num_centers must lie in"):
        StudyCoordinator(make_insts(study), aggregator=agg, num_centers=4)
