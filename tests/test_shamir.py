"""Shamir scheme: correctness, threshold security, homomorphisms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.field import FIELD31, FIELD_WIDE, lift_signed
from repro.core.fixed_point import FixedPointCodec
from repro.core.shamir import ShamirScheme, lagrange_coeffs_at_zero
from repro.core.secure_agg import (
    SecureAggregator,
    secure_add,
    secure_scale_by_public,
)


@pytest.mark.parametrize("t,w", [(1, 1), (2, 3), (3, 5), (5, 9)])
@pytest.mark.parametrize("field", [FIELD31, FIELD_WIDE], ids=lambda f: f.name)
def test_share_reconstruct_roundtrip(t, w, field, rng_key):
    sch = ShamirScheme(threshold=t, num_shares=w, field=field)
    secret = lift_signed(
        jnp.asarray([0, 1, -1, 123456, -(10**9)], dtype=jnp.int64), field
    )
    shares = sch.share(rng_key, secret)
    assert shares.shape == (w, field.num_residues, 5)
    assert (sch.reconstruct(shares) == secret).all()
    # any t-subset suffices
    idx = list(range(w - t, w))
    sub = shares[jnp.asarray(idx)]
    pts = [i + 1 for i in idx]
    assert (sch.reconstruct(sub, points=pts) == secret).all()


def test_below_threshold_rejected(rng_key):
    sch = ShamirScheme(threshold=3, num_shares=5)
    secret = lift_signed(jnp.asarray([42], dtype=jnp.int64), sch.field)
    shares = sch.share(rng_key, secret)
    with pytest.raises(ValueError, match="irrecoverable"):
        sch.reconstruct(shares[:2], points=[1, 2])


def test_single_share_is_uniformly_distributed():
    """Information-theoretic hiding: one share of a constant secret should
    look uniform over the field (chi-square-lite bucket test)."""
    sch = ShamirScheme(threshold=2, num_shares=3, field=FIELD31)
    secret = lift_signed(jnp.zeros((2048,), dtype=jnp.int64), FIELD31)
    shares = sch.share(jax.random.PRNGKey(7), secret)
    one = np.asarray(shares[0][0], dtype=np.float64)  # first holder's slice
    p = FIELD31.moduli[0]
    hist, _ = np.histogram(one, bins=16, range=(0, p))
    expected = 2048 / 16
    # loose bound: all buckets within 40% of expectation
    assert (np.abs(hist - expected) < 0.4 * expected).all()


def test_shares_differ_across_institutions(rng_key):
    """Fresh polynomial randomness per protect() call."""
    sch = ShamirScheme()
    secret = lift_signed(jnp.asarray([99], dtype=jnp.int64), sch.field)
    s1 = sch.share(jax.random.PRNGKey(1), secret)
    s2 = sch.share(jax.random.PRNGKey(2), secret)
    assert not (s1 == s2).all()


@given(vals=st.lists(st.integers(-(2**30), 2**30), min_size=2, max_size=6))
@settings(max_examples=20, deadline=None)
def test_additive_homomorphism(vals):
    """Algorithm 2 correctness: share-wise sums reconstruct to the sum."""
    sch = ShamirScheme(threshold=2, num_shares=3, field=FIELD_WIDE)
    secrets = [
        lift_signed(jnp.asarray([v], dtype=jnp.int64), sch.field) for v in vals
    ]
    shared = [
        sch.share(jax.random.PRNGKey(i), s) for i, s in enumerate(secrets)
    ]
    acc = shared[0]
    for s in shared[1:]:
        acc = secure_add(acc, s, sch.field, residue_axis=1)
    total = int(sum(vals))
    expect = lift_signed(jnp.asarray([total], dtype=jnp.int64), sch.field)
    assert (sch.reconstruct(acc) == expect).all()


def test_scale_by_public_constant(rng_key):
    sch = ShamirScheme(field=FIELD_WIDE)
    secret = lift_signed(jnp.asarray([17, -5], dtype=jnp.int64), sch.field)
    shares = sch.share(rng_key, secret)
    c = lift_signed(jnp.asarray(7, dtype=jnp.int64), sch.field)
    c_b = c.reshape(1, sch.field.num_residues, 1)
    scaled = secure_scale_by_public(shares, c_b, sch.field, residue_axis=1)
    expect = lift_signed(jnp.asarray([119, -35], dtype=jnp.int64), sch.field)
    assert (sch.reconstruct(scaled) == expect).all()


def test_lagrange_weights_sum_property():
    """sum_i L_i(0) * x_i^0 reconstructs constants: weights of the constant
    polynomial must sum to 1 mod p."""
    for field in (FIELD31, FIELD_WIDE):
        lam = np.asarray(lagrange_coeffs_at_zero([1, 2, 3], field))
        for r, p in enumerate(field.moduli):
            assert int(lam[r].sum()) % p == 1


def test_pytree_share_roundtrip(rng_key):
    agg = SecureAggregator()
    tree = {"h": jnp.eye(3) * 2.5, "g": jnp.asarray([1.0, -2.0]),
            "dev": jnp.asarray(3.25)}
    prot = agg.protect(rng_key, tree)
    out = agg.reveal(prot)
    for k in tree:
        np.testing.assert_allclose(out[k], tree[k], atol=2**-20)


@given(
    floats=st.lists(
        st.floats(-1e5, 1e5, allow_nan=False, width=32), min_size=1, max_size=8
    )
)
@settings(max_examples=30, deadline=None)
def test_fixed_point_quantization_bound(floats):
    codec = FixedPointCodec()
    x = jnp.asarray(floats, dtype=jnp.float64)
    err = np.abs(np.asarray(codec.decode(codec.encode(x))) - np.asarray(x))
    assert (err <= 0.5 / codec.scale + 1e-12).all()
