"""Unit tests for the roofline estimator (launch/hlo_analysis.py).

The §Roofline tables and §Perf iteration verdicts all read through this
module, so its conventions are pinned here against hand-computable
micro-kernels: dot flops, loop trip-count multiplication, slice-aware
fusion operands, in-place dynamic-update-slice accounting, and collective
byte conventions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo
from repro.distributed.compat import shard_map


def _cost(fn, *specs):
    return analyze_hlo(jax.jit(fn).lower(*specs).compile().as_text())


def test_matmul_flops_exact():
    h = _cost(lambda a, b: a @ b,
              jax.ShapeDtypeStruct((256, 512), jnp.float32),
              jax.ShapeDtypeStruct((512, 128), jnp.float32))
    assert h.flops == 2 * 256 * 512 * 128
    # bytes: a + b + out
    expect = 4 * (256 * 512 + 512 * 128 + 256 * 128)
    assert h.bytes == pytest.approx(expect, rel=0.05)


def test_scan_trip_count_multiplies_flops():
    def f(a):
        def step(c, _):
            return c @ c * 0.5, None
        return jax.lax.scan(step, a, None, length=7)[0]

    h = _cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert h.flops == 7 * 2 * 128**3


def test_scan_output_dus_not_charged_full_buffer():
    """Scan stacking a large output writes via in-place DUS; per trip we
    must charge ~the slice, not the whole stacked buffer."""
    def f(a):
        def step(c, _):
            c = c * 1.0001
            return c, c
        _, ys = jax.lax.scan(step, a, None, length=64)
        return ys  # (64, 256, 256)

    h = _cost(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    full_buffer_per_trip = 64 * 64 * 256 * 256 * 4  # the wrong accounting
    assert h.bytes < 0.25 * full_buffer_per_trip
    # and at least the genuine traffic: 64 x (read c + write c + write ys)
    assert h.bytes > 64 * 2 * 256 * 256 * 4


def test_sliced_scan_param_not_charged_full_stack():
    """A scan slicing per-layer weights from a stacked (L, d, d) operand
    must charge the slice, not L x the stack per trip."""
    def f(x, w_stack):
        def step(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(step, x, w_stack)[0]

    L, d = 16, 128
    h = _cost(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
              jax.ShapeDtypeStruct((L, d, d), jnp.float32))
    # flops: L x dxd matmuls
    assert h.flops == L * 2 * d**3
    # bytes should be ~L x (one slice + carry io), nowhere near L x stack
    assert h.bytes < 3 * L * d * d * 4 * 4


def test_collective_conventions():
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    from jax.sharding import PartitionSpec as P

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P()))
    txt = fn.lower(jax.ShapeDtypeStruct((1024,), jnp.float32)) \
            .compile().as_text()
    h = analyze_hlo(txt)
    if h.collective_count:  # single-device AR may be optimized away
        assert h.collective_bytes["all-reduce"] == 2 * 1024 * 4  # 2x rule


_RS_AG_HLO = """
HloModule rs_ag

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %rs = f32[256]{0} reduce-scatter(%p0), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%rs), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""

_AR_HLO = """
HloModule ar

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_reduce_scatter_counts_operand_bytes():
    """RS moves the full operand (4096 B), not its 1/D-sized result."""
    h = analyze_hlo(_RS_AG_HLO)
    assert h.collective_count["reduce-scatter"] == 1
    assert h.collective_bytes["reduce-scatter"] == 1024 * 4


def test_all_gather_counts_result_bytes():
    """AG's traffic is the full gathered buffer it produces."""
    h = analyze_hlo(_RS_AG_HLO)
    assert h.collective_count["all-gather"] == 1
    assert h.collective_bytes["all-gather"] == 1024 * 4


def test_rs_ag_pair_matches_all_reduce():
    """The conventions must be self-consistent: decomposing an AR into
    its RS + AG phases may not change the collective-bytes total."""
    pair = analyze_hlo(_RS_AG_HLO)
    ar = analyze_hlo(_AR_HLO)
    assert ar.collective_bytes["all-reduce"] == 2 * 1024 * 4
    assert (pair.collective_bytes["reduce-scatter"]
            + pair.collective_bytes["all-gather"]
            ) == ar.collective_bytes["all-reduce"]


def test_nested_scan_trip_products():
    def f(a):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        return jax.lax.scan(outer, a, None, length=5)[0]

    h = _cost(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert h.flops == 5 * 3 * 2 * 64**3
