"""Dry-run machinery smoke: lower+compile reduced cells on tiny meshes.

Runs launch/dryrun.py as a subprocess (it must own XLA_FLAGS before jax
init) with --smoke --host-devices 4 --mesh-shape 2,2 for one arch per
family, plus the microbatched and optimized paths.  This is the CI guard
for the 256/512-chip sweeps.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(tmp_path, arch, shape, *extra):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--smoke",
           "--host-devices", "4", "--mesh-shape", "2,2",
           "--out", str(tmp_path), *extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    rec = json.load(open(os.path.join(tmp_path, sorted(files)[-1])))
    return rec


@pytest.mark.parametrize("arch", ["deepseek_7b", "rwkv6_3b",
                                  "qwen3_moe_235b"])
def test_dryrun_train_smoke(tmp_path, arch):
    rec = _run(tmp_path, arch, "train_4k")
    assert rec["hlo_analysis"]["flops_per_device"] > 0
    assert rec["memory"]["temp_bytes_per_device"] > 0


def test_dryrun_microbatch_and_optimized(tmp_path):
    rec = _run(tmp_path, "h2o_danube3_4b", "train_4k",
               "--microbatch", "2", "--optimized",
               "--variant", "opt")
    assert rec["variant"] == "opt"
    assert rec["hlo_analysis"]["flops_per_device"] > 0


def test_dryrun_decode_smoke(tmp_path):
    rec = _run(tmp_path, "recurrentgemma_9b", "decode_32k")
    assert rec["hlo_analysis"]["bytes_per_device"] > 0
