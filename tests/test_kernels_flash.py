"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode).

Sweeps shapes (ragged S, GQA groups, MQA, head dims needing padding) and
dtypes, asserting allclose against ref.flash_attention.  The kernel's
claim — scores/softmax state never reach HBM — is structural (VMEM
scratch); these tests pin the numerics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(key, B, S, H, KVH, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,S,H,KVH,D",
    [
        (1, 64, 4, 4, 32),    # MHA, D padded to 128
        (2, 128, 4, 2, 64),   # GQA group 2
        (1, 96, 8, 1, 128),   # MQA, ragged S (96 -> padded)
        (1, 200, 2, 2, 16),   # very ragged S, small D
    ],
)
def test_flash_kernel_matches_oracle(rng_key, B, S, H, KVH, D):
    q, k, v = _qkv(rng_key, B, S, H, KVH, D, jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    gold = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(out, gold, rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16(rng_key):
    q, k, v = _qkv(rng_key, 2, 64, 4, 2, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    gold = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(gold, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_flash_kernel_multiblock_online_softmax(rng_key):
    """S spanning many k blocks exercises the running (m, l) rescale."""
    q, k, v = _qkv(rng_key, 1, 256, 2, 2, 32, jnp.float32)
    # inject large score outliers to stress the max-shift
    q = q.at[:, 17].mul(30.0)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    gold = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(out, gold, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------- backward
def _bwd_oracle(q, k, v, do):
    def loss(q, k, v):
        o = ref.flash_attention(q, k, v)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32))

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize(
    "B,S,H,KVH,D",
    [
        (1, 64, 2, 2, 32),    # MHA
        (2, 64, 4, 2, 64),    # GQA group 2 (dk/dv group-summed in scratch)
        (1, 96, 4, 1, 16),    # MQA, ragged S + D padding
    ],
)
def test_flash_bwd_kernels_match_autodiff(rng_key, B, S, H, KVH, D):
    q, k, v = _qkv(rng_key, B, S, H, KVH, D, jnp.float32)
    do = jax.random.normal(jax.random.fold_in(rng_key, 3),
                           (B, S, H, D), jnp.float32)
    dq, dk, dv = ops.flash_attention_bwd(q, k, v, do, block_q=32,
                                         block_k=32)
    gq, gk, gv = _bwd_oracle(q, k, v, do)
    np.testing.assert_allclose(dq, gq, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dk, gk, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dv, gv, rtol=3e-5, atol=3e-5)


def test_flash_fwd_stats_consistent(rng_key):
    """The (m, l) emitted by the fwd kernel must normalize p exactly."""
    from repro.kernels.flash_attention import flash_attention_pallas

    q, k, v = _qkv(rng_key, 1, 64, 2, 2, 128, jnp.float32)
    qp = jnp.moveaxis(q, 2, 1).reshape(2, 64, 128)
    kp = jnp.moveaxis(k, 2, 1).reshape(2, 64, 128)
    vp = jnp.moveaxis(v, 2, 1).reshape(2, 64, 128)
    o, m, l = flash_attention_pallas(qp, kp, vp, group=1, seq_len=64,
                                     block_q=32, block_k=32)
    # recompute the softmax denominator directly
    s = jnp.einsum("hqd,htd->hqt", qp * 128**-0.5, kp)
    mask = jnp.tril(jnp.ones((64, 64), bool))
    s = jnp.where(mask[None], s, -1e30)
    np.testing.assert_allclose(m, s.max(-1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        l, jnp.exp(s - s.max(-1, keepdims=True)).sum(-1),
        rtol=2e-5, atol=2e-5,
    )
