"""Integration tests: train.py / serve.py drivers end-to-end on CPU."""
import json
import os

import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_logreg_driver_converges(tmp_path):
    out = tmp_path / "m.json"
    train_mod.main([
        "--arch", "logreg_paper", "--study", "parkinsons.total",
        "--scale", "0.05", "--out", str(out),
    ])
    m = json.loads(out.read_text())
    assert m["converged"] and m["r2_vs_gold"] > 0.999999
    assert m["iterations"] <= 10


def test_lm_driver_secure_agg_loss_decreases(tmp_path):
    out = tmp_path / "m.json"
    train_mod.main([
        "--arch", "rwkv6_3b", "--smoke", "--steps", "8",
        "--batch", "4", "--seq-len", "32", "--lr", "1e-2",
        "--secure-agg", "shamir", "--institutions", "2",
        "--out", str(out),
    ])
    m = json.loads(out.read_text())
    assert m["loss_last"] < m["loss_first"]


def test_lm_driver_checkpoint_resume(tmp_path):
    ck = tmp_path / "ck"
    args = [
        "--arch", "deepseek_7b", "--smoke", "--batch", "4",
        "--seq-len", "32", "--checkpoint-dir", str(ck),
        "--checkpoint-every", "3",
    ]
    train_mod.main(args + ["--steps", "6"])
    saved = sorted(os.listdir(ck))
    assert any("0000000006" in s for s in saved)
    out = tmp_path / "m.json"
    train_mod.main(args + ["--steps", "9", "--resume", "--out", str(out)])
    m = json.loads(out.read_text())
    assert m["steps"] == 3  # resumed at 6, ran to 9


def test_lm_driver_failure_injection():
    # institution 3 dies at step 2; loop proceeds with survivors
    train_mod.main([
        "--arch", "qwen2_5_32b", "--smoke", "--steps", "4",
        "--batch", "4", "--seq-len", "32",
        "--institutions", "4", "--fail-at", "2",
    ])


def test_serve_driver_batched_decode():
    rep = serve_mod.main([
        "--arch", "h2o_danube3_4b", "--requests", "5", "--batch", "2",
        "--prompt-len", "16", "--new-tokens", "4",
    ])
    assert rep["tokens_generated"] == 5 * 4
    assert len(rep["sample_output"]) == 4
