"""Fused secure-aggregation pipeline: backends, flat buffers, reconstruction.

Pins down the contracts of the Pallas hot path against the reference
oracle: bit-identical shares given the same coefficients, exact
share -> aggregate -> reconstruct round trips (including non-contiguous
reconstruction point subsets), and the flat-buffer codec layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.field import (
    FIELD31,
    FIELD_WIDE,
    fsum,
    lift_signed,
    random_elements,
)
from repro.core.fixed_point import FixedPointCodec
from repro.core.flatbuf import pack_pytree, unpack_pytree
from repro.core.secure_agg import FlatProtected, SecureAggregator
from repro.core.shamir import ShamirScheme
from repro.kernels import ops

FIELDS = [FIELD31, FIELD_WIDE]
TW = [(2, 3), (3, 5)]


def _schemes(t, w, field):
    ref = ShamirScheme(threshold=t, num_shares=w, field=field,
                       backend="reference")
    pal = ShamirScheme(threshold=t, num_shares=w, field=field,
                       backend="pallas")
    return ref, pal


# ---------------------------------------------------------- backend equality
@pytest.mark.parametrize("t,w", TW)
@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
def test_backends_bit_identical_shares(t, w, field, rng_key):
    """Same coefficients => byte-for-byte identical share tensors."""
    ref, pal = _schemes(t, w, field)
    secret = lift_signed(
        jnp.asarray([0, 1, -1, 123456, -(10**9), 7], dtype=jnp.int64), field
    )
    coeffs = random_elements(rng_key, (t - 1,) + secret.shape[1:], field)
    a = ref.share_with_coeffs(secret, coeffs)
    b = pal.share_with_coeffs(secret, coeffs)
    assert a.dtype == b.dtype == jnp.uint64
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("t,w", TW)
@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
def test_roundtrip_share_aggregate_reconstruct(t, w, field, rng_key):
    """share -> share-wise aggregate -> reconstruct, kernel vs reference,
    over non-contiguous reconstruction point subsets."""
    ref, pal = _schemes(t, w, field)
    vals = [
        jnp.asarray([3, -17, 2**20, -(2**25), 0], dtype=jnp.int64),
        jnp.asarray([100, 100, -100, 1, -1], dtype=jnp.int64),
        jnp.asarray([-5, 123, 456, -789, 10], dtype=jnp.int64),
    ]
    secrets = [lift_signed(v, field) for v in vals]
    keys = jax.random.split(rng_key, len(secrets))
    shared = [pal.share(k, s) for k, s in zip(keys, secrets)]
    stacked = jnp.stack(shared, axis=0)  # (S, w, R, n)
    agg = fsum(stacked, field, axis=0, residue_axis=1)
    total = lift_signed(sum(vals), field)
    # every t-sized non-contiguous subset of points must reconstruct, on
    # both backends, to the exact field encoding of the sum
    subsets = [tuple(range(1, t + 1)), tuple(range(w - t + 1, w + 1))]
    if w > t:
        subsets.append((1,) + tuple(range(w - t + 2, w + 1)))  # gap subset
    for pts in subsets:
        idx = jnp.asarray([p - 1 for p in pts])
        got_pal = pal.reconstruct(agg[idx], points=list(pts))
        got_ref = ref.reconstruct(agg[idx], points=list(pts))
        np.testing.assert_array_equal(np.asarray(got_pal), np.asarray(total))
        np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(got_pal))


# ------------------------------------------------------------- flat buffers
def test_pack_unpack_roundtrip():
    tree = {
        "h": jnp.arange(9, dtype=jnp.float64).reshape(3, 3),
        "g": jnp.asarray([1.5, -2.25], dtype=jnp.float32),
        "dev": jnp.asarray(3.25, dtype=jnp.float64),
    }
    buf, layout = pack_pytree(tree)
    assert buf.shape == (layout.rows, 128) and layout.rows % 8 == 0
    assert layout.num_elements == 12
    out = unpack_pytree(buf, layout)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
def test_flat_pipeline_end_to_end(field, rng_key):
    """protect -> aggregate -> reveal through FlatProtected, vs reference."""
    codec = FixedPointCodec(field=field)
    scale = 1.0 if field is FIELD31 else 1000.0  # stay inside capacity
    tree = {
        "a": scale * jnp.asarray([[0.5, -0.25], [1.0, 0.125]]),
        "b": scale * jnp.asarray([0.75, -0.375, 0.0625]),
    }
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(
            scheme=ShamirScheme(field=field, backend=backend), codec=codec
        )
        prot = [
            agg.protect(jax.random.fold_in(rng_key, j), tree)
            for j in range(3)
        ]
        if backend == "pallas":
            assert isinstance(prot[0], FlatProtected)
            assert prot[0].buf.dtype == jnp.uint32
        summed = agg.aggregate(prot)
        out = agg.reveal(summed)
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(out[k]), 3 * np.asarray(tree[k]),
                atol=3 * 0.5 / codec.scale + 1e-12,
            )


def test_flat_reveal_point_subsets(rng_key):
    """Reveal from a non-contiguous subset of center slices (t-of-w)."""
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=2, num_shares=5, backend="pallas")
    )
    tree = {"g": jnp.asarray([1.0, -2.0, 3.5])}
    prot = agg.protect(rng_key, tree)
    sub = jax.tree_util.tree_map(
        lambda s: s[jnp.asarray([1, 4])], prot
    )  # centers 2 and 5
    out = agg.reveal(sub, points=[2, 5])
    np.testing.assert_allclose(
        np.asarray(out["g"]), [1.0, -2.0, 3.5], atol=2**-20
    )


def test_flat_reveal_below_threshold_rejected(rng_key):
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=3, num_shares=5, backend="pallas")
    )
    prot = agg.protect(rng_key, {"g": jnp.asarray([42.0])})
    sub = jax.tree_util.tree_map(lambda s: s[:2], prot)
    with pytest.raises(ValueError, match="irrecoverable"):
        agg.reveal(sub, points=[1, 2])


def test_duplicate_reconstruction_points_rejected(rng_key):
    """Duplicate center ids must error loudly, not reconstruct garbage."""
    from repro.kernels.shamir_reconstruct import lagrange_weights_host

    with pytest.raises(ValueError, match="distinct"):
        lagrange_weights_host((1, 1), FIELD31.moduli)
    sch = ShamirScheme(threshold=2, num_shares=3, backend="pallas")
    secret = lift_signed(jnp.asarray([5], dtype=jnp.int64), sch.field)
    shares = sch.share(rng_key, secret)
    with pytest.raises(ValueError, match="distinct"):
        sch.reconstruct(shares[:2], points=[2, 2])


def test_flat_reveal_default_is_t_subset(rng_key):
    """points=None reconstructs from the first t slices on BOTH backends —
    bit-identical to any explicit t-subset (exact field arithmetic)."""
    tree = {"g": jnp.asarray([1.0, -2.0, 3.5, 0.125])}
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(
            scheme=ShamirScheme(threshold=2, num_shares=5, backend=backend)
        )
        prot = agg.protect(rng_key, tree)
        default = agg.reveal(prot)  # all 5 slices present, no points
        for pts in [(1, 2), (2, 4), (3, 5)]:
            idx = jnp.asarray([p - 1 for p in pts])
            sub = jax.tree_util.tree_map(lambda s: s[idx], prot)
            got = agg.reveal(sub, points=pts)
            np.testing.assert_array_equal(np.asarray(default["g"]),
                                          np.asarray(got["g"]))


# ------------------------------------------------------- overflow checking
def test_encode_exact_at_capacity():
    """Values inside capacity round-trip; check=True stays silent."""
    codec = FixedPointCodec()
    x = jnp.asarray([0.999999 * codec.capacity(), -0.5 * codec.capacity()])
    out = codec.decode(codec.encode(x, check=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=0, atol=1.0 / codec.scale)


def test_encode_past_capacity_raises_with_check():
    """Just past capacity: silent saturation by default (documented), a
    hard OverflowError with the debug check armed."""
    codec = FixedPointCodec()
    x = jnp.asarray([1.001 * codec.capacity()])
    # default path saturates: reveals the capacity bound, NOT the value
    sat = codec.decode(codec.encode(x))
    assert float(sat[0]) < float(x[0])
    with pytest.raises(OverflowError, match="capacity"):
        codec.encode(x, check=True)


def test_protect_overflow_check_both_backends(rng_key):
    """The protect paths wire the check to the headroom_ok contract."""
    over = {"g": jnp.asarray([1.001 * FixedPointCodec().capacity()])}
    ok = {"g": jnp.asarray([3.25])}
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(backend=backend, overflow_check=True)
        assert not agg.headroom_ok(float(over["g"][0]), 1)
        agg.protect(rng_key, ok)  # in capacity: silent
        with pytest.raises(OverflowError, match="capacity"):
            agg.protect(rng_key, over)


def test_protect_batched_overflow_check(rng_key):
    agg = SecureAggregator(backend="pallas", overflow_check=True)
    cap = agg.codec.capacity()
    bad = {"g": jnp.asarray([[0.5], [1.001 * cap]])}  # one bad institution
    with pytest.raises(OverflowError, match="capacity"):
        agg.protect_batched(rng_key, bad)
    # each slice inside capacity but the AGGREGATE would overflow: the
    # batched bound is capacity / S (the headroom_ok contract), so this
    # is caught at protect time instead of revealing a wrong float
    agg_over = {"g": jnp.asarray([[0.6 * cap], [0.6 * cap]])}
    assert not agg.headroom_ok(0.6 * cap, 2)
    with pytest.raises(OverflowError, match="capacity"):
        agg.protect_batched(rng_key, agg_over)


def test_reveal_default_below_threshold_raises(rng_key):
    """points=None on a short share stack: the informative below-threshold
    error on BOTH backends, not a point-count mismatch."""
    tree = {"g": jnp.asarray([1.0, -2.0])}
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(
            scheme=ShamirScheme(threshold=3, num_shares=5, backend=backend)
        )
        prot = agg.protect(rng_key, tree)
        short = jax.tree_util.tree_map(lambda s: s[:2], prot)
        with pytest.raises(ValueError, match="irrecoverable"):
            agg.reveal(short)


def test_backend_override_rebuilds_scheme():
    agg = SecureAggregator(backend="pallas")
    assert agg.scheme.backend == "pallas"
    assert SecureAggregator().backend == "reference"
    with pytest.raises(ValueError, match="backend"):
        ShamirScheme(backend="cuda")


# ------------------------------------------------- fused encode+share kernel
@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_encode_share_matches_codec_plus_oracle(field, dtype, rng_key):
    """encode+share fusion == FixedPointCodec.encode then share kernel."""
    from repro.kernels import ref

    codec = FixedPointCodec(field=field)
    rows, t, w = 8, 2, 3
    x = jnp.clip(
        jax.random.normal(rng_key, (rows, 128), jnp.float64), -3, 3
    ).astype(dtype)
    coeffs = random_elements(
        jax.random.fold_in(rng_key, 1), (t - 1, rows, 128), field
    ).astype(jnp.uint32)
    shares = ops.shamir_protect_flat(
        x, coeffs, w, field.moduli, codec.frac_bits
    )
    assert shares.shape == (w, field.num_residues, rows, 128)
    enc = codec.encode(x)  # (R, rows, 128) uint64
    for r, p in enumerate(field.moduli):
        want = ref.shamir_shares(
            enc[r].reshape(-1),
            coeffs[r].reshape(t - 1, -1).astype(jnp.uint64), w, p,
        )
        got = shares[:, r].reshape(w, -1).astype(jnp.uint64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
