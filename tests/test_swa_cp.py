"""Context-parallel SWA attention (halo exchange) vs single-device attend.

Runs swa_attend_cp under a real (1, ntp) device mesh (host platform forced
to 8 CPU devices via conftest? no — this test spawns its own mesh from
whatever devices exist and skips when only 1 is present; the dry-run is
the full-scale check) — here we validate NUMERICS with ntp=1 mesh plus a
pure shard_map single-device run, and the halo logic via a manual
reference computation with ntp logical chunks executed sequentially.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import MeshRules
from repro.models.attention import attend, swa_attend_cp


def _qkv(key, B=2, S=64, H=4, KVH=2, Dk=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dk), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, Dk), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, Dk), jnp.float32)
    return q, k, v


def test_swa_cp_matches_attend_single_device(rng_key):
    """ntp=1 mesh: halo path degenerates but exercises shard_map + masks."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh=mesh)
    q, k, v = _qkv(rng_key)
    ref = attend(q, k, v, window=24)
    out = swa_attend_cp(q, k, v, window=24, rules=rules)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_swa_cp_halo_logic_manual():
    """Re-implements the chunked halo computation host-side and checks the
    masked-position semantics: with window w and chunk L, each q in chunk
    c attends to positions (pos-w, pos] only, across chunk boundaries."""
    key = jax.random.PRNGKey(1)
    q, k, v = _qkv(key, B=1, S=48, H=2, KVH=2)
    window = 20
    ref = attend(q, k, v, window=window)

    # manual chunked evaluation with n_halo left chunks
    L, n_chunks = 12, 4
    n_halo = -(-window // L)
    outs = []
    from repro.models.attention import _online_block_scan

    for c in range(n_chunks):
        lo = max(0, (c - n_halo) * L)
        span_lo = (c - n_halo) * L
        k_span = jnp.concatenate(
            [jnp.zeros((1, lo - span_lo, 2, 16), jnp.float32),
             k[:, lo:(c + 1) * L]], axis=1)
        v_span = jnp.concatenate(
            [jnp.zeros((1, lo - span_lo, 2, 16), jnp.float32),
             v[:, lo:(c + 1) * L]], axis=1)
        kv_pos = span_lo + jnp.arange((n_halo + 1) * L, dtype=jnp.int32)
        q_pos = c * L + jnp.arange(L, dtype=jnp.int32)
        qr = q[:, c * L:(c + 1) * L].reshape(1, L, 2, 1, 16)
        o = _online_block_scan(qr, k_span, v_span, q_pos, kv_pos, window,
                               16**-0.5)
        outs.append(o.reshape(1, L, 2, 16))
    out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
