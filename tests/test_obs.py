"""Observability layer: tracer, ledger, metrics, purity lint, audit.

The tentpole invariants:

* disabled tracing is invisible (no spans, bit-identical fits);
* the span exporters round-trip (JSONL) and emit valid Chrome traces;
* the privacy ledger counts every host-wrapper invocation of a
  declassification boundary, and the audit reconciles those counts
  against the static gate's certified jaxpr census — with the
  deliberate extra-reveal fixture FLAGGED;
* the obs core stays stdlib-only (purity lint), so none of the above
  can ever introduce a device dependency or hidden sync.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.drivers import all_driver_specs
from repro.analysis.lints import lint_obs_purity
from repro.core.secure_agg import SecureAggregator
from repro.data import generate_synthetic
from repro.obs import audit, ledger, metrics, trace


@pytest.fixture(autouse=True)
def _obs_clean():
    trace.disable()
    ledger.disable()
    ledger.reset()
    metrics.reset()
    yield
    trace.disable()
    ledger.disable()
    ledger.reset()
    metrics.reset()


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(7), num_institutions=3,
        records_per_institution=100, dim=5,
    )


# ------------------------------------------------------------- span tracer

def test_disabled_tracing_records_nothing():
    assert trace.get() is None
    with trace.span("protect", "x", foo=1) as s:
        s.set(bar=2)  # the noop span accepts the live-span API
    assert trace.get() is None


def test_spans_record_and_summarize():
    tracer = trace.enable(capacity=16)
    with trace.span("protect", "p1", rows=8):
        pass
    with trace.span("reveal"):
        pass
    assert [s.kind for s in tracer.spans] == ["protect", "reveal"]
    s = tracer.spans[0]
    assert s.name == "p1" and s.attrs == {"rows": 8} and s.duration >= 0
    summary = tracer.summary()
    assert summary["protect"]["count"] == 1
    assert len(tracer.summary_lines()) == 3  # header + 2 kinds


def test_ring_buffer_evicts_oldest():
    tracer = trace.enable(capacity=3)
    for i in range(5):
        with trace.span("k", f"s{i}"):
            pass
    assert [s.name for s in tracer.spans] == ["s2", "s3", "s4"]


def test_traced_decorator_labels_qualname():
    tracer = trace.enable()

    @trace.traced("newton")
    def my_step():
        return 42

    assert my_step() == 42
    assert tracer.spans[0].name.endswith("my_step")
    trace.disable()
    assert my_step() == 42  # disabled path: plain call-through


def test_jsonl_roundtrip_and_chrome_trace(tmp_path):
    tracer = trace.enable()
    with trace.span("protect", "p", rows=8):
        with trace.span("reveal", "r"):
            pass
    tracer = trace.disable()
    n = tracer.export_jsonl(tmp_path / "run.jsonl")
    assert n == 2

    back = trace.SpanTracer()
    with open(tmp_path / "run.jsonl") as fh:
        for line in fh:
            back.record(json.loads(line))
    assert back.summary() == tracer.summary()

    tracer.export_chrome_trace(tmp_path / "run.trace.json")
    doc = json.loads((tmp_path / "run.trace.json").read_text())
    events = doc["traceEvents"]
    assert {e["ph"] for e in events} == {"X"}
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in events)
    by_name = {e["name"]: e for e in events}
    # the reveal span nests inside the protect span on the timeline
    assert by_name["r"]["ts"] >= by_name["p"]["ts"]
    assert by_name["p"]["args"] == {"rows": 8}


def test_driver_emits_spans(study):
    from repro.core.newton import SecureFitDriver

    tracer = trace.enable()
    SecureFitDriver(study.parts, lam=1.0, protect="gradient",
                    fused=False).run(max_iter=3)
    kinds = {s.kind for s in tracer.spans}
    assert {"newton", "protect", "aggregate", "reveal"} <= kinds


def test_tracing_is_bit_invisible(study):
    from repro.core.newton import SecureFitDriver

    def fit():
        d = SecureFitDriver(study.parts, lam=1.0, protect="gradient",
                            aggregator=SecureAggregator(backend="pallas"),
                            fused=True)
        d.run(max_iter=6)
        return np.asarray(d.beta)

    off = fit()
    trace.enable()
    on = fit()
    trace.disable()
    np.testing.assert_array_equal(off, on)


# ---------------------------------------------------------- privacy ledger

def test_ledger_disabled_records_nothing():
    ledger.record_site("_reveal_flat", what="x", shape=(2, 2))
    assert ledger.counts() == {}


def test_ledger_capture_counts_wrapper_invocations():
    agg = SecureAggregator(backend="pallas")
    tree = {"g": jnp.arange(4.0)}
    with ledger.capture() as cap:
        prot = agg.protect(jax.random.PRNGKey(0), tree)
        agg.reveal(agg.aggregate([prot, prot]))
    assert cap.by_site.get("_protect_flat") == 1
    assert cap.by_site.get("_reveal_flat") == 1
    # and captures reset: outside the capture the ledger is off again,
    # so further boundary invocations leave the totals untouched
    assert not ledger.enabled()
    before = ledger.counts()
    ledger.record_site("_reveal_flat")
    assert ledger.counts() == before


def test_ledger_counts_per_invocation_despite_jit_cache():
    agg = SecureAggregator(backend="pallas")
    tree = {"g": jnp.arange(4.0)}
    with ledger.capture() as cap:
        for i in range(3):  # same shapes: jit cache hits after the first
            agg.protect(jax.random.PRNGKey(i), tree)
    assert cap.by_site["_protect_flat"] == 3


def test_declassify_sum_records_shape():
    from repro.core.secure_agg import declassify_sum

    with ledger.capture() as cap:
        declassify_sum(jnp.ones((4, 3)), axis=0)
    (key,) = [k for k in cap.counts if k[0] == "declassify_sum"]
    assert key[2] == (4, 3)


# ----------------------------------------------------------------- metrics

def test_observe_round_and_prometheus_render():
    metrics.observe_round("secure_fit", 1024, objective=3.5,
                          grad_norm=0.25, step_norm=0.1)
    metrics.observe_round("secure_fit", 1024)
    assert metrics.get("repro_rounds_total", driver="secure_fit") == 2
    assert metrics.get("repro_bytes_total", driver="secure_fit") == 2048
    assert metrics.get("repro_grad_norm", driver="secure_fit") == 0.25
    text = metrics.render_prometheus(
        metrics.ledger_counter_series({"_reveal_flat": 2,
                                       "_protect_flat": 2})
    )
    assert 'repro_rounds_total{driver="secure_fit"} 2' in text
    assert 'repro_declass_total{site="_reveal_flat"} 2' in text
    assert "repro_protect_total 2" in text
    assert "# TYPE repro_objective gauge" in text


# --------------------------------------------------------- obs purity lint

def test_obs_purity_real_modules_clean():
    rep = lint_obs_purity()
    assert rep.ok, [f.format() for f in rep.errors()]
    assert len([f for f in rep.findings if f.severity == "info"]) == 3


def test_obs_purity_catches_jax_import_and_materializer():
    bad_import = "import jax\nX = 1\n"
    bad_sync = ("def f(x):\n"
                "    import math\n"
                "    return jax.device_get(x)\n")
    rep = lint_obs_purity(modules={"obs/fake.py": bad_import})
    assert not rep.ok and "import of 'jax'" in rep.errors()[0].message
    rep = lint_obs_purity(modules={"obs/fake.py": bad_sync})
    assert not rep.ok and "device_get" in rep.errors()[0].message


def test_obs_purity_allows_the_lazy_profiler_hook():
    src = ("class SpanTracer:\n"
           "    def _annotation(self, name):\n"
           "        import jax.profiler\n"
           "        return jax.profiler.TraceAnnotation(name)\n")
    rep = lint_obs_purity(modules={"obs/trace.py": src})
    assert rep.ok, [f.format() for f in rep.errors()]


# ------------------------------------------------------------ the audit

def _fused_spec():
    return next(s for s in all_driver_specs()
                if s.name == "secure_fit_fused[protect=gradient]")


def test_graph_census_finds_the_certified_boundaries():
    spec = _fused_spec()
    closed, _ = spec.build()
    census = audit.graph_census(closed)
    by_site = {}
    for (site, _shape), n in census.items():
        by_site[site] = by_site.get(site, 0) + n
    assert by_site == {"_protect_flat": 1, "_reveal_flat": 1,
                       "declassify_sum": 1}


def test_audit_spec_reconciles():
    res = audit.audit_spec(_fused_spec())
    assert not res.skipped
    assert res.ok, res.findings()
    assert res.recorded == res.expected != {}


def test_extra_reveal_is_flagged():
    res = audit.extra_reveal_fixture(_fused_spec())
    assert not res.ok
    assert any("UNCERTIFIED" in f for f in res.findings())


def test_audit_cli_subprocess(tmp_path):
    """The full CLI path: 8 host devices, JSON output, self-test armed.

    Subprocess on purpose — the psum specs need XLA_FLAGS applied before
    jax imports (banned in-process; see conftest).  Restricted to the
    fused drivers to keep the smoke fast; bench_smoke runs all 12.
    """
    import os
    import pathlib

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # the CLI sets its own host-device flags
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs", "audit", "--json",
         "--drivers", "secure_fit_fused",
         "--textfile", str(tmp_path / "obs.prom")],
        capture_output=True, text=True, env=env,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
    )
    assert out.returncode == 0, out.stdout + out.stderr
    payload = json.loads(out.stdout)
    assert payload["ok"]
    assert len(payload["specs"]) == 2
    assert all(s["ok"] and not s["skipped"] for s in payload["specs"])
    assert payload["fixture"] is not None and not payload["fixture"]["ok"]
    prom = (tmp_path / "obs.prom").read_text()
    assert 'repro_declass_total{site="_reveal_flat"}' in prom
