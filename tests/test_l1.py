"""Elastic-net (L1) secure fit: KKT optimality + protocol invariance.

The institution-side protocol (summaries, shares, aggregation) is
identical to the L2 path; only the Computation Centers' solver changes.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.logreg import predict_proba
from repro.core.newton import secure_fit
from repro.data.synthetic import generate_synthetic


def _study(key, S=4, n=800, d=10):
    return generate_synthetic(key, num_institutions=S,
                              records_per_institution=n, dim=d)


def test_l1_zero_matches_l2_path(rng_key):
    study = _study(rng_key)
    a = secure_fit(list(study.parts), lam=1.0, l1=0.0)
    b = secure_fit(list(study.parts), lam=1.0)
    np.testing.assert_allclose(a.beta, b.beta, rtol=1e-10, atol=1e-12)


def test_l1_kkt_conditions(rng_key):
    """At the elastic-net optimum: |∇_j NLL + lam*beta_j| <= l1 for zero
    coords; = -l1*sign(beta_j) for active coords (within tolerance)."""
    study = _study(rng_key, d=8)
    lam, l1 = 0.5, 8.0
    res = secure_fit(list(study.parts), lam=lam, l1=l1, max_iter=80,
                     tol=1e-12)
    X, y = study.pooled()
    beta = jnp.asarray(res.beta)
    p = predict_proba(beta, X)
    # ascent gradient of logL: X^T (y - p); smooth obj gradient:
    grad_smooth = -(X.T @ (y - p)) + lam * beta
    g = np.asarray(grad_smooth)
    b = np.asarray(beta)
    tol = 0.05 * l1 + 1e-6
    for j in range(len(b)):
        if abs(b[j]) > 1e-8:
            assert abs(g[j] + l1 * np.sign(b[j])) < tol, (j, g[j], b[j])
        else:
            assert abs(g[j]) <= l1 + tol


def test_l1_induces_sparsity_monotonically(rng_key):
    study = _study(rng_key, d=12)
    nnz = []
    for l1 in (0.0, 20.0, 200.0):
        res = secure_fit(list(study.parts), lam=0.1, l1=l1, max_iter=60)
        nnz.append(int(np.sum(np.abs(res.beta) > 1e-6)))
    assert nnz[0] >= nnz[1] >= nnz[2]
    assert nnz[2] < nnz[0]  # strong penalty actually zeroes features
