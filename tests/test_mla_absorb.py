"""MLA absorbed decode == expand-then-attend decode (exact same math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.distributed import MeshRules
from repro.models import transformer as T


def test_mla_absorbed_decode_matches_expand(rng_key):
    cfg = smoke_config("deepseek_v2_lite")
    cfg32 = dataclasses.replace(cfg, dtype_str="float32")
    rules = MeshRules(mesh=None)
    params = T.init_params(rng_key, cfg32)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.fold_in(rng_key, 1), (B, P),
                              0, cfg32.vocab_size, dtype=jnp.int32)
    logits, caches, length = T.prefill(params, cfg32, rules, tokens=toks,
                                       cache_len=P + 4)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    cfg_abs = dataclasses.replace(cfg32, mla_absorb=True)
    l_exp, c_exp, _ = T.decode_step(params, caches, length, cfg32, rules,
                                    tokens=nxt)
    l_abs, c_abs, _ = T.decode_step(params, caches, length, cfg_abs, rules,
                                    tokens=nxt)
    np.testing.assert_allclose(np.asarray(l_abs), np.asarray(l_exp),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree_util.tree_leaves(c_abs),
                    jax.tree_util.tree_leaves(c_exp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)
