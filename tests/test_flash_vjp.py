"""Flash custom-VJP attention vs autodiff-through-scan oracle.

Forward is shared code, so the tests focus on gradients: the flash
backward (recompute block scores, O(S·d) residuals) must match plain
autodiff of the online-softmax scan for full-causal and windowed masks,
GQA grouping, Dk != Dv, and through the q-blocked banded path.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend


def _qkv(key, B=2, S=64, H=4, KVH=2, Dk=16, Dv=16, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dk), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, Dk), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, Dv), dtype)
    return q, k, v


def _grads(fn, q, k, v):
    def loss(q, k, v):
        o = fn(q, k, v)
        t = jnp.sin(jnp.arange(o.size, dtype=jnp.float32)).reshape(o.shape)
        return jnp.sum(o.astype(jnp.float32) * t)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("window", [0, 24])
def test_flash_grads_match_autodiff(rng_key, window):
    q, k, v = _qkv(rng_key)
    base = functools.partial(attend, window=window, q_block=32,
                             flash_vjp=False)
    flash = functools.partial(attend, window=window, q_block=32,
                              flash_vjp=True)
    np.testing.assert_allclose(flash(q, k, v), base(q, k, v),
                               rtol=1e-6, atol=1e-6)
    g_ref = _grads(base, q, k, v)
    g_fl = _grads(flash, q, k, v)
    for a, b in zip(g_fl, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_grads_mla_shapes(rng_key):
    # Dv != Dk (MLA-style) + GQA group > 1
    q, k, _ = _qkv(rng_key, Dk=24, Dv=24)
    v = jax.random.normal(jax.random.fold_in(rng_key, 9), (2, 64, 2, 12))
    base = functools.partial(attend, flash_vjp=False)
    flash = functools.partial(attend, flash_vjp=True)
    g_ref = _grads(base, q, k, v)
    g_fl = _grads(flash, q, k, v)
    for a, b in zip(g_fl, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs(rng_key):
    q, k, v = _qkv(rng_key, dtype=jnp.bfloat16)
    base = functools.partial(attend, flash_vjp=False)
    flash = functools.partial(attend, flash_vjp=True)
    np.testing.assert_allclose(
        np.asarray(flash(q, k, v), np.float32),
        np.asarray(base(q, k, v), np.float32), rtol=1e-2, atol=1e-2,
    )
    g_ref = _grads(base, q, k, v)
    g_fl = _grads(flash, q, k, v)
    for a, b in zip(g_fl, g_ref):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=5e-2,
        )
